PY := PYTHONPATH=src python

# Tier-1: fast suite, `slow`-marked tests excluded via pyproject addopts.
# Runs the docs drift gate first (it is also a pytest in tests/test_docs.py).
# PYTEST_FLAGS passes extra flags through (CI sets --durations=15).
test-fast: docs-check
	$(PY) -m pytest -x -q $(PYTEST_FLAGS)

# Everything, including the multi-minute jit-heavy tests.
test-all:
	$(PY) -m pytest -q -m "slow or not slow"

# Docs drift gate: README/ARCHITECTURE exist, core modules keep their
# docstrings, and doc-quoted `make`/`python -m` snippets match the tree.
docs-check:
	$(PY) -m tools.docs_check

bench-quick:
	$(PY) -m benchmarks.run --quick

multi-agent-bench:
	$(PY) -m benchmarks.run --quick --only multi_agent_throughput

# Disaggregated actor/learner fleet: samples/s vs worker count + the
# fault-resilience (time-to-target with a worker kill) report.
fleet-bench:
	$(PY) -m benchmarks.fleet_throughput

# Serving tier: slot-forward capacity + open-loop trace replay (QPS,
# p50/p99 latency) per domain — the committed serve_throughput baselines.
serve-bench:
	$(PY) -m benchmarks.serve_throughput

# Kill-and-resume end-to-end: SIGTERM a short rl_train mid-run, resume
# it, and require bitwise-identical final params vs the uninterrupted
# same-seed run (what the CI fault-smoke job runs).
fault-smoke:
	$(PY) tools/ci_fault_smoke.py

# Serving chaos end-to-end: a quick virtual-clock policy_serve replay
# under a deterministic SlowDispatch+CorruptCheckpoint plan — asserts a
# clean drain, a rejected corrupt reload (old weights keep serving),
# and a fault snapshot matching the plan (what CI's serve-chaos runs).
serve-chaos:
	$(PY) tools/ci_serve_chaos.py

# Regression gate: re-measure the throughput benches and fail on a >30%
# steps/s drop vs the committed results/bench baselines (side-effect-free).
# Also fails when results/dryrun has zero ok cells (empty roofline).
bench-check:
	$(PY) -m benchmarks.run --check

# Regenerate the roofline dry-run cells (results/dryrun/*.json) for the
# real whole-horizon IALS programs on the simulated pod meshes, then
# rebuild the committed roofline tables/summary from them.
dryrun:
	$(PY) -m repro.launch.dryrun --ials all
	$(PY) -m benchmarks.run --only roofline_report

.PHONY: test-fast test-all docs-check bench-quick multi-agent-bench \
	fleet-bench serve-bench fault-smoke serve-chaos bench-check dryrun
