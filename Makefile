PY := PYTHONPATH=src python

# Tier-1: fast suite, `slow`-marked tests excluded via pyproject addopts.
test-fast:
	$(PY) -m pytest -x -q

# Everything, including the multi-minute jit-heavy tests.
test-all:
	$(PY) -m pytest -q -m "slow or not slow"

bench-quick:
	$(PY) -m benchmarks.run --quick

multi-agent-bench:
	$(PY) -m benchmarks.run --quick --only multi_agent_throughput

# Regression gate: re-measure the throughput benches and fail on a >30%
# steps/s drop vs the committed results/bench baselines (side-effect-free).
bench-check:
	$(PY) -m benchmarks.run --check

.PHONY: test-fast test-all bench-quick multi-agent-bench bench-check
