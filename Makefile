PY := PYTHONPATH=src python

# Tier-1: fast suite, `slow`-marked tests excluded via pyproject addopts.
test-fast:
	$(PY) -m pytest -x -q

# Everything, including the multi-minute jit-heavy tests.
test-all:
	$(PY) -m pytest -q -m "slow or not slow"

bench-quick:
	$(PY) -m benchmarks.run --quick

multi-agent-bench:
	$(PY) -m benchmarks.run --quick --only multi_agent_throughput

.PHONY: test-fast test-all bench-quick multi-agent-bench
