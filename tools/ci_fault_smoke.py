"""Kill-and-resume fault smoke (``make fault-smoke``; CI runs it too).

Exercises the fault-tolerance contract (docs/ARCHITECTURE.md §7) with a
real SIGTERM against a real ``rl_train`` process — the in-process tests
pin the same property, but only a subprocess proves the signal path,
the clean-exit flush, and the auto-resume CLI behave end to end:

  1. run a short uninterrupted ``rl_train --ckpt-dir`` to completion
     (the same-seed oracle);
  2. launch the identical command against a fresh checkpoint dir, wait
     for the first training iteration to stream past, SIGTERM it, and
     require a clean exit that prints the "checkpoint flushed" line;
  3. re-run that identical command — it must auto-resume from the
     flushed checkpoint — and require ``final_params_md5`` (and the
     final GS eval reward) to match the oracle run **bitwise**.

Pure stdlib + the installed package via subprocess; safe for CI (writes
only under a temp dir, never touches committed baselines).
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# small enough for a CPU CI runner, large enough that the kill lands
# mid-run: the SIGTERM is sent after the first iteration row appears and
# the guard flushes at the next iteration boundary (--save-every 1)
BASE_ARGS = [
    "--domain", "traffic", "--simulator", "ials", "--iterations", "4",
    "--eval-every", "100", "--n-envs", "8", "--rollout-len", "8",
    "--episode-len", "16", "--collect-episodes", "2", "--aip-epochs", "1",
    "--seed", "4", "--save-every", "1",
]
TIMEOUT_S = 900


def _cmd(ckpt_dir: Path, out: Path) -> list[str]:
    return [sys.executable, "-m", "repro.launch.rl_train", *BASE_ARGS,
            "--ckpt-dir", str(ckpt_dir), "--out", str(out)]


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["PYTHONUNBUFFERED"] = "1"
    return env


def _run_to_completion(ckpt_dir: Path, out: Path) -> dict:
    subprocess.run(_cmd(ckpt_dir, out), env=_env(), cwd=REPO,
                   check=True, timeout=TIMEOUT_S)
    return json.loads(out.read_text())


def _run_and_kill(ckpt_dir: Path, out: Path) -> None:
    """Start the run, SIGTERM it after the first iteration row, and
    require the clean preemption exit (flush + 'exiting cleanly')."""
    proc = subprocess.Popen(_cmd(ckpt_dir, out), env=_env(), cwd=REPO,
                            stdout=subprocess.PIPE, text=True, bufsize=1)
    lines, sent = [], False
    deadline = time.time() + TIMEOUT_S
    try:
        for line in proc.stdout:
            lines.append(line.rstrip())
            if time.time() > deadline:
                raise TimeoutError("killed run exceeded timeout")
            if not sent and line.startswith("{") and '"iter"' in line:
                proc.send_signal(signal.SIGTERM)
                sent = True
        rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert sent, f"no iteration row ever streamed:\n" + "\n".join(lines)
    assert rc == 0, f"preempted run exited {rc}:\n" + "\n".join(lines)
    assert any("checkpoint flushed, exiting cleanly" in ln
               for ln in lines), \
        "SIGTERM did not produce the clean flush line:\n" + "\n".join(lines)


def main() -> int:
    with tempfile.TemporaryDirectory(prefix="fault_smoke_") as tmp:
        tmp = Path(tmp)
        print("fault-smoke: [1/3] uninterrupted same-seed oracle run")
        ref = _run_to_completion(tmp / "ref_ckpt", tmp / "ref.json")
        assert not ref["preempted"]

        print("fault-smoke: [2/3] SIGTERM mid-run, expect clean flush")
        _run_and_kill(tmp / "kill_ckpt", tmp / "kill.json")
        killed = json.loads((tmp / "kill.json").read_text())
        assert killed["preempted"], "killed run did not record preemption"

        print("fault-smoke: [3/3] re-run same command, expect auto-resume")
        res = _run_to_completion(tmp / "kill_ckpt", tmp / "res.json")
        assert res["diag"].get("resumed_from") or res["resumed_from"] > 0, \
            "resumed run did not restore a checkpoint"

        ok_md5 = res["final_params_md5"] == ref["final_params_md5"]
        ref_eval = ref["history"][-1]["gs_eval_reward"]
        res_eval = res["history"][-1]["gs_eval_reward"]
        print(f"fault-smoke: oracle md5 {ref['final_params_md5']}  "
              f"resumed md5 {res['final_params_md5']}")
        print(f"fault-smoke: oracle eval {ref_eval}  resumed eval {res_eval}")
        assert ok_md5, "resumed params differ from the uninterrupted run"
        assert res_eval == ref_eval, "final GS eval reward drifted"
        print("fault-smoke: BITWISE RESUME OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
