"""Serving chaos smoke (``make serve-chaos``; CI runs it too).

Exercises the overload contract (docs/ARCHITECTURE.md §8) end to end
through the real ``repro.launch.policy_serve`` driver — the in-process
tests pin the same properties, but only the driver run proves the
``--faults`` plan parsing, the admission wiring, the reload seam, and
the JSON snapshot behave together:

  1. replay a quick virtual-clock trace behind admission control with a
     deterministic chaos plan: a ``SlowDispatch`` stall plus a
     ``CorruptCheckpoint`` poisoning the one scheduled hot-reload
     attempt (``--reload-at``);
  2. require a clean drain (``final_state == "drained"``, every
     non-shed request served);
  3. require the corrupt reload to have been REJECTED — the policy
     version must still be 0 and the reload log must carry the
     rejection — while the replay kept serving;
  4. require the driver's fault-application snapshot to match the
     plan's literal event counts (the driver itself runs
     ``FaultInjector.assert_exhausted`` — a planned event that never
     fires fails the run, not just this comparison);
  5. replay the identical command and require the identical snapshot —
     the chaos run is bit-deterministic on the virtual clock.

In-process (no subprocess): the driver's ``main`` is a library entry;
writes only under a temp dir, never touches committed baselines.
"""
from __future__ import annotations

import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

PLAN = "slow:2:0.05,corrupt:0:nan"
PLAN_COUNTS = {"SlowDispatch": 1, "CorruptCheckpoint": 1}


def _serve(out_path: Path) -> dict:
    from repro.launch import policy_serve
    return policy_serve.main([
        "--domain", "traffic", "--slot", "16", "--regions", "8",
        "--rps", "4000", "--duration-s", "0.1",
        "--virtual", "--service-time-s", "0.002",
        "--admission", "--queue-cap", "256",
        "--faults", PLAN, "--reload-at", "1",
        "--out", str(out_path)])


def main() -> int:
    with tempfile.TemporaryDirectory(prefix="serve_chaos_") as tmp:
        tmp = Path(tmp)
        print(f"serve-chaos: [1/3] chaos replay, plan: {PLAN}")
        res = _serve(tmp / "chaos.json")

        assert res["final_state"] == "drained", \
            f"server did not drain: {res['final_state']!r}"
        assert res["served"] + res["rejected"] == res["requests"], \
            "served + shed != offered: requests were lost silently"
        assert res["served"] > 0, "nothing served"

        print("serve-chaos: [2/3] corrupt reload must have been rejected")
        assert res["reload_rejected"] == 1 and res["reloads"] == 0, \
            f"reload outcome wrong: {res['reload_rejected']=} " \
            f"{res['reloads']=}"
        assert res["policy_version"] == 0, \
            "corrupt weights swapped in: policy_version advanced"
        tag, reason = res["reload_log"][-1]
        assert tag == "rejected" and "canary" in reason, \
            f"unexpected reload log entry: {(tag, reason)!r}"

        assert res["faults_applied"] == PLAN_COUNTS, \
            f"fault snapshot {res['faults_applied']!r} != plan " \
            f"{PLAN_COUNTS!r}"

        print("serve-chaos: [3/3] identical rerun, expect identical "
              "snapshot (virtual clock)")
        res2 = _serve(tmp / "chaos2.json")
        assert res2 == res, "chaos replay is not deterministic"

        print(f"serve-chaos: OK — {res['served']} served, "
              f"{res['rejected']} shed "
              f"({res['rejected_by_reason']}), corrupt reload rejected, "
              f"plan exhausted, drained")
    return 0


if __name__ == "__main__":
    sys.exit(main())
