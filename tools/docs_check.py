"""Docs drift gate (``make docs-check``, also run by the test suite).

Fails when the documentation and the tree disagree:
  1. ``README.md`` or ``docs/ARCHITECTURE.md`` is missing;
  2. any module under ``src/repro/{core,envs,kernels,rl}`` lacks a module
     docstring;
  3. a ``make <target>`` quoted in the docs names a target the Makefile
     does not define (snippet drift);
  4. a ``python -m <module>`` entry point quoted in the docs does not
     resolve to a module file under ``src/`` or the repo root;
  5. a ``path/to/file.py::symbol`` reference (the engine dispatch table's
     cell format) names a file that does not exist or a symbol the file
     does not define at top level;
  6. a REQUIRED snippet is missing from its doc (``REQUIRED_SNIPPETS``):
     load-bearing entry points and dispatch-table cells the docs must
     keep quoting — e.g. the ``python -m benchmarks.train_throughput``
     train-throughput tier and the actor-in-the-loop ``policy_rollout``
     dispatch symbols. (Checks 3-5 then verify those quotes resolve, so
     the pair catches both "doc dropped it" and "tree renamed it".)

Pure stdlib, no imports of the package itself — the checker must keep
working even when the package is broken.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ("README.md", "docs/ARCHITECTURE.md")
DOCSTRING_TREES = ("src/repro/core", "src/repro/envs", "src/repro/kernels",
                   "src/repro/rl", "src/repro/serving")

# snippets the named doc must quote (inside backticks or a fenced block);
# the resolution checks below make sure each still matches the tree
REQUIRED_SNIPPETS = {
    "README.md": (
        "python -m benchmarks.train_throughput",
        "python -m benchmarks.fleet_throughput",
        "python -m repro.launch.dryrun --ials",
        "make fault-smoke",
        # the serving tier (§8) entry points
        "python -m repro.launch.policy_serve",
        "python -m benchmarks.serve_throughput",
        "make serve-chaos",
    ),
    "docs/ARCHITECTURE.md": (
        "kernels/ops.py::policy_rollout",
        "kernels/aip_step.py::policy_rollout",
        "kernels/ref.py::policy_rollout_ref",
        "python -m benchmarks.train_throughput",
        "python -m repro.launch.dryrun --ials",
        # the fault-tolerance contract (§7) entry points
        "distributed/actor_learner.py::ActorLearnerTrainer",
        "distributed/fault_injection.py::FaultInjector",
        "distributed/fault_injection.py::torn_save",
        "checkpoint/ckpt.py::read_metadata",
        "rl/ppo.py::learner_update_fn",
        "python -m benchmarks.fleet_throughput",
        # the serving contract (§8) entry points + dispatch cells
        "python -m repro.launch.policy_serve",
        "python -m benchmarks.serve_throughput",
        "serving/scheduler.py::SlotScheduler",
        "serving/server.py::PolicyServer",
        "kernels/ops.py::serve_forward",
        "envs/api.py::pad_lanes",
        "checkpoint/ckpt.py::restore_subtree",
        # the bucket table + cross-policy ABI (§8, PR 9)
        "serving/scheduler.py::BucketedSlotScheduler",
        "serving/scheduler.py::calibrate_buckets",
        "serving/scheduler.py::expected_padded_waste",
        "serving/server.py::ServeStats",
        "rl/ppo.py::stack_policy_weights",
        "kernels/ops.py::serve_forward_multi",
        "kernels/ref.py::serve_forward_multi_ref",
        "kernels/aip_step.py::serve_forward_multi",
        # the overload contract (§8, PR 10)
        "serving/overload.py::AdmissionController",
        "serving/overload.py::BrownoutController",
        "serving/overload.py::DispatchLatencyModel",
        "serving/request.py::flood_trace",
        "distributed/fault_injection.py::SlowDispatch",
        "distributed/fault_injection.py::RequestFlood",
        "distributed/fault_injection.py::CorruptCheckpoint",
        "distributed/fault_injection.py::parse_serve_faults",
        "make serve-chaos",
    ),
}


def missing_docs() -> list[str]:
    return [f"missing required doc: {name}" for name in DOC_FILES
            if not (REPO / name).is_file()]


def missing_docstrings() -> list[str]:
    errors = []
    for tree in DOCSTRING_TREES:
        for path in sorted((REPO / tree).rglob("*.py")):
            mod = ast.parse(path.read_text(), filename=str(path))
            if not ast.get_docstring(mod):
                rel = path.relative_to(REPO)
                errors.append(f"module docstring missing: {rel}")
    return errors


def _makefile_targets() -> set[str]:
    targets = set()
    for line in (REPO / "Makefile").read_text().splitlines():
        m = re.match(r"^([A-Za-z][\w.-]*):", line)
        if m:
            targets.add(m.group(1))
    return targets


def _code_snippets(text: str) -> str:
    """Fenced code blocks plus inline backtick spans — the only places a
    `make ...` / `python -m ...` reference counts as a quoted snippet
    (prose like "adapters make the two worlds ..." must not trip the
    gate)."""
    fenced = re.findall(r"```.*?```", text, flags=re.S)
    inline = re.findall(r"`[^`\n]+`", text)
    return "\n".join(fenced + inline)


def stale_make_refs() -> list[str]:
    targets = _makefile_targets()
    errors = []
    for name in DOC_FILES:
        path = REPO / name
        if not path.is_file():
            continue
        snippets = _code_snippets(path.read_text())
        for ref in re.findall(r"\bmake\s+([a-z][\w-]*)", snippets):
            if ref not in targets:
                errors.append(f"{name} quotes `make {ref}` but the "
                              f"Makefile defines no such target")
    return errors


def _module_exists(module: str) -> bool:
    rel = Path(*module.split("."))
    return any((root / rel).with_suffix(".py").is_file()
               or (root / rel / "__init__.py").is_file()
               for root in (REPO / "src", REPO))


def stale_module_refs() -> list[str]:
    errors = []
    for name in DOC_FILES:
        path = REPO / name
        if not path.is_file():
            continue
        for ref in re.findall(r"-m\s+([\w.]+)",
                              _code_snippets(path.read_text())):
            if not _module_exists(ref):
                errors.append(f"{name} quotes `python -m {ref}` but no "
                              f"such module exists")
    return errors


_SYMBOL_ROOTS = ("", "src", "src/repro")


def _resolve_doc_path(rel: str):
    for root in _SYMBOL_ROOTS:
        p = REPO / root / rel
        if p.is_file():
            return p
    return None


def _top_level_names(path: Path) -> set[str]:
    names = set()
    for node in ast.parse(path.read_text(), filename=str(path)).body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name):
                names.add(node.target.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add(alias.asname or alias.name.split(".")[0])
    return names


def stale_symbol_refs() -> list[str]:
    """``file.py::symbol`` references (the ARCHITECTURE dispatch table's
    cell format) must name a real file defining that symbol at top
    level, so the table cannot quietly outlive a refactor."""
    errors = []
    for name in DOC_FILES:
        path = REPO / name
        if not path.is_file():
            continue
        snippets = _code_snippets(path.read_text())
        for rel, sym in re.findall(r"([\w][\w/.-]*\.py)::(\w+)", snippets):
            target = _resolve_doc_path(rel)
            if target is None:
                errors.append(f"{name} references `{rel}::{sym}` but no "
                              f"such file exists")
            elif sym not in _top_level_names(target):
                errors.append(f"{name} references `{rel}::{sym}` but "
                              f"{rel} defines no top-level `{sym}`")
    return errors


def missing_required_snippets() -> list[str]:
    """Load-bearing snippets (entry points, dispatch-table cells) must
    stay quoted in their doc — dropping one from the docs is drift just
    as much as quoting a dead one."""
    errors = []
    for name, snippets in REQUIRED_SNIPPETS.items():
        path = REPO / name
        if not path.is_file():
            continue                      # missing_docs() reports it
        quoted = _code_snippets(path.read_text())
        for snip in snippets:
            if snip not in quoted:
                errors.append(f"{name} no longer quotes the required "
                              f"snippet `{snip}`")
    return errors


def run_checks() -> list[str]:
    errors = missing_docs()
    errors += missing_docstrings()
    errors += stale_make_refs()
    errors += stale_module_refs()
    errors += stale_symbol_refs()
    errors += missing_required_snippets()
    return errors


def main() -> int:
    errors = run_checks()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if not errors:
        print("docs-check: ok")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
