"""Expert-parallel MoE via shard_map (§Perf hillclimb #2, beyond-baseline).

Key observation (from the dry-run attribution): under pure GSPMD the
capacity dispatch reshards the full (N*k, d) token payload and all-reduces
(E, C, d_ff)-sized expert activations — ~2.1 TB of collective bytes per
train step on deepseek-moe-16b. But activations are already REPLICATED over
the ``model`` axis (they are sharded over pod/data only), so dispatch needs
NO communication at all: every device routes its local tokens, keeps only
the assignments that hit its own expert group (``axis_index("model")``), and
runs its local experts. The only collective in the whole layer is one
``psum`` of the (N_local, d) combined output over ``model``.

Capacity is per-(data-shard, expert): statistically this drops slightly
more tokens than a global capacity at equal capacity_factor (documented in
EXPERIMENTS.md); with dropless settings the result is bitwise-comparable to
``moe.moe_apply`` (tested on an 8-device mesh).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.act_sharding import current_mesh
from .module import ACTIVATIONS

Params = Dict[str, Any]


def _dp_spec(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def moe_apply_ep(p: Params, x: jax.Array, *, top_k: int, act: str = "silu",
                 capacity_factor: float = 1.25,
                 expert_axes: str = "model") -> tuple:
    """Drop-in for moe.moe_apply when a mesh with a 'model' axis is active.

    ``expert_axes``: "model" shards experts over the model axis only (tokens
    stay dp-sharded; zero-communication dispatch). "data_model" spreads
    experts over BOTH axes — required when E_loc expert weights would not
    fit a device (deepseek-v3: 16 experts/device = 81 GB; 1/device = 5 GB);
    tokens are then replicated (one all-gather) and slot-index gathering
    keeps the dispatch buffer at (E_loc, C, d) instead of (N*k, d).
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        from . import moe as _moe
        return _moe.moe_apply(p, x, top_k=top_k, act=act,
                              capacity_factor=capacity_factor)

    B, T, d = x.shape
    E = p["router"].shape[-1]
    e_axes = ("model",)
    if expert_axes == "data_model" and "data" in mesh.axis_names \
            and E % (mesh.shape["model"] * mesh.shape["data"]) == 0:
        e_axes = ("data", "model")
    ep = 1
    for a in e_axes:
        ep *= mesh.shape[a]
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    dp = _dp_spec(mesh)
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_dp *= mesh.shape[a]
    tokens = x.reshape(-1, d)
    N = tokens.shape[0]
    if N % n_dp or "data" in e_axes:
        # tokens replicated: tiny batches, or experts spread over the data
        # axis too (the expert group then needs every dp shard's tokens)
        dp = None
        N_loc = N
    else:
        N_loc = N // n_dp
    C = max(1, math.ceil(N_loc * top_k / E * capacity_factor))
    a_fn = ACTIVATIONS[act]

    def local_moe(tok, router, wg, wi, wo):
        """Per-device: tok (N_loc, d); wg/wi/wo (E_loc, ...)."""
        j = lax.axis_index("model")
        if len(e_axes) == 2:
            j = lax.axis_index("data") * mesh.shape["model"] + j
        logits = tok.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = lax.top_k(probs, top_k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        flat_e = top_i.reshape(-1)
        flat_w = top_p.reshape(-1)
        n = tok.shape[0]
        tok_idx = jnp.broadcast_to(jnp.arange(n)[:, None],
                                   (n, top_k)).reshape(-1)
        # rank within expert (over ALL experts, locally computed)
        sort_idx = jnp.argsort(flat_e)
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(n * top_k) - starts[flat_e[sort_idx]]
        rank = jnp.zeros_like(rank_sorted).at[sort_idx].set(rank_sorted)
        keep = rank < C

        # keep only assignments owned by THIS device's expert group.
        # Dispatch via SLOT INDICES: scatter token ids (cheap, no d dim)
        # into the (E_loc, C) slot map, then ONE (E_loc*C, d) gather — the
        # (N*k, d) payload tensor never exists.
        local = (flat_e >= j * E_loc) & (flat_e < (j + 1) * E_loc) & keep
        le = jnp.where(local, flat_e - j * E_loc, 0)
        lr = jnp.where(local, rank, C)            # C == drop slot
        slot_tok = jnp.full((E_loc, C + 1), n, jnp.int32).at[le, lr].set(
            tok_idx.astype(jnp.int32), mode="drop")[:, :C]
        slot_valid = (slot_tok < n)
        tok_pad = jnp.concatenate(
            [tok, jnp.zeros((1, d), tok.dtype)], axis=0)
        buf = tok_pad[slot_tok.reshape(-1)].reshape(E_loc, C, d)

        h = (a_fn(jnp.einsum("ecd,edf->ecf", buf, wg))
             * jnp.einsum("ecd,edf->ecf", buf, wi))
        y = jnp.einsum("ecf,efd->ecd", h, wo)               # (E_loc, C, d)
        y = y * slot_valid[..., None].astype(y.dtype)

        # combine back to token-major (non-local/dropped rows are zeroed)
        slot_of_assign = le * C + jnp.minimum(lr, C - 1)
        out_flat = y.reshape(E_loc * C, d)[slot_of_assign] * \
            (flat_w.astype(y.dtype) * local.astype(y.dtype))[:, None]
        out = out_flat.reshape(n, top_k, d).sum(axis=1)
        out = lax.psum(out, "model")          # the layer's ONLY collective
        if len(e_axes) == 2:
            out = lax.psum(out, "data")

        # aux (identical on every model shard after the psums)
        me = probs.mean(axis=0)
        cnt = jnp.bincount(flat_e, weights=keep.astype(jnp.float32),
                           length=E) / max(n * top_k, 1)
        lb = E * jnp.sum(me * cnt)
        zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        drop = 1.0 - keep.astype(jnp.float32).mean()
        aux = jnp.stack([lb, zl, drop])
        aux = lax.pmean(aux, "model")
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                aux = lax.pmean(aux, ax)
        return out, aux

    pspec_e = P(e_axes if len(e_axes) > 1 else e_axes[0], None, None)
    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), pspec_e, pspec_e, pspec_e),
        out_specs=(P(dp, None), P()),
        check_rep=False)
    out, aux_v = fn(tokens, p["router"], p["experts"]["w_gate"],
                    p["experts"]["w_in"], p["experts"]["w_out"])
    aux = {"lb_loss": aux_v[0], "z_loss": aux_v[1], "drop_frac": aux_v[2]}

    if "shared" in p:
        from . import moe as _moe
        out = out + _moe.gated_mlp(p["shared"], tokens, act)
    return out.reshape(B, T, d), aux
