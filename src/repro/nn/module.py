"""Minimal functional parameter substrate.

Params are plain pytrees (nested dicts of jnp arrays). Every layer is a pair
of functions: ``<layer>_init(key, ...) -> params`` and
``<layer>_apply(params, x, ...) -> y``. No classes, no tracing magic — this
keeps everything transparently compatible with pjit/shard_map, scan-stacked
parameters, and ShapeDtypeStruct abstract evaluation for the dry-run.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> Params:
    """Dense layer params. Default init: truncated-normal fan-in."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embedding(p: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def rmsnorm_init(d: int, *, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)).astype(dt)) * p["g"].astype(dt)


def layernorm_init(d: int, *, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(dt)) * p["g"].astype(dt) + p["b"].astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(f"unknown norm kind {kind}")


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def relu2(x):
    """Squared ReLU (Nemotron-4)."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: Dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
    "relu2": relu2,
    "tanh": jnp.tanh,
}


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_size(tree) -> int:
    """Total number of elements in a pytree of arrays."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def stack_init(init_fn: Callable[[jax.Array], Params], key, n: int) -> Params:
    """vmap an init function over ``n`` keys -> stacked (leading-dim n) params.

    This is the scan-over-layers representation: one pytree whose every leaf
    has a leading layer axis, consumed by ``jax.lax.scan``.
    """
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def abstractify(tree, sharding_fn=None):
    """Map a pytree of arrays to ShapeDtypeStructs (optionally with sharding)."""
    def go(x):
        sh = sharding_fn(x) if sharding_fn is not None else None
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
    return jax.tree_util.tree_map(go, tree)
