"""GRU — the paper's AIP backbone (Appendix F, Eq. 11).

``gru_sequence`` is the XLA path; ``repro/kernels/gru.py`` provides the fused
Pallas TPU kernel (both matmuls + gate fusion in one VMEM-resident kernel),
validated against ``repro/kernels/ref.py``.

Gates use the rational activations from ``repro.nn.act`` — the cell is the
IALS rollout engine's per-tick hot loop, and exact tanh/logistic were its
dominant cost (see act.py). Training and rollout share this definition.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .act import fast_sigmoid, fast_tanh
from .module import dense_init

Params = Dict[str, Any]


def gru_init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wx": dense_init(k1, d_in, 3 * d_hidden, dtype=dtype)["w"],
        "wh": dense_init(k2, d_hidden, 3 * d_hidden, dtype=dtype)["w"],
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def gru_cell(p: Params, h: jax.Array, x: jax.Array) -> jax.Array:
    """h: (..., H); x: (..., D) -> new h."""
    H = h.shape[-1]
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    r = fast_sigmoid(gx[..., :H] + gh[..., :H])
    z = fast_sigmoid(gx[..., H:2 * H] + gh[..., H:2 * H])
    n = fast_tanh(gx[..., 2 * H:] + r * gh[..., 2 * H:])
    return (1.0 - z) * n + z * h


def gru_sequence(p: Params, xs: jax.Array, h0: jax.Array | None = None):
    """xs: (B, T, D) -> (hs (B, T, H), h_T)."""
    B, T, _ = xs.shape
    H = p["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, H), xs.dtype)

    def step(h, x):
        h2 = gru_cell(p, h, x)
        return h2, h2

    hT, hs = lax.scan(step, h0, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1), hT
