"""Attention: RoPE, chunked flash-style softmax attention (GQA), decode path.

The training/prefill path is an online-softmax (flash) formulation written in
pure jnp with ``lax.scan`` over query and key/value chunks — this is the XLA
path used by the dry-run (bounded memory at 32k context). The TPU Pallas
kernel in ``repro/kernels/flash_attention.py`` implements the same math with
explicit VMEM BlockSpecs and is validated against ``repro/kernels/ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

BIG_NEG = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions: (...,) int -> cos/sin of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rot_dim: int | None = None) -> jax.Array:
    """x: (B, T, H, D); positions: (T,) or (B, T). Rotates first rot_dim dims."""
    D = x.shape[-1]
    rot_dim = D if rot_dim is None else rot_dim
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    cos, sin = rope_angles(positions, rot_dim, theta)  # (..., rot_dim//2)
    # broadcast across head axis: positions (T,) -> (1, T, 1, rd//2)
    if cos.ndim == 2:  # (T, rd//2)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == 3:  # (B, T, rd//2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Chunked flash-style attention (training / prefill)
# ---------------------------------------------------------------------------

def _pick_chunk(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (n=1500, want=1024 -> 750)."""
    if n <= want:
        return n
    k = -(-n // want)  # ceil
    while n % k:
        k += 1
    return n // k


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    q_offset: int = 0, p_bf16: bool = True) -> jax.Array:
    """Online-softmax attention with GQA grouping.

    q: (B, T, H, D); k, v: (B, S, KH, Dk/Dv) with H % KH == 0.
    Never materialises the (T, S) score matrix nor the repeated KV heads:
    scores live per (q_chunk, k_chunk) tile, grouped einsum handles GQA.
    ``q_offset``: absolute position of q[0] for causal masking (prefill
    continuation); q position i attends to k positions <= q_offset + i.
    """
    B, T, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    scale = (D ** -0.5) if scale is None else scale
    # precision follows the compute dtype: bf16 prob tiles only for bf16
    # models (fp32 smoke/reference paths stay bit-faithful to the oracle)
    p_bf16 = p_bf16 and q.dtype == jnp.bfloat16
    qc = _pick_chunk(T, q_chunk)
    kc = _pick_chunk(S, k_chunk)
    nq, nk = T // qc, S // kc

    # (B, T, KH, G, D) grouped view
    qg = q.reshape(B, nq, qc, KH, G, D).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    q_pos_base = jnp.arange(qc)
    k_pos_base = jnp.arange(kc)

    def q_chunk_body(_, i):
        qi = qg[:, i]  # (B, qc, KH, G, D)
        q_pos = q_offset + i * qc + q_pos_base  # (qc,)

        def kv_body(carry, j):
            m, l, acc = carry
            kj = lax.dynamic_slice_in_dim(kf, j * kc, kc, axis=1)
            vj = lax.dynamic_slice_in_dim(vf, j * kc, kc, axis=1)
            # scores: (B, KH, G, qc, kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj)
            if causal:
                k_pos = j * kc + k_pos_base
                mask = q_pos[:, None] >= k_pos[None, :]  # (qc, kc)
                s = jnp.where(mask[None, None, None], s, BIG_NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(s <= BIG_NEG / 2, 0.0, p)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            # bf16 probability tiles (fp32 softmax stats + accumulator):
            # halves the dominant HBM term of the XLA attention path
            # (§Perf hillclimb #3); the Pallas kernel keeps tiles in VMEM.
            pv = p.astype(jnp.bfloat16) if p_bf16 else p
            vv = vj.astype(pv.dtype)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", pv, vv,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KH, G, qc), BIG_NEG, jnp.float32),
                jnp.zeros((B, KH, G, qc), jnp.float32),
                jnp.zeros((B, KH, G, qc, Dv), jnp.float32))
        (m, l, acc), _ = lax.scan(kv_body, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]  # (B,KH,G,qc,Dv)
        return None, out.transpose(0, 3, 1, 2, 4)      # (B,qc,KH,G,Dv)

    _, outs = lax.scan(q_chunk_body, None, jnp.arange(nq))
    # outs: (nq, B, qc, KH, G, Dv) -> (B, T, H, Dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, H, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token vs a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, scale: float | None = None) -> jax.Array:
    """q: (B, H, D); caches: (B, S, KH, D[v]); pos: scalar current length-1.

    Attends over cache slots <= pos (the new token's K/V must already be
    written at index ``pos``). Memory: (B, H, S) scores — linear in context.
    """
    B, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = (D ** -0.5) if scale is None else scale
    # NO cache.astype(f32): that materialises a full fp32 cache copy
    # (llama3-405b decode_32k measured 160 GiB/device before this; the
    # einsums accumulate in fp32 via preferred_element_type instead)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(valid, s, BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


def mla_decode_attention(q_nope: jax.Array, q_rope: jax.Array,
                         ckv_cache: jax.Array, krope_cache: jax.Array,
                         w_kb_k: jax.Array, w_kb_v: jax.Array,
                         pos: jax.Array, *, scale: float) -> jax.Array:
    """Absorbed MLA decode (DeepSeek-V2/V3).

    q_nope: (B, H, Dn); q_rope: (B, H, Dr); ckv_cache: (B, S, R);
    krope_cache: (B, S, Dr); w_kb_k: (H, R, Dn) latent->k_nope per head;
    w_kb_v: (H, R, Dv) latent->v per head. Attention runs in the compressed
    latent space: scores and values touch only the (B, S, R) cache — the
    memory-bandwidth win that motivates MLA.
    """
    B, H, Dn = q_nope.shape
    S = ckv_cache.shape[1]
    # absorb W^UK into q: (B, H, R); caches stay in storage dtype (no fp32
    # materialisation — see decode_attention note)
    q_lat = jnp.einsum("bhd,hrd->bhr", q_nope, w_kb_k,
                       preferred_element_type=jnp.float32)
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(ckv_cache.dtype), ckv_cache,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope, krope_cache,
                       preferred_element_type=jnp.float32)
    s = s * scale
    valid = jnp.arange(S)[None, None, :] <= pos
    s = jnp.where(valid, s, BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_cache.dtype), ckv_cache,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bhr,hrd->bhd", o_lat.astype(w_kb_v.dtype), w_kb_v,
                     preferred_element_type=jnp.float32)
    return out.astype(q_nope.dtype)
