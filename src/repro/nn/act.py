"""Rational gate activations — the IALS hot-loop's transcendental diet.

Profiling the fused rollout engine on CPU showed the GRU gate
nonlinearities, not the matmuls, dominating the AIP step (~70% of the
per-timestep cost): ``tanh``/``logistic`` lower to expensive transcendental
expansions, and the AIP evaluates ~``3 * H`` of them per lane per tick.
These rational approximations (the degree-7 Lambert continued fraction for
tanh, sigmoid via the tanh half-angle identity) are mul/add-only, vectorize
on any backend, and run inside Pallas kernel bodies unchanged.

Accuracy: |tanh_err| < 1e-4, |sigmoid_err| < 5e-5 over the whole real
line, and both stay exactly inside [-1, 1] / [0, 1] saturation. They are
used *consistently* — AIP training, the XLA rollout path, the Pallas
kernels, and the ``ref.py`` oracles all share these definitions — so the
simulator rolls out exactly the model that was trained, and
kernel-vs-oracle parity is exact rather than approximate.
"""
from __future__ import annotations

import jax.numpy as jnp

# the rational crosses 1 exactly here; clamping at the crossing makes the
# approximation saturate to exactly +-1 (worst-case |err| ~= 9.6e-5)
_CLAMP = 4.97178686


def fast_tanh(x: jnp.ndarray) -> jnp.ndarray:
    """Degree-7/6 rational tanh (Lambert's continued fraction), clamped."""
    x = jnp.clip(x, -_CLAMP, _CLAMP)
    x2 = x * x
    num = x * (135135.0 + x2 * (17325.0 + x2 * (378.0 + x2)))
    den = 135135.0 + x2 * (62370.0 + x2 * (3150.0 + x2 * 28.0))
    return num / den


def fast_sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    """sigmoid(x) = (tanh(x/2) + 1) / 2 on the rational tanh."""
    return 0.5 * (fast_tanh(0.5 * x) + 1.0)


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 counter-based random bits -> f32 uniforms on [0, 1).

    Uses the top 24 bits so every value is exactly representable in f32;
    ``uniform_from_bits(bits) < p`` is an unbiased Bernoulli(p) draw up to
    2^-24 probability quantisation. This is the shared threshold-compare
    convention of the fused AIP step (kernel and oracle alike).
    """
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
