"""State-space / recurrent blocks: Mamba (S6), xLSTM's mLSTM and sLSTM.

Design for TPU + scan-over-layers:
- Mamba uses a chunked associative scan: sequential over T/chunk chunks
  (carrying the (B, dI, dS) state), parallel ``lax.associative_scan`` inside a
  chunk — bounds live memory to (B, chunk, dI, dS) while keeping MXU-friendly
  einsums.
- mLSTM/sLSTM use scan-of-scans: outer scan over chunks saves only
  chunk-boundary states for BPTT; the inner per-step scan is wrapped in
  ``jax.checkpoint`` so intermediates are recomputed in the backward pass.
- All recurrent state is fp32 regardless of activation dtype (stability),
  with exp-gate max-stabilisers (the xLSTM m-state).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .module import dense_init

Params = Dict[str, Any]


def _chunk(n: int, want: int) -> int:
    """Largest divisor of n that is <= want."""
    if n <= want:
        return n
    k = -(-n // want)
    while n % k:
        k += 1
    return n // k


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (shared by mamba / mLSTM)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (C, K); b: (C,). Causal depthwise convolution."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4); unrolled taps beat a conv op on TPU
        out = out + xp[:, k:k + x.shape[1], :] * w[:, k]
    return out + b


def conv_step(x_window: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x_window: (B, K, C) most-recent-last -> (B, C)."""
    return jnp.einsum("bkc,ck->bc", x_window, w) + b


# ---------------------------------------------------------------------------
# Mamba (S6)
# ---------------------------------------------------------------------------

def mamba_init(key, d_model: int, *, expand: int = 2, d_state: int = 16,
               d_conv: int = 4, dt_rank: int | None = None,
               dtype=jnp.float32) -> Params:
    dI = expand * d_model
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[4], (dI,),
                                   minval=math.log(1e-3), maxval=math.log(1e-1)))))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * dI, dtype=dtype)["w"],
        "conv_w": (jax.random.normal(ks[1], (dI, d_conv)) * (d_conv ** -0.5)
                   ).astype(dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "x_proj": dense_init(ks[2], dI, dt_rank + 2 * d_state, dtype=dtype)["w"],
        "dt_w": dense_init(ks[3], dt_rank, dI, dtype=dtype)["w"],
        "dt_b": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (dI, d_state)).copy()),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[5], dI, d_model, dtype=dtype)["w"],
    }


def _ssm_combine(a, b):
    (a1, u1), (a2, u2) = a, b
    return a1 * a2, a2 * u1 + u2


def mamba_apply(p: Params, x: jax.Array, *, d_state: int = 16,
                chunk: int = 128, return_state: bool = False):
    """x: (B, T, d_model) -> (B, T, d_model). Full-sequence (train/prefill)."""
    B, T, _ = x.shape
    dI = p["conv_w"].shape[0]
    dt_rank = p["dt_w"].shape[0]
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))

    dbc = xc @ p["x_proj"]
    dt_in = dbc[..., :dt_rank]
    B_ = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                      # (dI, dS)
    xc32 = xc.astype(jnp.float32)

    ck = _chunk(T, chunk)
    nc = T // ck

    def chunk_body(h, idx):
        sl = lambda a: lax.dynamic_slice_in_dim(a, idx * ck, ck, axis=1)
        dt_c, B_c, C_c, x_c = sl(dt), sl(B_), sl(C_), sl(xc32)
        decay = jnp.exp(dt_c[..., None] * A)                    # (B,ck,dI,dS)
        u = (dt_c * x_c)[..., None] * B_c[:, :, None, :]        # (B,ck,dI,dS)
        a_cum, u_cum = lax.associative_scan(_ssm_combine, (decay, u), axis=1)
        hs = a_cum * h[:, None] + u_cum                         # (B,ck,dI,dS)
        y = jnp.einsum("btds,bts->btd", hs, C_c)
        return hs[:, -1], y

    h0 = jnp.zeros((B, dI, d_state), jnp.float32)
    h_last, ys = lax.scan(chunk_body, h0, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, dI)
    y = y + p["D"] * xc32
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    if return_state:
        K = p["conv_w"].shape[-1]
        win = jnp.pad(xi, ((0, 0), (max(K - T, 0), 0), (0, 0)))[:, -K:]
        return out, MambaState(conv=win, h=h_last)
    return out


class MambaState(NamedTuple):
    conv: jax.Array  # (B, K, dI) rolling window of pre-conv inputs
    h: jax.Array     # (B, dI, dS)


def mamba_init_state(batch: int, dI: int, d_conv: int, d_state: int,
                     dtype=jnp.float32) -> MambaState:
    return MambaState(conv=jnp.zeros((batch, d_conv, dI), dtype),
                      h=jnp.zeros((batch, dI, d_state), jnp.float32))


def mamba_step(p: Params, state: MambaState, x: jax.Array,
               *, d_state: int = 16) -> tuple:
    """Single decode step. x: (B, d_model) -> (out (B, d_model), state)."""
    dt_rank = p["dt_w"].shape[0]
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv = jnp.concatenate([state.conv[:, 1:], xi[:, None]], axis=1)
    xc = jax.nn.silu(conv_step(conv, p["conv_w"], p["conv_b"]))
    dbc = xc @ p["x_proj"]
    dt_in = dbc[..., :dt_rank]
    B_ = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    xc32 = xc.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * A)                          # (B,dI,dS)
    u = (dt * xc32)[..., None] * B_[:, None, :]
    h = decay * state.h + u
    y = jnp.einsum("bds,bs->bd", h, C_) + p["D"] * xc32
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, MambaState(conv=conv, h=h)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int, *, proj_factor: float = 2.0,
               d_conv: int = 4, dtype=jnp.float32) -> Params:
    dI = int(proj_factor * d_model)
    assert dI % n_heads == 0
    DH = dI // n_heads
    ks = jax.random.split(key, 8)

    def bd(k):  # block-diagonal per-head projection (xLSTM qkv_proj_blocksize)
        return (jax.random.normal(k, (n_heads, DH, DH)) * (DH ** -0.5)
                ).astype(dtype)

    return {
        "up_proj": dense_init(ks[0], d_model, 2 * dI, dtype=dtype)["w"],
        "conv_w": (jax.random.normal(ks[1], (dI, d_conv)) * (d_conv ** -0.5)
                   ).astype(dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "wq": bd(ks[2]), "wk": bd(ks[3]), "wv": bd(ks[4]),
        "w_if": dense_init(ks[5], dI, 2 * n_heads, dtype=jnp.float32,
                           bias=True),
        "out_norm_g": jnp.ones((dI,), dtype),
        "down_proj": dense_init(ks[6], dI, d_model, dtype=dtype)["w"],
    }


def _bd_proj(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., dI); w: (NH, DH, DH) block-diagonal -> (..., NH, DH)."""
    nh, dh = w.shape[0], w.shape[1]
    xr = x.reshape(*x.shape[:-1], nh, dh)
    return jnp.einsum("...hd,hde->...he", xr, w)


class MLSTMState(NamedTuple):
    conv: jax.Array  # (B, K, dI)
    C: jax.Array     # (B, NH, DH, DH)
    n: jax.Array     # (B, NH, DH)
    m: jax.Array     # (B, NH)


def mlstm_init_state(batch: int, dI: int, n_heads: int, d_conv: int,
                     dtype=jnp.float32) -> MLSTMState:
    DH = dI // n_heads
    return MLSTMState(conv=jnp.zeros((batch, d_conv, dI), dtype),
                      C=jnp.zeros((batch, n_heads, DH, DH), jnp.float32),
                      n=jnp.zeros((batch, n_heads, DH), jnp.float32),
                      m=jnp.full((batch, n_heads), -1e30, jnp.float32))


def _mlstm_cell(qkvif, state: MLSTMState):
    """One recurrent step. q,k,v: (B,NH,DH); i_raw,f_raw: (B,NH)."""
    q, k, v, i_raw, f_raw = qkvif
    DH = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    k_s = k / math.sqrt(DH)
    C = f_g[..., None, None] * state.C + i_g[..., None, None] * (
        v[..., :, None] * k_s[..., None, :])
    n = f_g[..., None] * state.n + i_g[..., None] * k_s
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return h, MLSTMState(conv=state.conv, C=C, n=n, m=m_new)


def _mlstm_chunk_parallel(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Chunkwise-parallel mLSTM (§Perf hillclimb #1).

    Inputs are ONE chunk: q,k,v (L, B, NH, DH); i_raw,f_raw (L, B, NH).
    The recurrent form reads+writes the (B, NH, DH, DH) matrix memory every
    timestep (measured 2281 s HBM roofline term on xlstm-1.3b train_4k);
    this form touches C once per chunk:
      intra-chunk: attention-like (L, L) gate-weighted scores,
      inter-chunk: one rank-L update  C' = decay*C + (gated k)^T v,
    with the xLSTM max-stabiliser handled exactly (verified to ~1e-6 against
    the recurrent cell in tests/test_ssm_chunkwise.py).
    """
    L, B, NH, DH = q.shape
    logf = jax.nn.log_sigmoid(f_raw)                        # (L, B, NH)
    b = jnp.cumsum(logf, axis=0)                            # b_t = sum logf
    b_total = b[-1]                                         # (B, NH)

    # log-weights: intra w(t,tau) = b_t - b_tau + i_tau (tau <= t)
    #              inter w(t)     = b_t + m_prev
    log_intra = b[:, None] - b[None, :] + i_raw[None, :]    # (t, tau, B, NH)
    tril = jnp.tril(jnp.ones((L, L), bool))[:, :, None, None]
    log_intra = jnp.where(tril, log_intra, -jnp.inf)
    m_intra = jnp.max(log_intra, axis=1)                    # (t, B, NH)
    log_inter = b + state.m[None]                           # (t, B, NH)
    m_t = jnp.maximum(m_intra, log_inter)                   # running max

    k_s = k / math.sqrt(DH)
    s_qk = jnp.einsum("tbhd,ubhd->tubh", q, k_s)            # (t, tau, B, NH)
    w_intra = jnp.where(tril, jnp.exp(log_intra - m_t[:, None]), 0.0)
    h_intra = jnp.einsum("tubh,ubhd->tbhd", w_intra * s_qk, v)
    n_intra = jnp.einsum("tubh,ubhd->tbhd", w_intra, k_s)

    w_inter = jnp.exp(log_inter - m_t)                      # (t, B, NH)
    h_inter = jnp.einsum("tbhj,bhij->tbhi", q, state.C) * w_inter[..., None]
    n_inter = state.n[None] * w_inter[..., None]
    qn = jnp.einsum("tbhd,tbhd->tbh", q, n_intra + n_inter)
    den = jnp.maximum(jnp.abs(qn), 1.0)
    h = (h_intra + h_inter) / den[..., None]                # (t, B, NH, DH)

    # chunk-end state (== the recurrence unrolled L steps)
    m_state = jnp.maximum(b_total + state.m,
                          jnp.max(b_total[None] - b + i_raw, axis=0))
    w_c = jnp.exp(b_total[None] - b + i_raw - m_state[None])  # (tau, B, NH)
    decay = jnp.exp(b_total + state.m - m_state)
    C_new = decay[..., None, None] * state.C + \
        jnp.einsum("tbh,tbhi,tbhj->bhij", w_c, v, k_s)
    n_new = decay[..., None] * state.n + \
        jnp.einsum("tbh,tbhd->bhd", w_c, k_s)
    new_state = MLSTMState(conv=state.conv, C=C_new, n=n_new, m=m_state)
    return h, new_state


def mlstm_apply(p: Params, x: jax.Array, n_heads: int, *,
                chunk: int = 64, return_state: bool = False,
                chunkwise: bool = True):
    """x: (B, T, d_model). Chunkwise-parallel by default (§Perf hillclimb);
    ``chunkwise=False`` falls back to the per-step recurrent scan."""
    B, T, _ = x.shape
    dI = p["conv_w"].shape[0]
    DH = dI // n_heads
    xz = x @ p["up_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))
    q = _bd_proj(xc, p["wq"]).astype(jnp.float32)
    k = _bd_proj(xc, p["wk"]).astype(jnp.float32)
    v = _bd_proj(xi, p["wv"]).astype(jnp.float32)
    if_raw = (xc.astype(jnp.float32) @ p["w_if"]["w"] + p["w_if"]["b"])
    i_raw, f_raw = jnp.split(if_raw.reshape(B, T, 2, n_heads), 2, axis=2)
    i_raw, f_raw = i_raw[:, :, 0], f_raw[:, :, 0]        # (B, T, NH)

    ck = _chunk(T, chunk)
    nc = T // ck

    if chunkwise:
        @jax.checkpoint
        def chunk_body(carry, inputs):
            h, st = _mlstm_chunk_parallel(*inputs, carry)
            return st, h
    else:
        @jax.checkpoint
        def chunk_body(carry, inputs):
            def step(st, inp):
                h, st2 = _mlstm_cell(inp, st)
                return st2, h
            st, hs = lax.scan(step, carry, inputs)  # hs: (ck, B, NH, DH)
            return st, hs

    def outer(carry, idx):
        sl = lambda a: jnp.moveaxis(
            lax.dynamic_slice_in_dim(a, idx * ck, ck, axis=1), 1, 0)
        st, hs = chunk_body(carry, (sl(q), sl(k), sl(v), sl(i_raw), sl(f_raw)))
        return st, hs

    st0 = MLSTMState(conv=jnp.zeros((B, 1, dI), x.dtype),
                     C=jnp.zeros((B, n_heads, DH, DH), jnp.float32),
                     n=jnp.zeros((B, n_heads, DH), jnp.float32),
                     m=jnp.full((B, n_heads), -1e30, jnp.float32))
    st_last, hss = lax.scan(outer, st0, jnp.arange(nc))  # (nc, ck, B, NH, DH)
    h = hss.reshape(T, B, dI).transpose(1, 0, 2).astype(x.dtype)
    h = _groupnorm_heads(h, p["out_norm_g"], n_heads)
    out = (h * jax.nn.silu(z)) @ p["down_proj"]
    if return_state:
        K = p["conv_w"].shape[-1]
        win = jnp.pad(xi, ((0, 0), (max(K - T, 0), 0), (0, 0)))[:, -K:]
        return out, MLSTMState(conv=win, C=st_last.C, n=st_last.n, m=st_last.m)
    return out


def _groupnorm_heads(h: jax.Array, g: jax.Array, n_heads: int) -> jax.Array:
    """Per-head RMS norm over the head dim (xLSTM uses GroupNorm)."""
    shp = h.shape
    hh = h.reshape(*shp[:-1], n_heads, shp[-1] // n_heads).astype(jnp.float32)
    var = jnp.mean(hh * hh, axis=-1, keepdims=True)
    hh = hh * jax.lax.rsqrt(var + 1e-6)
    return (hh.reshape(shp) * g).astype(h.dtype)


def mlstm_step(p: Params, state: MLSTMState, x: jax.Array,
               n_heads: int) -> tuple:
    """Single decode step. x: (B, d_model)."""
    B = x.shape[0]
    dI = p["conv_w"].shape[0]
    DH = dI // n_heads
    xz = x @ p["up_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv = jnp.concatenate([state.conv[:, 1:], xi[:, None]], axis=1)
    xc = jax.nn.silu(conv_step(conv, p["conv_w"], p["conv_b"]))
    q = _bd_proj(xc, p["wq"]).astype(jnp.float32)
    k = _bd_proj(xc, p["wk"]).astype(jnp.float32)
    v = _bd_proj(xi, p["wv"]).astype(jnp.float32)
    if_raw = xc.astype(jnp.float32) @ p["w_if"]["w"] + p["w_if"]["b"]
    i_raw, f_raw = jnp.split(if_raw.reshape(B, 2, n_heads), 2, axis=1)
    h, st = _mlstm_cell((q, k, v, i_raw[:, 0], f_raw[:, 0]),
                        MLSTMState(conv=conv, C=state.C, n=state.n, m=state.m))
    hf = h.reshape(B, dI).astype(x.dtype)
    hf = _groupnorm_heads(hf, p["out_norm_g"], n_heads)
    return (hf * jax.nn.silu(z)) @ p["down_proj"], st


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with recurrent head-block-diagonal weights)
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, n_heads: int, *, ff_factor: float = 4 / 3,
               dtype=jnp.float32) -> Params:
    assert d_model % n_heads == 0
    DH = d_model // n_heads
    ks = jax.random.split(key, 8)
    d_ff = int(ff_factor * d_model)
    def rmat(k):
        return (jax.random.normal(k, (n_heads, DH, DH)) * (DH ** -0.5)
                ).astype(jnp.float32)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype=dtype,
                           bias=True),
        "r_z": rmat(ks[1]), "r_i": rmat(ks[2]),
        "r_f": rmat(ks[3]), "r_o": rmat(ks[4]),
        "out_norm_g": jnp.ones((d_model,), dtype),
        "ff_up": dense_init(ks[5], d_model, 2 * d_ff, dtype=dtype)["w"],
        "ff_down": dense_init(ks[6], d_ff, d_model, dtype=dtype)["w"],
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, NH, DH)
    n: jax.Array
    h: jax.Array
    m: jax.Array  # (B, NH, DH)


def slstm_init_state(batch: int, n_heads: int, DH: int) -> SLSTMState:
    z = jnp.zeros((batch, n_heads, DH), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def _fused_r(p: Params) -> jax.Array:
    """Fused recurrent weights (NH, 4*DH, DH) — built ONCE outside the
    per-timestep scan (a per-step concat measured +23 s on the HBM roofline
    term before being hoisted here)."""
    return jnp.concatenate([p["r_z"], p["r_i"], p["r_f"], p["r_o"]], axis=1)


def _slstm_cell(r_all: jax.Array, state: SLSTMState, wx: jax.Array) -> tuple:
    """wx: (B, 4, NH, DH) precomputed input projections [z, i, f, o];
    r_all: fused recurrent weights from ``_fused_r``."""
    # single fused recurrent matmul (4 gates at once): one MXU op per step
    rg = jnp.einsum("bhj,hij->bhi", state.h, r_all)
    rz, ri, rf, ro = jnp.split(rg, 4, axis=-1)
    z_t = jnp.tanh(wx[:, 0] + rz)
    i_raw = wx[:, 1] + ri
    f_raw = wx[:, 2] + rf
    o_t = jax.nn.sigmoid(wx[:, 3] + ro)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    c = f_g * state.c + i_g * z_t
    n = f_g * state.n + i_g
    h = o_t * c / jnp.maximum(n, 1e-6)
    return h, SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_apply(p: Params, x: jax.Array, n_heads: int, *,
                chunk: int = 64, return_state: bool = False):
    """x: (B, T, d_model)."""
    B, T, d = x.shape
    DH = d // n_heads
    wx = (x @ p["w_in"]["w"] + p["w_in"]["b"]).astype(jnp.float32)
    wx = wx.reshape(B, T, 4, n_heads, DH)

    ck = _chunk(T, chunk)
    nc = T // ck
    r_all = _fused_r(p)

    @jax.checkpoint
    def chunk_body(carry, inputs):
        def step(st, inp):
            h, st2 = _slstm_cell(r_all, st, inp)
            return st2, h
        return lax.scan(step, carry, inputs)

    def outer(carry, idx):
        inp = jnp.moveaxis(
            lax.dynamic_slice_in_dim(wx, idx * ck, ck, axis=1), 1, 0)
        return chunk_body(carry, inp)

    st_last, hs = lax.scan(outer, slstm_init_state(B, n_heads, DH),
                           jnp.arange(nc))
    h = hs.reshape(T, B, d).transpose(1, 0, 2).astype(x.dtype)
    h = _groupnorm_heads(h, p["out_norm_g"], n_heads)
    up = h @ p["ff_up"]
    u1, u2 = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(u1) * u2) @ p["ff_down"]
    if return_state:
        return out, st_last
    return out


def slstm_step(p: Params, state: SLSTMState, x: jax.Array,
               n_heads: int) -> tuple:
    B, d = x.shape
    DH = d // n_heads
    wx = (x @ p["w_in"]["w"] + p["w_in"]["b"]).astype(jnp.float32)
    h, st = _slstm_cell(_fused_r(p), state, wx.reshape(B, 4, n_heads, DH))
    hf = h.reshape(B, d).astype(x.dtype)
    hf = _groupnorm_heads(hf, p["out_norm_g"], n_heads)
    up = hf @ p["ff_up"]
    u1, u2 = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(u1) * u2) @ p["ff_down"], st
