"""Mixture-of-Experts: top-k routing with capacity-based sort/scatter dispatch.

TPU adaptation notes (vs GPU megablocks-style ragged kernels): we use the
GShard/Switch capacity formulation — tokens are ranked within their expert via
an argsort, scattered into a dense (E, C, d) buffer, processed with a batched
einsum over the expert axis (sharded on the ``model`` mesh axis => expert
parallelism; the scatter/gather lowers to all-to-all under SPMD), and combined
back with the router weights. No data-dependent shapes, fully jit-able.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.act_sharding import constrain

from .module import dense_init, ACTIVATIONS

Params = Dict[str, Any]


def gated_mlp_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype=dtype)["w"],
        "w_in": dense_init(k2, d_model, d_ff, dtype=dtype)["w"],
        "w_out": dense_init(k3, d_ff, d_model, dtype=dtype)["w"],
    }


def gated_mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    a = ACTIVATIONS[act]
    return (a(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]


def mlp_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype=dtype)["w"],
        "w_out": dense_init(k2, d_ff, d_model, dtype=dtype)["w"],
    }


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    return ACTIVATIONS[act](x @ p["w_in"]) @ p["w_out"]


def moe_init(key, d_model: int, d_expert: int, n_routed: int,
             n_shared: int, *, dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    kg, ki, ko = jax.random.split(ke, 3)
    p: Params = {
        "router": dense_init(kr, d_model, n_routed, dtype=jnp.float32)["w"],
        "experts": {
            "w_gate": (jax.random.normal(kg, (n_routed, d_model, d_expert))
                       * (d_model ** -0.5)).astype(dtype),
            "w_in": (jax.random.normal(ki, (n_routed, d_model, d_expert))
                     * (d_model ** -0.5)).astype(dtype),
            "w_out": (jax.random.normal(ko, (n_routed, d_expert, d_model))
                      * (d_expert ** -0.5)).astype(dtype),
        },
    }
    if n_shared > 0:
        p["shared"] = gated_mlp_init(ks, d_model, d_expert * n_shared,
                                     dtype=dtype)
    return p


def moe_apply(p: Params, x: jax.Array, *, top_k: int, act: str = "silu",
              capacity_factor: float = 1.25,
              router_noise: jax.Array | None = None) -> tuple:
    """x: (B, T, d) -> (out (B, T, d), aux dict with load-balance/z losses)."""
    B, T, d = x.shape
    E = p["router"].shape[-1]
    tokens = x.reshape(-1, d)
    N = tokens.shape[0]

    logits = (tokens.astype(jnp.float32) @ p["router"])  # (N, E)
    if router_noise is not None:
        logits = logits + router_noise
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, top_k)               # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                           # (N*k,)
    flat_w = top_p.reshape(-1)
    tok_idx = jnp.broadcast_to(jnp.arange(N)[:, None], (N, top_k)).reshape(-1)

    C = max(1, math.ceil(N * top_k / E * capacity_factor))
    C = min(C, N)  # no point exceeding token count

    # rank of each (token, expert) assignment within its expert, via argsort
    sort_idx = jnp.argsort(flat_e)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)              # (E,)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(N * top_k) - starts[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[sort_idx].set(rank_sorted)
    keep = rank < C

    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, 0)
    vals = constrain(tokens[tok_idx] * keep[:, None].astype(tokens.dtype),
                     "dp", None)
    # expert-major layout: ONE explicit reshard (all-to-all) here instead of
    # GSPMD inventing per-matmul all-reduces downstream
    buf = constrain(
        jnp.zeros((E, C, d), tokens.dtype).at[safe_e, safe_r].add(vals),
        "tp", None, None)

    # expert computation, batched over E (expert-parallel on the model axis)
    a = ACTIVATIONS[act]
    h = (a(jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_in"]))
    y = constrain(jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_out"]),
                  "tp", None, None)                       # (E, C, d)

    out_flat = constrain(y[safe_e, safe_r], "dp", None) * \
        (keep.astype(y.dtype) * flat_w.astype(y.dtype))[:, None]
    out = out_flat.reshape(N, top_k, d).sum(axis=1)

    if "shared" in p:
        out = out + gated_mlp(p["shared"], tokens, act)

    # aux losses: Switch load-balance + router z-loss
    me = probs.mean(axis=0)                              # (E,)
    ce = jnp.bincount(flat_e, weights=keep.astype(jnp.float32),
                      length=E) / max(N * top_k, 1)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - keep.astype(jnp.float32).mean()
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": dropped}
    return out.reshape(B, T, d), aux
