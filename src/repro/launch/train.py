"""LM training driver: config system + launcher wiring all substrates.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Wires: TokenPipeline (host-sharded data) -> train_step (grad-accumulated,
remat, sharded when >1 device) -> AdamW -> TrainingGuard (atomic checkpoints,
auto-resume, SIGTERM-safe) -> StragglerDetector. On a real cluster the same
driver runs per-host under ``jax.distributed.initialize`` with the
production mesh from launch/mesh.py; in this container it runs the reduced
configs end-to-end on CPU.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.fault_tolerance import TrainingGuard, StragglerDetector
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim.adamw import adamw, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)

    data = TokenPipeline(DataConfig(seq_len=args.seq,
                                    global_batch=args.batch,
                                    vocab_size=cfg.vocab_size,
                                    seed=args.seed))
    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, args.microbatches),
                      donate_argnums=(0, 1))

    def init_state():
        params = lm.init_params(cfg, key)
        return {"params": params, "opt": opt.init(params)}

    guard = None
    start_step = 0
    if args.ckpt_dir:
        guard = TrainingGuard(args.ckpt_dir, save_every=args.save_every)
        state, start_step = guard.resume_or(init_state)
        if start_step:
            print(f"resumed from step {start_step}")
    else:
        state = init_state()

    detector = StragglerDetector()
    history = []
    extra = {}
    if cfg.family == "vlm":
        extra["vision"] = jnp.zeros((args.batch, cfg.n_vision_tokens,
                                     cfg.d_model), cfg.dtype())
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros((args.batch, cfg.n_audio_frames,
                                     cfg.d_model), cfg.dtype())

    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(step).items()}
        batch.update(extra)
        t0 = time.time()
        state["params"], state["opt"], metrics = step_fn(
            state["params"], state["opt"], batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if detector.update(step, dt):
            print(f"[straggler] sustained slow steps at {step} "
                  f"(would trigger elastic restart on a cluster)")
        if step % args.log_every == 0 or step == args.steps - 1:
            row = {"step": step, "loss": float(metrics["loss"]),
                   "ce": float(metrics["ce"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "step_time_s": round(dt, 4)}
            history.append(row)
            print(json.dumps(row))
        if guard is not None:
            # read the flag BEFORE maybe_save: a successful forced save
            # clears it (the guard answers the signal once, not forever)
            preempted = guard.preempted
            saved = guard.maybe_save(step + 1, state)
            if preempted and saved:
                print("preempted: checkpoint flushed, exiting cleanly")
                return history

    if guard is not None:
        guard.maybe_save(args.steps, state, force=True)
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(history, indent=1))
    return history


if __name__ == "__main__":
    main()
