"""Batched serving driver: prefill + decode with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-moe-16b \
        --reduced --batch 4 --prompt-len 24 --gen 32

Static-batch serving (the dry-run's ``serve_step`` contract): one prefill
fills the cache, then greedy/temperature decode steps. On a pod the same
functions lower under the production mesh with sequence-parallel caches
(distributed/sharding.cache_specs); this driver exercises the identical
code path at CPU scale and reports tokens/s.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.launch import steps as steps_lib
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    inputs = {"tokens": prompt}
    if cfg.family == "vlm":
        inputs["vision"] = jnp.zeros(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype())
    if cfg.family == "encdec":
        inputs["frames"] = jnp.zeros(
            (args.batch, cfg.n_audio_frames, cfg.d_model), cfg.dtype())

    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len))
    serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, inputs)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(k, lg):
        if args.temperature <= 0:
            return jnp.argmax(lg, -1)
        return jax.random.categorical(k, lg / args.temperature)

    tok = sample(key, logits)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen):
        key, k = jax.random.split(key)
        logits, cache = serve(params, cache, tok,
                              jnp.int32(args.prompt_len + i))
        tok = sample(k, logits)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.stack(out, 1)
    stats = {
        "arch": cfg.name, "batch": args.batch,
        "prefill_s": round(t_prefill, 3),
        "decode_tokens_per_s": round(args.batch * args.gen
                                     / max(t_decode, 1e-9), 1),
        "generated_shape": list(gen.shape),
    }
    print(json.dumps(stats))
    return gen, stats


if __name__ == "__main__":
    main()
