"""Serve a trained policy against synthetic open-loop traffic.

    PYTHONPATH=src python -m repro.launch.policy_serve --domain traffic \
        --regions 256 --rps 20000 --duration-s 2 --slot 128
    PYTHONPATH=src python -m repro.launch.policy_serve --domain warehouse \
        --ckpt-dir ckpts/wh --slot 64 --out serve.json

The deployment half of the training story: thousands of heterogeneous
agent regions (ragged grid sizes, staggered episode phases —
``serving/request.py``'s trace model) stream action requests at a fixed
offered load; ``serving/scheduler.py::SlotScheduler`` packs them into
fixed-shape slots earliest-deadline-first, and
``serving/server.py::PolicyServer`` drives each slot through ONE jitted
masked policy forward (``kernels/ops.py::serve_forward``). The replay
reports p50/p99 request latency (arrival -> slot completion, wall
clock, queueing included) and sustained QPS — the serving contract and
measurement method are docs/ARCHITECTURE.md §8.

``--ckpt-dir`` restores the policy from an ``rl_train`` checkpoint via
``checkpoint/ckpt.py::restore_subtree`` — only the ``['policy']``
leaves' bytes are read; the optimizer/rollout/simulator payload of the
training checkpoint never touches the inference process.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.launch.rl_train import build_domain
from repro.rl import ppo
from repro.serving import PolicyServer, TraceConfig, synthetic_trace


def build_server_and_trace(args):
    """-> (PolicyServer, trace, info dict) — the driver body, callable
    in-process (tests and the serve bench reuse it)."""
    gs, _, _, frame_stack = build_domain(args.domain)
    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim,
                         n_actions=gs.spec.n_actions,
                         frame_stack=frame_stack)
    info = {"domain": args.domain, "slot": args.slot, "route": args.route}
    template = ppo.init_policy(pcfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        params, step, meta = ckpt.restore_subtree(
            args.ckpt_dir, template, "['policy']", step=args.step)
        info["restored_step"] = step
        info["ckpt_metadata"] = meta
    else:
        params = template
    server = PolicyServer(params, obs_dim=pcfg.obs_dim,
                          n_actions=pcfg.n_actions,
                          frame_stack=frame_stack, slot=args.slot,
                          route=args.route)
    trace = synthetic_trace(TraceConfig(
        n_regions=args.regions, mean_rps=args.rps,
        horizon_s=args.duration_s, frame_dim=server.frame_dim,
        seed=args.seed))
    info["requests"] = len(trace)
    return server, trace, info


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--domain", choices=["traffic", "warehouse"],
                    default="traffic")
    ap.add_argument("--slot", type=int, default=128)
    ap.add_argument("--regions", type=int, default=256)
    ap.add_argument("--rps", type=float, default=20000.0)
    ap.add_argument("--duration-s", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--route", choices=["auto", "interpret", "xla"],
                    default="auto")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore the policy subtree from an rl_train "
                         "checkpoint (restore_subtree: no training-state "
                         "payload read)")
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    server, trace, info = build_server_and_trace(args)
    # compile the slot program before the clock starts — the first
    # dispatch of a jitted shape is a trace+compile, not a serve latency
    server.forward_slot(np.zeros((args.slot, server.frame_dim),
                                 np.float32), 1)
    report = server.serve(trace)
    out = {**info, **report.summary()}
    print(json.dumps(out, indent=1))
    if args.out:
        Path(args.out).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
