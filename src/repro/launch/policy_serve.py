"""Serve a trained policy against synthetic open-loop traffic.

    PYTHONPATH=src python -m repro.launch.policy_serve --domain traffic \
        --regions 256 --rps 20000 --duration-s 2 --slot 128
    PYTHONPATH=src python -m repro.launch.policy_serve --domain traffic \
        --bimodal --buckets 16,64,256          # multi-slot bucketed server
    PYTHONPATH=src python -m repro.launch.policy_serve --domain traffic \
        --bimodal --calibrate 3 --n-policies 4 # calibrated + cross-policy
    PYTHONPATH=src python -m repro.launch.policy_serve --domain warehouse \
        --ckpt-dir ckpts/wh --slot 64 --out serve.json

The deployment half of the training story: thousands of heterogeneous
agent regions (ragged grid sizes, staggered episode phases —
``serving/request.py``'s trace model; ``--bimodal`` switches the burst
sizes to the heavy-tailed bimodal mix) stream action requests at a
fixed offered load; ``serving/scheduler.py`` packs them into slots
earliest-deadline-first — one fixed shape (``--slot``), an explicit
bucket set (``--buckets 16,64,256``), or a set calibrated offline from
the trace itself (``--calibrate K``) — and
``serving/server.py::PolicyServer`` drives each slot through a table of
jitted masked policy forwards (``kernels/ops.py::serve_forward``; with
``--n-policies N`` a cross-policy family batched per lane through
``kernels/ops.py::serve_forward_multi``). The replay reports p50/p99
request latency (arrival -> slot completion, wall clock, queueing
included), sustained QPS, and the padded-lane waste observability
(``ServeStats``: padded_lane_frac + per-shape dispatch/occupancy
counters) — the serving contract and measurement method are
docs/ARCHITECTURE.md §8.

``--ckpt-dir`` restores the policy from an ``rl_train`` checkpoint via
``checkpoint/ckpt.py::restore_subtree`` — only the ``['policy']``
leaves' bytes are read; the optimizer/rollout/simulator payload of the
training checkpoint never touches the inference process. With
``--n-policies N`` the same restored tree seeds checkpoint 0 and the
remaining N-1 are fresh inits (stand-ins for per-region fine-tunes).

Overload + chaos controls (the overload contract, ARCHITECTURE §8):
``--admission`` puts an ``serving/overload.py::AdmissionController`` in
front of the scheduler (bounded queue ``--queue-cap``, deadline
feasibility, brownout shedding) — rejections are counted in the output,
never silent. ``--faults`` replays a deterministic serving fault plan
(``distributed/fault_injection.py::parse_serve_faults``), e.g.
``slow:10:0.05,flood:0.5:0.2:4,corrupt:0:nan`` — a slow dispatch, a
traffic flood, and a hot-reload attempt whose candidate weights are
poisoned (the reload gate must reject it and keep serving on the old
weights). ``--reload-at 100,200`` schedules hot self-reload attempts at
those dispatch indices (the seam corrupt events target).
``--virtual --service-time-s S`` replays on a deterministic virtual
clock — same decisions every run (the chaos-smoke CI path). After a
fault run the driver asserts the plan is exhausted: a fault that never
fired is a configuration bug, not a pass.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.checkpoint import ckpt
from repro.distributed.fault_injection import (FaultInjector,
                                               parse_serve_faults)
from repro.launch.rl_train import build_domain
from repro.rl import ppo
from repro.serving import (BIMODAL_SIZES, BIMODAL_WEIGHTS,
                           AdmissionController, OverloadConfig, PolicyServer,
                           TraceConfig, calibrate_buckets, synthetic_trace)


def build_server_and_trace(args):
    """-> (PolicyServer, trace, info dict) — the driver body, callable
    in-process (tests and the serve bench reuse it)."""
    gs, _, _, frame_stack = build_domain(args.domain)
    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim,
                         n_actions=gs.spec.n_actions,
                         frame_stack=frame_stack)
    n_policies = getattr(args, "n_policies", 1)
    template = ppo.init_policy(pcfg, jax.random.PRNGKey(args.seed))
    info = {"domain": args.domain, "route": args.route,
            "n_policies": n_policies}
    if args.ckpt_dir:
        params, step, meta = ckpt.restore_subtree(
            args.ckpt_dir, template, "['policy']", step=args.step)
        info["restored_step"] = step
        info["ckpt_metadata"] = meta
    else:
        params = template
    if n_policies > 1:
        params = [params] + [
            ppo.init_policy(pcfg, jax.random.PRNGKey(args.seed + 1 + n))
            for n in range(n_policies - 1)]

    tcfg = TraceConfig(n_regions=args.regions, mean_rps=args.rps,
                       horizon_s=args.duration_s,
                       frame_dim=gs.spec.obs_dim * frame_stack,
                       seed=args.seed, n_policies=n_policies)
    if getattr(args, "bimodal", False):
        tcfg = dataclasses.replace(tcfg, region_sizes=BIMODAL_SIZES,
                                   region_size_weights=BIMODAL_WEIGHTS)
    trace = synthetic_trace(tcfg)
    info["requests"] = len(trace)

    if getattr(args, "calibrate", None):
        slot = calibrate_buckets(trace, max_buckets=args.calibrate,
                                 max_slot=args.slot)
        info["calibrated"] = True
    elif getattr(args, "buckets", None):
        slot = tuple(int(s) for s in args.buckets.split(","))
    else:
        slot = args.slot
    info["slot"] = list(slot) if isinstance(slot, tuple) else slot

    server = PolicyServer(params, obs_dim=pcfg.obs_dim,
                          n_actions=pcfg.n_actions,
                          frame_stack=frame_stack, slot=slot,
                          route=args.route)
    return server, trace, info


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--domain", choices=["traffic", "warehouse"],
                    default="traffic")
    ap.add_argument("--slot", type=int, default=128,
                    help="single compiled slot shape (also the max_slot "
                         "cap for --calibrate)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated ascending slot shapes, e.g. "
                         "16,64,256 — the bucketed multi-slot server")
    ap.add_argument("--calibrate", type=int, default=None, metavar="K",
                    help="pick <= K bucket shapes offline from the "
                         "trace's burst-size distribution "
                         "(serving/scheduler.py::calibrate_buckets); "
                         "overrides --buckets/--slot")
    ap.add_argument("--n-policies", type=int, default=1,
                    help="cross-policy batching: serve N checkpoints "
                         "from one server, lane-routed by the request's "
                         "region-family index")
    ap.add_argument("--bimodal", action="store_true",
                    help="bimodal region burst sizes (the bucketed "
                         "scheduler's target workload)")
    ap.add_argument("--regions", type=int, default=256)
    ap.add_argument("--rps", type=float, default=20000.0)
    ap.add_argument("--duration-s", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--route", choices=["auto", "interpret", "xla"],
                    default="auto")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore the policy subtree from an rl_train "
                         "checkpoint (restore_subtree: no training-state "
                         "payload read)")
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--admission", action="store_true",
                    help="admission control in front of the scheduler: "
                         "bounded queue + deadline feasibility + brownout "
                         "(serving/overload.py::AdmissionController)")
    ap.add_argument("--queue-cap", type=int, default=8192,
                    help="bounded admission queue (pending requests)")
    ap.add_argument("--faults", default=None,
                    help="deterministic serving fault plan, e.g. "
                         "'slow:10:0.05,flood:0.5:0.2:4,corrupt:0:nan' "
                         "(fault_injection.py::parse_serve_faults)")
    ap.add_argument("--reload-at", default=None,
                    help="comma-separated dispatch indices at which to "
                         "attempt a hot self-reload (the seam corrupt "
                         "faults target)")
    ap.add_argument("--virtual", action="store_true",
                    help="deterministic virtual-clock replay: every "
                         "scheduler/admission/fault decision replays "
                         "exactly (the chaos-smoke path)")
    ap.add_argument("--service-time-s", type=float, default=1e-3,
                    help="per-dispatch service time of the virtual clock")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    server, trace, info = build_server_and_trace(args)
    admission = None
    if args.admission:
        admission = AdmissionController(OverloadConfig(
            queue_cap=args.queue_cap,
            default_latency_s=args.service_time_s))
    inj = None
    if args.faults:
        inj = FaultInjector(parse_serve_faults(args.faults))
        info["fault_plan"] = args.faults
    reload_at = (tuple(int(d) for d in args.reload_at.split(","))
                 if args.reload_at else ())
    # compile every slot program before the clock starts — the first
    # dispatch of a jitted shape is a trace+compile, not a serve latency
    server.warmup()
    report = server.serve(
        trace, mode="virtual" if args.virtual else "wallclock",
        service_time_s=args.service_time_s, admission=admission,
        faults=inj, reload_at=reload_at)
    out = {**info, **report.summary(),
           "policy_version": server.policy_version,
           "reload_log": [list(e) for e in server.reload_log]}
    if inj is not None:
        inj.assert_exhausted()   # a fault that never fired is a config bug
        out["faults_applied"] = inj.applied_counts()
    print(json.dumps(out, indent=1))
    if args.out:
        Path(args.out).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
