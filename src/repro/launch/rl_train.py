"""RL training driver — the paper's workflow end-to-end (its Fig. 3/5 runs).

    PYTHONPATH=src python -m repro.launch.rl_train --domain traffic \
        --simulator ials --iterations 60

Pipeline per the paper (§5.1):
  1. collect a (d_t, u_t) dataset from the GS under a random policy (Alg. 1)
  2. train the AIP offline (Eq. 3)
  3. train PPO on the chosen simulator: gs | ials | untrained-ials | f-ials
  4. periodically evaluate on the GS (the deployment environment)

Emits a JSON history of (iteration, wallclock, train reward, GS eval reward)
— the learning-curves benchmark reads this.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import collect, influence, ials as ials_lib
from repro.envs.traffic import (TrafficConfig, make_traffic_env,
                                make_local_traffic_env)
from repro.envs.warehouse import (WarehouseConfig, make_warehouse_env,
                                  make_local_warehouse_env)
from repro.rl import ppo


def build_domain(domain: str, vanish_after: int = 0):
    if domain == "traffic":
        cfg = TrafficConfig()
        return make_traffic_env(cfg), make_local_traffic_env(cfg), 1
    cfg = WarehouseConfig(vanish_after=vanish_after)
    return make_warehouse_env(cfg), make_local_warehouse_env(cfg), 8


def build_simulator(simulator: str, gs, ls, aip_kind: str, key, *,
                    collect_episodes: int, ep_len: int, aip_epochs: int,
                    fixed_marginal=None, aip_window: int = 0):
    """-> (env for PPO, aip diagnostics dict)."""
    diag = {}
    if simulator == "gs":
        return gs, diag
    acfg = influence.AIPConfig(
        kind=aip_kind, d_in=gs.spec.dset_dim, n_out=gs.spec.n_influence,
        hidden=64, stack=8 if aip_kind == "fnn" else 1)
    k1, k2 = jax.random.split(key)
    if simulator == "untrained-ials":
        params = influence.init_aip(acfg, k2)
        data = collect.collect_dataset(gs, k1, n_episodes=8, ep_len=ep_len)
        diag["aip_xent"] = float(influence.xent_loss(
            params, acfg, data["d"], data["u"]))
        return ials_lib.make_ials(ls, params, acfg), diag
    t0 = time.time()
    data = collect.collect_dataset(gs, k1, n_episodes=collect_episodes,
                                   ep_len=ep_len)
    if simulator == "f-ials":
        marg = (jnp.full((gs.spec.n_influence,), fixed_marginal)
                if fixed_marginal is not None
                else collect.empirical_marginal(data["u"]))
        params = influence.init_aip(acfg, k2)
        env = ials_lib.make_ials(ls, params, acfg, fixed_marginal_vec=marg)
        # XE of the fixed marginal on held-out data
        p = jnp.clip(marg, 1e-6, 1 - 1e-6)
        xe = -(data["u"] * jnp.log(p) + (1 - data["u"]) * jnp.log(1 - p))
        diag["aip_xent"] = float(xe.sum(-1).mean())
        diag["aip_train_time_s"] = time.time() - t0
        return env, diag
    # trained IALS
    params, m = influence.train_aip(acfg, data["d"], data["u"], k2,
                                    epochs=aip_epochs, window=aip_window)
    diag["aip_xent"] = m["final_loss"]
    diag["aip_train_time_s"] = time.time() - t0
    return ials_lib.make_ials(ls, params, acfg), diag


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", choices=["traffic", "warehouse"],
                    default="traffic")
    ap.add_argument("--simulator", default="ials",
                    choices=["gs", "ials", "untrained-ials", "f-ials"])
    ap.add_argument("--aip", default=None, choices=[None, "gru", "fnn"])
    ap.add_argument("--fixed-marginal", type=float, default=None)
    ap.add_argument("--iterations", type=int, default=40)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--rollout-len", type=int, default=128)
    ap.add_argument("--episode-len", type=int, default=128)
    ap.add_argument("--collect-episodes", type=int, default=64)
    ap.add_argument("--aip-epochs", type=int, default=10)
    ap.add_argument("--vanish-after", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    gs, ls, frame_stack = build_domain(args.domain, args.vanish_after)
    aip_kind = args.aip or ("gru" if args.domain == "warehouse" else "fnn")

    t_start = time.time()
    key, k_sim = jax.random.split(key)
    env, diag = build_simulator(
        args.simulator, gs, ls, aip_kind, k_sim,
        collect_episodes=args.collect_episodes, ep_len=args.episode_len,
        aip_epochs=args.aip_epochs, fixed_marginal=args.fixed_marginal)

    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim,
                         n_actions=gs.spec.n_actions,
                         frame_stack=frame_stack, n_envs=args.n_envs,
                         rollout_len=args.rollout_len,
                         episode_len=args.episode_len)
    key, k0, k1 = jax.random.split(key, 3)
    params = ppo.init_policy(pcfg, k0)
    opt, iteration = ppo.make_train_iteration(env, pcfg)
    ost = opt.init(params)
    rs = ppo.init_rollout_state(env, pcfg, k1)

    history = []
    for it in range(args.iterations):
        key, k = jax.random.split(key)
        params, ost, rs, m = iteration(params, ost, rs, k)
        row = {"iter": it, "wallclock_s": round(time.time() - t_start, 2),
               "train_reward": float(m["mean_reward"]),
               "env_steps": (it + 1) * args.n_envs * args.rollout_len}
        if it % args.eval_every == 0 or it == args.iterations - 1:
            key, ke = jax.random.split(key)
            row["gs_eval_reward"] = ppo.evaluate(gs, pcfg, params, ke,
                                                 n_episodes=8)
        history.append(row)
        print(json.dumps(row))

    out = {"args": vars(args), "diag": diag, "history": history,
           "total_wallclock_s": round(time.time() - t_start, 2)}
    if args.out:
        Path(args.out).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
