"""RL training driver — the paper's workflow end-to-end (its Fig. 3/5 runs).

    PYTHONPATH=src python -m repro.launch.rl_train --domain traffic \
        --simulator ials --iterations 60

Pipeline per the paper (§5.1):
  1. collect a (d_t, u_t) dataset from the GS under a random policy (Alg. 1)
  2. train the AIP offline (Eq. 3)
  3. train PPO on the chosen simulator: gs | ials | untrained-ials | f-ials
  4. periodically evaluate on the GS (the deployment environment)

Multi-agent (Distributed IALS, ``--n-agents A``): one GS rollout collects
every agent's (d_t, u_t) pairs, A per-agent AIPs train in a single batched
pass (vmap of the training loop), PPO is parameter-shared across agents with
the agent axis as extra batch dimension, and evaluation reports per-agent GS
rewards. ``--n-agents 25`` on traffic = every intersection of the 5x5 grid;
``--n-agents 36`` on warehouse = every robot region. Rollout batches are
placed on the mesh ``data`` axis when more than one device is visible.

Emits a JSON history of (iteration, wallclock, train reward, GS eval reward)
— the learning-curves benchmark reads this.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import collect, engine, influence
from repro.envs.traffic import (TrafficConfig, make_traffic_env,
                                make_batched_local_traffic_env,
                                make_local_traffic_env,
                                make_multi_traffic_env)
from repro.envs.warehouse import (WarehouseConfig, make_warehouse_env,
                                  make_batched_local_warehouse_env,
                                  make_local_warehouse_env,
                                  make_multi_warehouse_env)
from repro.launch.mesh import make_host_mesh
from repro.rl import ppo


def grid_agents(grid: int, n_agents: int):
    """First ``n_agents`` cells of a grid x grid board, row-major."""
    cells = [(i, j) for i in range(grid) for j in range(grid)]
    if n_agents > len(cells):
        raise ValueError(f"n_agents={n_agents} > {grid}x{grid} grid")
    return jnp.asarray(cells[:n_agents], jnp.int32)


def build_domain(domain: str, vanish_after: int = 0, n_agents: int = 1):
    """-> (gs, ls, batched_ls, frame_stack); gs is multi-agent when
    n_agents > 1. ``batched_ls`` is the natively batched LS the fused IALS
    rollout engine steps; ``ls`` keeps the scalar protocol for tooling."""
    if domain == "traffic":
        cfg = TrafficConfig()
        if n_agents > 1:
            gs = make_multi_traffic_env(cfg, grid_agents(cfg.grid, n_agents))
        else:
            gs = make_traffic_env(cfg)
        return (gs, make_local_traffic_env(cfg),
                make_batched_local_traffic_env(cfg), 1)
    cfg = WarehouseConfig(vanish_after=vanish_after)
    if n_agents > 1:
        gs = make_multi_warehouse_env(cfg, grid_agents(cfg.grid, n_agents))
    else:
        gs = make_warehouse_env(cfg)
    return (gs, make_local_warehouse_env(cfg),
            make_batched_local_warehouse_env(cfg), 8)


def _make_sim(ls, params, acfg, n_agents, **kw):
    """``ls``: a BatchedLocalEnv — PPO trains on the unified fused rollout
    engine (one implementation for every backbone x agent-multiplicity
    combination; single-agent is the A=1 squeeze)."""
    return engine.make_unified_ials(ls, params, acfg, n_agents=n_agents,
                                    **kw)


def build_simulator(simulator: str, gs, ls, aip_kind: str, key, *,
                    collect_episodes: int, ep_len: int, aip_epochs: int,
                    fixed_marginal=None, aip_window: int = 0,
                    stateless_f_ials: bool = False):
    """-> (env for PPO, aip diagnostics dict). ``stateless_f_ials`` makes
    the f-ials simulator skip its (ignored) AIP forward pass entirely —
    see ``ials.make_ials`` for the state-shape-parity tradeoff."""
    diag = {}
    if simulator == "gs":
        return gs, diag
    A = gs.spec.n_agents
    acfg = influence.AIPConfig(
        kind=aip_kind, d_in=gs.spec.dset_dim, n_out=gs.spec.n_influence,
        hidden=64, stack=8 if aip_kind == "fnn" else 1)
    k1, k2 = jax.random.split(key)

    def agent_data(n_eps):
        data = collect.collect_dataset(gs, k1, n_episodes=n_eps,
                                       ep_len=ep_len)
        if A > 1:
            data = collect.per_agent(data)      # (A, N, T, ...)
        return data

    if simulator == "untrained-ials":
        data = agent_data(8)
        if A > 1:
            params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
                jax.random.split(k2, A))
            diag["aip_xent"] = float(jnp.mean(jax.vmap(
                lambda p, d, u: influence.xent_loss(p, acfg, d, u))(
                    params, data["d"], data["u"])))
        else:
            params = influence.init_aip(acfg, k2)
            diag["aip_xent"] = float(influence.xent_loss(
                params, acfg, data["d"], data["u"]))
        return _make_sim(ls, params, acfg, A), diag

    t0 = time.time()
    data = agent_data(collect_episodes)
    if simulator == "f-ials":
        M = gs.spec.n_influence
        if fixed_marginal is not None:
            marg = jnp.full((A, M) if A > 1 else (M,), fixed_marginal)
        else:
            marg = collect.empirical_marginal(data["u"], per_agent=A > 1)
        if A > 1:
            params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
                jax.random.split(k2, A))
        else:
            params = influence.init_aip(acfg, k2)
        env = _make_sim(ls, params, acfg, A, fixed_marginal_vec=marg,
                        stateless=stateless_f_ials)
        # XE of the fixed marginal on held-out data
        p = jnp.clip(marg, 1e-6, 1 - 1e-6)
        if A > 1:
            p = p[:, None, None, :]             # broadcast over (A, N, T, M)
        xe = -(data["u"] * jnp.log(p) + (1 - data["u"]) * jnp.log(1 - p))
        diag["aip_xent"] = float(xe.sum(-1).mean())
        diag["aip_train_time_s"] = time.time() - t0
        return env, diag

    # trained IALS (the dataset is dead after the fit -> donate the
    # epoch buffers to the jitted training loop)
    if A > 1:
        params, m = influence.train_aip_batched(
            acfg, data["d"], data["u"], jax.random.split(k2, A),
            epochs=aip_epochs, window=aip_window, donate=True)
        diag["aip_xent_per_agent"] = m["final_loss_per_agent"]
    else:
        params, m = influence.train_aip(acfg, data["d"], data["u"], k2,
                                        epochs=aip_epochs,
                                        window=aip_window, donate=True)
    diag["aip_xent"] = m["final_loss"]
    diag["aip_train_time_s"] = time.time() - t0
    return _make_sim(ls, params, acfg, A), diag


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", choices=["traffic", "warehouse"],
                    default="traffic")
    ap.add_argument("--simulator", default="ials",
                    choices=["gs", "ials", "untrained-ials", "f-ials"])
    ap.add_argument("--aip", default=None, choices=[None, "gru", "fnn"])
    ap.add_argument("--fixed-marginal", type=float, default=None)
    ap.add_argument("--stateless-f-ials", action="store_true",
                    help="f-ials only: freeze the ignored AIP recurrent "
                         "state instead of advancing it every tick")
    ap.add_argument("--exact-policy-tanh", action="store_true",
                    help="evaluate the PPO policy net with exact jnp.tanh "
                         "instead of the default rational gates "
                         "(nn/act.py)")
    ap.add_argument("--n-agents", type=int, default=1,
                    help="agents trained at once (25 = full 5x5 traffic "
                         "grid, 36 = full 6x6 warehouse floor)")
    ap.add_argument("--iterations", type=int, default=40)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--rollout-len", type=int, default=128)
    ap.add_argument("--episode-len", type=int, default=128)
    ap.add_argument("--collect-episodes", type=int, default=64)
    ap.add_argument("--aip-epochs", type=int, default=10)
    ap.add_argument("--vanish-after", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    gs, _, ls, frame_stack = build_domain(args.domain, args.vanish_after,
                                          args.n_agents)
    aip_kind = args.aip or ("gru" if args.domain == "warehouse" else "fnn")

    t_start = time.time()
    key, k_sim = jax.random.split(key)
    env, diag = build_simulator(
        args.simulator, gs, ls, aip_kind, k_sim,
        collect_episodes=args.collect_episodes, ep_len=args.episode_len,
        aip_epochs=args.aip_epochs, fixed_marginal=args.fixed_marginal,
        stateless_f_ials=args.stateless_f_ials)

    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim,
                         n_actions=gs.spec.n_actions,
                         frame_stack=frame_stack, n_envs=args.n_envs,
                         rollout_len=args.rollout_len,
                         episode_len=args.episode_len,
                         n_agents=args.n_agents,
                         fast_gates=not args.exact_policy_tanh)
    key, k0, k1 = jax.random.split(key, 3)
    mesh = (make_host_mesh()
            if len(jax.devices()) > 1
            and args.n_envs % len(jax.devices()) == 0 else None)
    params = ppo.init_policy(pcfg, k0)
    opt, iteration = ppo.make_train_iteration(env, pcfg, mesh=mesh)
    ost = opt.init(params)
    rs = ppo.init_rollout_state(env, pcfg, k1, mesh=mesh)

    steps_per_iter = args.n_envs * args.rollout_len * max(args.n_agents, 1)
    history = []
    for it in range(args.iterations):
        key, k = jax.random.split(key)
        params, ost, rs, m = iteration(params, ost, rs, k)
        row = {"iter": it, "wallclock_s": round(time.time() - t_start, 2),
               "train_reward": float(m["mean_reward"]),
               "env_steps": (it + 1) * steps_per_iter}
        if it % args.eval_every == 0 or it == args.iterations - 1:
            key, ke = jax.random.split(key)
            if args.n_agents > 1:
                per = ppo.evaluate(gs, pcfg, params, ke, n_episodes=8,
                                   per_agent=True)
                row["gs_eval_reward_per_agent"] = [
                    round(float(r), 4) for r in per]
                row["gs_eval_reward"] = float(per.mean())
            else:
                row["gs_eval_reward"] = ppo.evaluate(gs, pcfg, params, ke,
                                                     n_episodes=8)
        history.append(row)
        print(json.dumps(row))

    out = {"args": vars(args), "diag": diag, "history": history,
           "total_wallclock_s": round(time.time() - t_start, 2)}
    if args.out:
        Path(args.out).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
