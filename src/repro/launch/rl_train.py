"""RL training driver — the paper's workflow end-to-end (its Fig. 3/5 runs).

    PYTHONPATH=src python -m repro.launch.rl_train --domain traffic \
        --simulator ials --iterations 60

Pipeline per the paper (§5.1):
  1. collect a (d_t, u_t) dataset from the GS under a random policy (Alg. 1)
  2. train the AIP offline (Eq. 3)
  3. train PPO on the chosen simulator: gs | ials | untrained-ials | f-ials
  4. periodically evaluate on the GS (the deployment environment)

Multi-agent (Distributed IALS, ``--n-agents A``): one GS rollout collects
every agent's (d_t, u_t) pairs, A per-agent AIPs train in a single batched
pass (vmap of the training loop), PPO is parameter-shared across agents with
the agent axis as extra batch dimension, and evaluation reports per-agent GS
rewards. ``--n-agents 25`` on traffic = every intersection of the 5x5 grid;
``--n-agents 36`` on warehouse = every robot region. Rollout batches are
placed on the mesh ``data`` axis when more than one device is visible.

Fault tolerance (the kill-and-resume contract, docs/ARCHITECTURE.md §7):
``--ckpt-dir`` makes the run preemption-safe — every RNG key is derived by
position (``fold_in(root, stream), it``), never by a split chain, so the
checkpoint needs only the iteration index to rewind the randomness. The
checkpoint carries the FULL RL state: policy params, optimizer state,
rollout/env state, the trained (per-agent) AIP params the simulator was
built from, and the iteration counter. A killed run re-launched with the
same command auto-resumes from the latest committed checkpoint — skipping
dataset collection and AIP training (the AIP comes back from disk) — and
replays the **bitwise identical** remaining trajectory; the same-seed
uninterrupted run is the oracle (tests/test_actor_learner.py pins this).

``--n-workers N`` (N >= 1) switches to the disaggregated actor/learner
fleet (distributed/actor_learner.py): N rollout workers stream tagged
trajectory batches into one learner with the documented
``--max-staleness`` drop policy; ``--kill-worker W:TICK`` /
``--delay-batch W:TICK:N`` schedule deterministic faults
(distributed/fault_injection.py). The default deterministic schedule keeps
the bitwise-resume claim; ``--async-fleet`` is the free-running
throughput mode (no bitwise claim).

Emits a JSON history of (iteration, wallclock, train reward, GS eval
reward) plus ``final_params_md5`` — the learning-curves benchmark reads
the history; the CI fault smoke compares the digest across kill/resume.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from pathlib import Path
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import collect, engine, influence
from repro.distributed import actor_learner, fault_injection
from repro.distributed.fault_tolerance import TrainingGuard
from repro.envs.traffic import (TrafficConfig, make_traffic_env,
                                make_batched_local_traffic_env,
                                make_local_traffic_env,
                                make_multi_traffic_env)
from repro.envs.warehouse import (WarehouseConfig, make_warehouse_env,
                                  make_batched_local_warehouse_env,
                                  make_local_warehouse_env,
                                  make_multi_warehouse_env)
from repro.launch.mesh import make_host_mesh
from repro.rl import ppo

# fold_in stream tags — every key in the driver is fold_in(fold_in(root,
# TAG), position), so resume only needs the position (an int in the
# checkpoint), never a key chain
_K_SIM, _K_POLICY, _K_ROLLOUT, _K_TRAIN, _K_EVAL = 0, 1, 2, 3, 4


def grid_agents(grid: int, n_agents: int):
    """First ``n_agents`` cells of a grid x grid board, row-major."""
    cells = [(i, j) for i in range(grid) for j in range(grid)]
    if n_agents > len(cells):
        raise ValueError(f"n_agents={n_agents} > {grid}x{grid} grid")
    return jnp.asarray(cells[:n_agents], jnp.int32)


def build_domain(domain: str, vanish_after: int = 0, n_agents: int = 1):
    """-> (gs, ls, batched_ls, frame_stack); gs is multi-agent when
    n_agents > 1. ``batched_ls`` is the natively batched LS the fused IALS
    rollout engine steps; ``ls`` keeps the scalar protocol for tooling."""
    if domain == "traffic":
        cfg = TrafficConfig()
        if n_agents > 1:
            gs = make_multi_traffic_env(cfg, grid_agents(cfg.grid, n_agents))
        else:
            gs = make_traffic_env(cfg)
        return (gs, make_local_traffic_env(cfg),
                make_batched_local_traffic_env(cfg), 1)
    cfg = WarehouseConfig(vanish_after=vanish_after)
    if n_agents > 1:
        gs = make_multi_warehouse_env(cfg, grid_agents(cfg.grid, n_agents))
    else:
        gs = make_warehouse_env(cfg)
    return (gs, make_local_warehouse_env(cfg),
            make_batched_local_warehouse_env(cfg), 8)


def _make_sim(ls, params, acfg, n_agents, **kw):
    """``ls``: a BatchedLocalEnv — PPO trains on the unified fused rollout
    engine (one implementation for every backbone x agent-multiplicity
    combination; single-agent is the A=1 squeeze)."""
    return engine.make_unified_ials(ls, params, acfg, n_agents=n_agents,
                                    **kw)


class SimBuild(NamedTuple):
    """A simulator recipe split at the checkpoint boundary: ``template()``
    is a cheap, shape-correct pytree of the simulator's trainable state
    (the restore target), ``train(key)`` produces the real state (dataset
    collection + AIP fit — the expensive part a resume skips), and
    ``make_env(sim_params)`` builds the PPO environment from either."""
    template: Callable[[], Any]
    train: Callable[[Any], Tuple[Any, dict]]
    make_env: Callable[[Any], Any]


def prepare_simulator(simulator: str, gs, ls, aip_kind: str, *,
                      collect_episodes: int, ep_len: int, aip_epochs: int,
                      fixed_marginal=None, aip_window: int = 0,
                      stateless_f_ials: bool = False) -> SimBuild:
    """-> SimBuild. ``stateless_f_ials`` makes the f-ials simulator skip
    its (ignored) AIP forward pass entirely — see ``ials.make_ials`` for
    the state-shape-parity tradeoff."""
    if simulator == "gs":
        return SimBuild(template=lambda: {},
                        train=lambda key: ({}, {}),
                        make_env=lambda p: gs)
    A = gs.spec.n_agents
    acfg = influence.AIPConfig(
        kind=aip_kind, d_in=gs.spec.dset_dim, n_out=gs.spec.n_influence,
        hidden=64, stack=8 if aip_kind == "fnn" else 1)

    def init_params(key):
        if A > 1:
            return jax.vmap(lambda k: influence.init_aip(acfg, k))(
                jax.random.split(key, A))
        return influence.init_aip(acfg, key)

    def agent_data(key, n_eps):
        data = collect.collect_dataset(gs, key, n_episodes=n_eps,
                                       ep_len=ep_len)
        if A > 1:
            data = collect.per_agent(data)      # (A, N, T, ...)
        return data

    if simulator == "untrained-ials":
        def train(key):
            k1, k2 = jax.random.split(key)
            data = agent_data(k1, 8)
            params = init_params(k2)
            if A > 1:
                xent = float(jnp.mean(jax.vmap(
                    lambda p, d, u: influence.xent_loss(p, acfg, d, u))(
                        params, data["d"], data["u"])))
            else:
                xent = float(influence.xent_loss(
                    params, acfg, data["d"], data["u"]))
            return params, {"aip_xent": xent}
        return SimBuild(
            template=lambda: init_params(jax.random.PRNGKey(0)),
            train=train,
            make_env=lambda p: _make_sim(ls, p, acfg, A))

    if simulator == "f-ials":
        M = gs.spec.n_influence
        marg_shape = (A, M) if A > 1 else (M,)

        def train(key):
            t0 = time.time()
            k1, k2 = jax.random.split(key)
            data = agent_data(k1, collect_episodes)
            if fixed_marginal is not None:
                marg = jnp.full(marg_shape, fixed_marginal)
            else:
                marg = collect.empirical_marginal(data["u"],
                                                  per_agent=A > 1)
            params = init_params(k2)
            # XE of the fixed marginal on held-out data
            p = jnp.clip(marg, 1e-6, 1 - 1e-6)
            if A > 1:
                p = p[:, None, None, :]         # broadcast over (A, N, T, M)
            xe = -(data["u"] * jnp.log(p)
                   + (1 - data["u"]) * jnp.log(1 - p))
            diag = {"aip_xent": float(xe.sum(-1).mean()),
                    "aip_train_time_s": time.time() - t0}
            return {"aip": params, "marg": marg}, diag
        return SimBuild(
            template=lambda: {"aip": init_params(jax.random.PRNGKey(0)),
                              "marg": jnp.zeros(marg_shape)},
            train=train,
            make_env=lambda p: _make_sim(ls, p["aip"], acfg, A,
                                         fixed_marginal_vec=p["marg"],
                                         stateless=stateless_f_ials))

    # trained IALS (the dataset is dead after the fit -> donate the
    # epoch buffers to the jitted training loop)
    def train(key):
        t0 = time.time()
        k1, k2 = jax.random.split(key)
        data = agent_data(k1, collect_episodes)
        diag = {}
        if A > 1:
            params, m = influence.train_aip_batched(
                acfg, data["d"], data["u"], jax.random.split(k2, A),
                epochs=aip_epochs, window=aip_window, donate=True)
            diag["aip_xent_per_agent"] = m["final_loss_per_agent"]
        else:
            params, m = influence.train_aip(acfg, data["d"], data["u"], k2,
                                            epochs=aip_epochs,
                                            window=aip_window, donate=True)
        diag["aip_xent"] = m["final_loss"]
        diag["aip_train_time_s"] = time.time() - t0
        return params, diag
    return SimBuild(template=lambda: init_params(jax.random.PRNGKey(0)),
                    train=train,
                    make_env=lambda p: _make_sim(ls, p, acfg, A))


def build_simulator(simulator: str, gs, ls, aip_kind: str, key, *,
                    collect_episodes: int, ep_len: int, aip_epochs: int,
                    fixed_marginal=None, aip_window: int = 0,
                    stateless_f_ials: bool = False):
    """-> (env for PPO, aip diagnostics dict) — the one-shot convenience
    wrapper over ``prepare_simulator`` for callers that never resume."""
    sb = prepare_simulator(
        simulator, gs, ls, aip_kind, collect_episodes=collect_episodes,
        ep_len=ep_len, aip_epochs=aip_epochs, fixed_marginal=fixed_marginal,
        aip_window=aip_window, stateless_f_ials=stateless_f_ials)
    sim_params, diag = sb.train(key)
    return sb.make_env(sim_params), diag


def params_md5(tree) -> str:
    """Digest of every leaf's raw bytes in tree order — two runs agree
    iff their params are bitwise identical (the resume oracle)."""
    h = hashlib.md5()
    for leaf in jax.tree_util.tree_leaves(tree):
        h.update(np.ascontiguousarray(jax.device_get(leaf)).tobytes())
    return h.hexdigest()


def _parse_faults(kills, delays):
    events = []
    for s in kills or []:
        w, t = (int(x) for x in s.split(":"))
        events.append(fault_injection.KillWorker(worker_id=w, at_tick=t))
    for s in delays or []:
        w, t, n = (int(x) for x in s.split(":"))
        events.append(fault_injection.DelayBatch(worker_id=w, at_tick=t,
                                                 ticks=n))
    return events


def run_training(args):
    """The driver body, callable in-process (tests use this to compare a
    kill/resume pair against an uninterrupted run)."""
    root = jax.random.PRNGKey(args.seed)
    gs, _, ls, frame_stack = build_domain(args.domain, args.vanish_after,
                                          args.n_agents)
    aip_kind = args.aip or ("gru" if args.domain == "warehouse" else "fnn")
    sb = prepare_simulator(
        args.simulator, gs, ls, aip_kind,
        collect_episodes=args.collect_episodes, ep_len=args.episode_len,
        aip_epochs=args.aip_epochs, fixed_marginal=args.fixed_marginal,
        stateless_f_ials=args.stateless_f_ials)

    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim,
                         n_actions=gs.spec.n_actions,
                         frame_stack=frame_stack, n_envs=args.n_envs,
                         rollout_len=args.rollout_len,
                         episode_len=args.episode_len,
                         n_agents=args.n_agents,
                         fast_gates=not args.exact_policy_tanh)
    mesh = (make_host_mesh()
            if len(jax.devices()) > 1
            and args.n_envs % len(jax.devices()) == 0 else None)
    t_start = time.time()
    guard = (TrainingGuard(args.ckpt_dir, save_every=args.save_every)
             if args.ckpt_dir else None)
    resume_step = (ckpt.latest_step(args.ckpt_dir)
                   if args.ckpt_dir else None)

    def eval_row(row, params, it):
        ke = jax.random.fold_in(jax.random.fold_in(root, _K_EVAL), it)
        if args.n_agents > 1:
            per = ppo.evaluate(gs, pcfg, params, ke, n_episodes=8,
                               per_agent=True)
            row["gs_eval_reward_per_agent"] = [round(float(r), 4)
                                               for r in per]
            row["gs_eval_reward"] = float(per.mean())
        else:
            row["gs_eval_reward"] = ppo.evaluate(gs, pcfg, params, ke,
                                                 n_episodes=8)
        return row

    if args.n_workers > 0:
        out = _run_fleet(args, root, sb, pcfg, guard, resume_step,
                         eval_row, t_start)
    else:
        out = _run_integrated(args, root, sb, pcfg, mesh, guard,
                              resume_step, eval_row, t_start)
    if guard is not None:
        guard.uninstall()
    if args.out:
        Path(args.out).write_text(json.dumps(out, indent=1))
    return out


def _run_integrated(args, root, sb: SimBuild, pcfg, mesh, guard,
                    resume_step, eval_row, t_start):
    """Single-process trainer: the fused ``train_iteration`` loop with
    position-keyed RNG and full-state checkpoints."""
    start_it = 0
    if resume_step is not None:
        # restore first (shapes come from cheap templates), THEN rebuild
        # the engine from the restored AIP params — make_unified_ials
        # closes over them at construction
        env_t = sb.make_env(sb.template())
        policy_t = ppo.init_policy(pcfg, jax.random.PRNGKey(0))
        template = {"policy": policy_t,
                    "opt": ppo.make_optimizer(pcfg).init(policy_t),
                    "rs": ppo.init_rollout_state(env_t, pcfg,
                                                 jax.random.PRNGKey(0),
                                                 mesh=mesh),
                    "sim": sb.template(), "it": jnp.int32(0)}
        tree, step, _ = ckpt.restore(args.ckpt_dir, template, resume_step)
        sim_params, diag = tree["sim"], {"resumed_from": step}
        env = sb.make_env(sim_params)
        params, ost, rs = tree["policy"], tree["opt"], tree["rs"]
        start_it = int(tree["it"])
        _, iteration = ppo.make_train_iteration(env, pcfg, mesh=mesh)
        print(f"resumed from iteration {start_it}")
    else:
        sim_params, diag = sb.train(jax.random.fold_in(root, _K_SIM))
        env = sb.make_env(sim_params)
        params = ppo.init_policy(pcfg, jax.random.fold_in(root, _K_POLICY))
        opt, iteration = ppo.make_train_iteration(env, pcfg, mesh=mesh)
        ost = opt.init(params)
        rs = ppo.init_rollout_state(env, pcfg,
                                    jax.random.fold_in(root, _K_ROLLOUT),
                                    mesh=mesh)

    steps_per_iter = args.n_envs * args.rollout_len * max(args.n_agents, 1)
    history = []
    preempted = False
    for it in range(start_it, args.iterations):
        k = jax.random.fold_in(jax.random.fold_in(root, _K_TRAIN), it)
        params, ost, rs, m = iteration(params, ost, rs, k)
        row = {"iter": it, "wallclock_s": round(time.time() - t_start, 2),
               "train_reward": float(m["mean_reward"]),
               "env_steps": (it + 1) * steps_per_iter}
        if it % args.eval_every == 0 or it == args.iterations - 1:
            row = eval_row(row, params, it)
        history.append(row)
        print(json.dumps(row))
        if guard is not None:
            # read the flag BEFORE maybe_save: a successful forced save
            # clears it (the guard answers the signal once, not forever)
            was_preempted = guard.preempted
            saved = guard.maybe_save(
                it + 1,
                {"policy": params, "opt": ost, "rs": rs,
                 "sim": sim_params, "it": jnp.int32(it + 1)},
                metadata={"mode": "integrated", "iterations_done": it + 1})
            if was_preempted and saved:
                print("preempted: RL checkpoint flushed, exiting cleanly")
                preempted = True
                break

    return {"args": vars(args), "diag": diag, "history": history,
            "preempted": preempted, "resumed_from": start_it,
            "final_params_md5": params_md5(params),
            "total_wallclock_s": round(time.time() - t_start, 2)}


def _run_fleet(args, root, sb: SimBuild, pcfg, guard, resume_step,
               eval_row, t_start):
    """Disaggregated trainer: N workers -> bounded queue -> one learner,
    chunked at ``eval_every`` updates (chunk boundaries are quiescent —
    no in-flight batches — which is where checkpoints happen)."""
    fcfg = actor_learner.FleetConfig(
        n_workers=args.n_workers, queue_size=args.queue_size,
        max_staleness=args.max_staleness, publish_every=args.publish_every,
        deterministic=not args.async_fleet, seed=args.seed)
    events = _parse_faults(args.kill_worker, args.delay_batch)
    injector = (fault_injection.FaultInjector(
        fault_injection.FaultPlan.of(*events)) if events else None)

    diag = {}
    if resume_step is not None:
        env_t = sb.make_env(sb.template())
        trainer_t = actor_learner.ActorLearnerTrainer(env_t, pcfg, fcfg)
        state, sim_params, start_v = actor_learner.resume_fleet(
            args.ckpt_dir, trainer_t, extra_template=sb.template())
        diag["resumed_from"] = start_v
        print(f"resumed fleet at learner version {start_v}")
    else:
        sim_params, diag = sb.train(jax.random.fold_in(root, _K_SIM))
        state = None
    env = sb.make_env(sim_params)
    trainer = actor_learner.ActorLearnerTrainer(env, pcfg, fcfg,
                                                injector=injector)
    if state is None:
        state = trainer.init_state()

    stats = {"produced": 0, "updates": 0, "dropped": 0, "delayed": 0}
    history = []
    preempted = False
    v = int(state.version)
    while v < args.iterations:
        chunk = min(args.eval_every, args.iterations - v)
        should_stop = (lambda: guard.preempted) if guard is not None \
            else None
        state, info = trainer.run(state, chunk, should_stop=should_stop)
        for k in stats:
            stats[k] += info[k]
        v = int(state.version)
        for h in info["history"]:
            row = {"iter": h["version"], "worker": h["worker"],
                   "staleness": h["staleness"], "dropped": h["dropped"]}
            if not h["dropped"]:
                row["train_reward"] = h["mean_reward"]
            history.append(row)
        row = eval_row({"iter": v,
                        "wallclock_s": round(time.time() - t_start, 2)},
                       state.params, v)
        history.append(row)
        print(json.dumps(row))
        if guard is not None:
            was_preempted = guard.preempted
            saved = guard.maybe_save(
                v, {"fleet": state, "extra": sim_params},
                metadata={"mode": "fleet", **trainer.save_metadata(state)})
            if was_preempted and saved:
                print("preempted: fleet checkpoint flushed, exiting cleanly")
                preempted = True
                break
    if guard is not None and not preempted:
        guard.maybe_save(v, {"fleet": state, "extra": sim_params},
                         force=True,
                         metadata={"mode": "fleet",
                                   **trainer.save_metadata(state)})
    if injector is not None:
        stats["kills"] = injector.kills_applied
        stats["faults_exhausted"] = injector.exhausted

    return {"args": vars(args), "diag": diag, "history": history,
            "fleet": stats, "preempted": preempted,
            "final_params_md5": params_md5(state.params),
            "total_wallclock_s": round(time.time() - t_start, 2)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", choices=["traffic", "warehouse"],
                    default="traffic")
    ap.add_argument("--simulator", default="ials",
                    choices=["gs", "ials", "untrained-ials", "f-ials"])
    ap.add_argument("--aip", default=None, choices=[None, "gru", "fnn"])
    ap.add_argument("--fixed-marginal", type=float, default=None)
    ap.add_argument("--stateless-f-ials", action="store_true",
                    help="f-ials only: freeze the ignored AIP recurrent "
                         "state instead of advancing it every tick")
    ap.add_argument("--exact-policy-tanh", action="store_true",
                    help="evaluate the PPO policy net with exact jnp.tanh "
                         "instead of the default rational gates "
                         "(nn/act.py)")
    ap.add_argument("--n-agents", type=int, default=1,
                    help="agents trained at once (25 = full 5x5 traffic "
                         "grid, 36 = full 6x6 warehouse floor)")
    ap.add_argument("--iterations", type=int, default=40)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--rollout-len", type=int, default=128)
    ap.add_argument("--episode-len", type=int, default=128)
    ap.add_argument("--collect-episodes", type=int, default=64)
    ap.add_argument("--aip-epochs", type=int, default=10)
    ap.add_argument("--vanish-after", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    # fault tolerance / disaggregation
    ap.add_argument("--ckpt-dir", default="",
                    help="enable preemption-safe checkpointing + "
                         "auto-resume (bitwise on the deterministic paths)")
    ap.add_argument("--save-every", type=int, default=5,
                    help="checkpoint every N learner iterations "
                         "(SIGTERM always forces a flush)")
    ap.add_argument("--n-workers", type=int, default=0,
                    help="rollout workers for the disaggregated "
                         "actor/learner fleet (0 = integrated trainer)")
    ap.add_argument("--max-staleness", type=int, default=4,
                    help="drop trajectory batches staler than this many "
                         "policy versions")
    ap.add_argument("--publish-every", type=int, default=1,
                    help="learner updates between parameter publications")
    ap.add_argument("--queue-size", type=int, default=8)
    ap.add_argument("--async-fleet", action="store_true",
                    help="free-running worker threads (throughput mode; "
                         "no bitwise-resume claim)")
    ap.add_argument("--kill-worker", action="append", metavar="W:TICK",
                    help="deterministically kill+restart worker W before "
                         "its produce at fleet tick TICK (repeatable)")
    ap.add_argument("--delay-batch", action="append", metavar="W:TICK:N",
                    help="hold the batch worker W produces at TICK for N "
                         "ticks (drives it past --max-staleness)")
    args = ap.parse_args(argv)
    return run_training(args)


if __name__ == "__main__":
    main()
