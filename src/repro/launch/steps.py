"""Step functions: train_step (grad-accumulated), prefill_step, serve_step.

These are the units the dry-run lowers and the real launcher jits. Gradient
accumulation runs as a ``lax.scan`` over microbatches (bounds live activation
memory); gradients accumulate in fp32 regardless of param dtype.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain
from repro.models import lm
from repro.optim.adamw import Optimizer


def make_loss_fn(cfg: ArchConfig) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    n_microbatches: int = 1) -> Callable:
    loss = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: constrain(
                    x.reshape((n_microbatches,
                               x.shape[0] // n_microbatches) + x.shape[1:]),
                    None, "dp", *([None] * (x.ndim - 1))), batch)

            def body(acc, mb):
                g_acc, l_acc, m_acc = acc
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree_util.tree_map(lambda a, b: a + b, m_acc, m)
                return (g_acc, l_acc + l, m_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "lb_loss": 0.0, "z_loss": 0.0, "drop_frac": 0.0}
            m0 = jax.tree_util.tree_map(jnp.float32, m0)
            (grads, l, metrics), _ = lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32), m0), micro)
            inv = 1.0 / n_microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            l = l * inv
            metrics = jax.tree_util.tree_map(lambda m: m * inv, metrics)

        params, opt_state, om = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(loss=l, **om)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int) -> Callable:
    def prefill_step(params, inputs):
        return lm.prefill(params, cfg, inputs, max_len)
    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, token, pos):
        """One decode step: write KV at ``pos``, return logits + new cache."""
        return lm.decode_step(params, cfg, cache, token, pos)
    return serve_step
