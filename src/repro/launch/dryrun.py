"""Multi-pod dry-run: prove the distribution config is coherent.

For one (arch, shape, mesh) cell:
  lower the step function against ShapeDtypeStruct inputs with explicit
  NamedShardings -> .compile() -> memory_analysis + cost_analysis + the
  loop-corrected HLO collective/flops analysis -> JSON to results/dryrun/.

Two cell families share that pipeline:

- the LM demo cells (``--arch/--shape``, the original harness);
- the IALS cells (``--ials``): THIS repo's real whole-horizon programs —
  ``aip_rollout_multi`` / ``fnn_rollout`` (the engine's fused horizon
  rollout, GRU / FNN backbone), ``policy_rollout`` (the
  actor-in-the-loop dispatch) and the full PPO ``train_iteration`` —
  lowered AOT at representative shapes (A in {1, 25, 36}, B sweeps,
  both domains x both backbones) with inputs sharded under the IALS
  partition rules of ``distributed/sharding.py``. The committed
  roofline artifacts (``benchmarks/roofline_report.py``) are built from
  these cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
  PYTHONPATH=src python -m repro.launch.dryrun --ials all --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --ials policy_rollout \
      --domain traffic --n-agents 25 --batch 64 --horizon 128 --mesh pod1
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_name: str,
             overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, SHAPES, cell_applicable
    from repro.distributed import sharding as shd
    from repro.distributed.act_sharding import use_mesh
    from repro.distributed.hlo_analysis import analyze, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as specs_lib
    from repro.launch import steps as steps_lib
    from repro.models import lm
    from repro.optim.adamw import adamw
    from repro.nn.module import abstractify

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    n_chips = mesh.devices.size
    t0 = time.time()

    # --- abstract params with shardings ---
    shd.set_moe_expert_axes(cfg.moe_expert_axes)
    pshapes = lm.param_shapes(cfg)
    pspecs = shd.param_specs(pshapes, mesh, cfg.parallelism)
    psharded = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=jax.sharding.NamedSharding(mesh, s)),
        pshapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    kind = shape.kind
    n_micro = cfg.force_microbatches or shape.n_microbatches
    with mesh, use_mesh(mesh, cfg.parallelism):
        if kind == "train":
            opt = adamw(1e-4)
            oshapes = jax.eval_shape(opt.init, pshapes)
            ospecs = shd.opt_state_specs(oshapes, mesh, pspecs)
            osharded = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype,
                    sharding=jax.sharding.NamedSharding(mesh, s)),
                oshapes, ospecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            inputs = specs_lib.train_input_specs(cfg, shape, mesh)
            step = steps_lib.make_train_step(cfg, opt, n_micro)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                psharded, osharded, inputs)
        elif kind == "prefill":
            inputs = specs_lib.prefill_input_specs(cfg, shape, mesh)
            step = steps_lib.make_prefill_step(cfg, shape.seq_len)
            lowered = jax.jit(step).lower(psharded, inputs)
        else:  # decode
            dspecs = specs_lib.decode_input_specs(cfg, shape, mesh)
            step = steps_lib.make_serve_step(cfg)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                psharded, dspecs["cache"], dspecs["token"], dspecs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):        # older jax wraps it in a 1-elem list
        ca = ca[0] if ca else {}
    hlo = analyze(compiled.as_text())

    counts = lm.count_params(cfg)
    # MODEL_FLOPS = 6 N D (train) / 2 N D (fwd) per token, N = active non-embed
    n_active = counts["active"] - counts["embed"]
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    fl_per_tok = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    model_flops = fl_per_tok * n_active * tokens
    rf = roofline(hlo, n_chips, model_flops)

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params_total": counts["total"], "params_active": counts["active"],
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed", 0.0)},
        "hlo": hlo,
        "roofline": rf,
    }
    return out


# ---------------------------------------------------------------------------
# IALS cells: the repo's real whole-horizon programs
# ---------------------------------------------------------------------------

IALS_PROGRAMS = ("aip_rollout_multi", "fnn_rollout", "policy_rollout",
                 "train_iteration")

# (program, domain, backbone, A, B, T, mesh) — the committed sweep:
# every program, A in {1, 25, 36} (full 5x5 traffic grid / 6x6 warehouse
# floor), a B sweep, both domains, both backbones, pod1 + pod2. B is
# picked divisible by the mesh data axes (16 on pod1, 2x16 on pod2).
IALS_SWEEP = [
    ("aip_rollout_multi", "traffic", "gru", 25, 64, 128, "pod1"),
    ("aip_rollout_multi", "warehouse", "gru", 36, 64, 128, "pod1"),
    ("aip_rollout_multi", "warehouse", "gru", 1, 512, 128, "pod1"),
    ("fnn_rollout", "traffic", "fnn", 1, 512, 128, "pod1"),
    ("fnn_rollout", "traffic", "fnn", 25, 64, 128, "pod1"),
    ("fnn_rollout", "warehouse", "fnn", 36, 64, 128, "pod1"),
    ("policy_rollout", "traffic", "fnn", 25, 64, 128, "pod1"),
    ("policy_rollout", "warehouse", "gru", 36, 64, 128, "pod1"),
    ("train_iteration", "traffic", "fnn", 1, 256, 128, "pod1"),
    ("train_iteration", "warehouse", "gru", 1, 256, 128, "pod1"),
    ("aip_rollout_multi", "warehouse", "gru", 36, 64, 128, "pod2"),
    ("policy_rollout", "traffic", "fnn", 25, 64, 128, "pod2"),
]


def _ials_mesh(mesh_name: str):
    """pod1/pod2 = the production meshes; "host" = whatever devices the
    forced host platform exposes (the CI smoke runs on 8)."""
    import jax
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    if mesh_name == "host":
        n = len(jax.devices())
        return make_host_mesh(model=2 if n % 2 == 0 and n > 1 else 1)
    return make_production_mesh(multi_pod=(mesh_name == "pod2"))


def _ials_model_flops(program: str, acfg, pcfg, B: int, A: int,
                      T: int) -> float:
    """Analytic useful-FLOP lower bound: the matmul flops the modeled
    networks MUST do (2*m*k*n per GEMM), times lanes x ticks. Elementwise
    tick work and the LS transition are excluded, so the ratio reported
    against the HLO count is conservative."""
    H = acfg.hidden
    if acfg.kind == "gru":
        f_aip = 2.0 * (acfg.d_in * 3 * H + H * 3 * H + H * acfg.n_out)
    else:
        f_aip = 2.0 * (acfg.stack * acfg.d_in * H + H * H
                       + H * acfg.n_out)
    lanes = float(T) * B * A
    if program in ("aip_rollout_multi", "fnn_rollout"):
        return lanes * f_aip
    Hp = pcfg.hidden
    f_pol = 2.0 * (pcfg.frame_stack * pcfg.obs_dim * Hp + Hp * Hp
                   + Hp * (pcfg.n_actions + 1))
    if program == "policy_rollout":
        return lanes * (f_aip + f_pol)
    # train_iteration: the acting rollout plus epochs x (fwd + bwd ~ 3x
    # fwd) policy passes over every collected sample
    return lanes * (f_aip + f_pol) + pcfg.epochs * lanes * 3.0 * f_pol


def run_ials_cell(program: str, domain: str, backbone: str, n_agents: int,
                  batch: int, horizon: int, mesh_name: str) -> dict:
    """Lower one IALS whole-horizon program AOT with IALS-rule-sharded
    inputs on a simulated mesh, and run the roofline pipeline on it."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import engine, influence
    from repro.distributed import sharding as shd
    from repro.distributed.hlo_analysis import analyze, roofline
    from repro.envs.traffic import (TrafficConfig,
                                    make_batched_local_traffic_env)
    from repro.envs.warehouse import (WarehouseConfig,
                                      make_batched_local_warehouse_env)
    from repro.rl import ppo

    if program not in IALS_PROGRAMS:
        raise SystemExit(f"unknown IALS program {program!r} "
                         f"(one of {IALS_PROGRAMS})")
    if program == "aip_rollout_multi" and backbone != "gru":
        backbone = "gru"          # the GRU-backbone horizon dispatch
    if program == "fnn_rollout" and backbone != "fnn":
        backbone = "fnn"
    A, B, T = n_agents, batch, horizon
    shape_name = f"{domain}_{backbone}_A{A}_B{B}_T{T}"
    arch = f"ials_{program}"

    if domain == "traffic":
        bls, frame_stack = make_batched_local_traffic_env(
            TrafficConfig()), 1
    else:
        bls, frame_stack = make_batched_local_warehouse_env(
            WarehouseConfig()), 8
    acfg = influence.AIPConfig(
        kind=backbone, d_in=bls.spec.dset_dim, n_out=bls.spec.n_influence,
        hidden=64, stack=8 if backbone == "fnn" else 1)

    mesh = _ials_mesh(mesh_name)
    n_chips = int(mesh.devices.size)
    t0 = time.time()

    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if A > 1:
        aip_shapes = jax.eval_shape(
            lambda ks: jax.vmap(lambda k: influence.init_aip(acfg, k))(ks),
            jax.ShapeDtypeStruct((A, 2), jnp.uint32))
    else:
        aip_shapes = jax.eval_shape(
            lambda k: influence.init_aip(acfg, k), key_s)

    def sds(tree, specs):
        return jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            tree, specs)

    # forced kernel route: on CPU the ops layer dispatches the stacked
    # oracle scans — the identical-math pure-XLA twin of the TPU Pallas
    # kernels, so the lowered HLO is analyzable (a Pallas custom-call
    # would be opaque; see the roofline contract in docs/ARCHITECTURE.md)
    def build_engine(aip, *, kernel=True):
        return engine.make_unified_ials(
            bls, aip, acfg, n_agents=A, use_horizon_kernel=kernel,
            mesh=mesh)

    env0 = build_engine(aip_shapes)
    state_shapes = jax.eval_shape(lambda k: env0.reset(k, B), key_s)

    aip_in = sds(aip_shapes, shd.ials_aip_param_specs(
        aip_shapes, mesh, A, batch=B))
    state_in = sds(state_shapes, shd.ials_state_specs(
        state_shapes, mesh, A))
    rep = lambda t: sds(t, jax.tree_util.tree_map(lambda _: P(), t))
    n_params = sum(int(l.size) for l in
                   jax.tree_util.tree_leaves(aip_shapes))

    if program in ("aip_rollout_multi", "fnn_rollout"):
        act_shape = (T, B, A) if A > 1 else (T, B)
        act_s = jax.ShapeDtypeStruct(act_shape, jnp.int32)
        actions_in = sds(act_s, shd.ials_stream_pspec(act_s, mesh, B, A))
        keys_in = rep(jax.ShapeDtypeStruct((T, 2), jnp.uint32))

        def f(aip, state, actions, keys):
            return build_engine(aip).rollout(state, actions, keys)

        lowered = jax.jit(f).lower(aip_in, state_in, actions_in, keys_in)
    else:
        pcfg = ppo.PPOConfig(
            obs_dim=bls.spec.obs_dim, n_actions=bls.spec.n_actions,
            frame_stack=frame_stack, n_envs=B, rollout_len=T,
            episode_len=T, n_agents=A)
        pol_shapes = jax.eval_shape(
            lambda k: ppo.init_policy(pcfg, k), key_s)
        rs_shapes = jax.eval_shape(
            lambda k: ppo.init_rollout_state(env0, pcfg, k), key_s)
        pol_in = sds(pol_shapes, shd.ials_replicated_specs(pol_shapes))
        rs_in = sds(rs_shapes, shd.ials_state_specs(rs_shapes, mesh, A))
        key_in = rep(key_s)
        n_params += sum(int(l.size) for l in
                        jax.tree_util.tree_leaves(pol_shapes))

        if program == "policy_rollout":
            def f(aip, pol, rs, key):
                return ppo.rollout(build_engine(aip), pcfg, pol, rs, key)

            lowered = jax.jit(f).lower(aip_in, pol_in, rs_in, key_in)
        else:                     # train_iteration
            opt = ppo.make_optimizer(pcfg)
            ost_shapes = jax.eval_shape(opt.init, pol_shapes)
            ost_in = sds(ost_shapes,
                         shd.ials_replicated_specs(ost_shapes))

            def f(aip, pol, ost, rs, key):
                # the default (scan) route: the program PPO trains with
                it = ppo.train_iteration_fn(
                    build_engine(aip, kernel=None), pcfg, opt, mesh=mesh)
                return it(pol, ost, rs, key)

            lowered = jax.jit(f, donate_argnums=(1, 2, 3)).lower(
                aip_in, pol_in, ost_in, rs_in, key_in)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    hlo = analyze(compiled.as_text())
    model_flops = _ials_model_flops(
        program, acfg, pcfg if program in ("policy_rollout",
                                           "train_iteration") else None,
        B, A, T)
    rf = roofline(hlo, n_chips, model_flops)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "family": "ials", "program": program,
        "domain": domain, "backbone": backbone, "n_agents": A,
        "batch": B, "horizon": T, "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params_total": n_params, "params_active": n_params,
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed",
                                                    0.0)},
        "hlo": hlo,
        "roofline": rf,
    }


def _ials_cell_filename(program, domain, backbone, A, B, T, mesh) -> str:
    return (f"ials_{program}__{domain}_{backbone}_A{A}_B{B}_T{T}"
            f"__{mesh}.json")


def _ials_sweep(args):
    """Run the committed IALS sweep, one subprocess per cell (isolates
    compiles; a crashed cell records an error instead of killing the
    sweep)."""
    for prog, dom, bk, A, B, T, mesh in IALS_SWEEP:
        if args.mesh == "host":
            mesh = "host"         # CI smoke: every cell on the host mesh
        elif args.mesh == "pod2" and mesh != "pod2":
            continue              # explicit pod2-only rerun
        # --mesh pod1 (default) / both: the sweep's own per-row meshes
        fn = RESULTS / _ials_cell_filename(prog, dom, bk, A, B, T, mesh)
        if fn.exists() and not args.force:
            print(f"skip (cached): {fn.name}")
            continue
        print(f"=== ials {prog} {dom} {bk} A{A} B{B} T{T} {mesh} ===",
              flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--ials", prog, "--domain", dom, "--backbone", bk,
               "--n-agents", str(A), "--batch", str(B),
               "--horizon", str(T), "--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=7200)
        print(r.stdout[-2000:])
        if r.returncode != 0:
            print("FAILED:", r.stderr[-3000:])
            fn.write_text(json.dumps({
                "arch": f"ials_{prog}", "family": "ials",
                "shape": f"{dom}_{bk}_A{A}_B{B}_T{T}", "mesh": mesh,
                "status": "error", "stderr": r.stderr[-3000:]}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "both", "host"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ials", default=None, metavar="PROGRAM",
                    help="IALS cell family: one of "
                         f"{', '.join(IALS_PROGRAMS)}, or 'all' for the "
                         "committed sweep")
    ap.add_argument("--domain", default="traffic",
                    choices=["traffic", "warehouse"])
    ap.add_argument("--backbone", default=None, choices=["gru", "fnn"])
    ap.add_argument("--n-agents", type=int, default=1)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=128)
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ArchConfig overrides (hillclimb)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result filename (hillclimb variants)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.ials == "all":
        _ials_sweep(args)
        return
    if args.ials:
        backbone = args.backbone or (
            "gru" if args.domain == "warehouse" else "fnn")
        mesh = "pod1" if args.mesh == "both" else args.mesh
        res = run_ials_cell(args.ials, args.domain, backbone,
                            args.n_agents, args.batch, args.horizon, mesh)
        fn = RESULTS / _ials_cell_filename(
            args.ials, args.domain, res["backbone"], args.n_agents,
            args.batch, args.horizon, mesh)
        fn.write_text(json.dumps(res, indent=1))
        print(json.dumps({k: res[k] for k in
                          ("arch", "shape", "mesh", "status")}))
        r = res["roofline"]
        print(f"  compile={res['compile_s']}s  "
              f"peak_mem/dev="
              f"{res['memory']['peak_bytes_per_device']/2**20:.2f}MiB  "
              f"t_comp={r['t_compute_s']:.4f}s "
              f"t_mem={r['t_memory_s']:.4f}s "
              f"t_coll={r['t_collective_s']:.4f}s  -> {r['bottleneck']}")
        return

    if args.all:
        _sweep(args)
        return

    overrides = json.loads(args.overrides) if args.overrides else None
    res = run_cell(args.arch, args.shape, args.mesh, overrides)
    fn = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}{args.tag}.json"
    fn.write_text(json.dumps(res, indent=1))
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "mesh", "status") if k in res}))
    if res.get("status") == "ok":
        r = res["roofline"]
        print(f"  compile={res['compile_s']}s  "
              f"peak_mem/dev={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB  "
              f"t_comp={r['t_compute_s']:.4f}s t_mem={r['t_memory_s']:.4f}s "
              f"t_coll={r['t_collective_s']:.4f}s  -> {r['bottleneck']}")


def _sweep(args):
    """Run every cell as a subprocess (isolates compiles; survives OOM)."""
    from repro.configs.base import list_configs, SHAPES, get_config, \
        cell_applicable
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    cells = []
    for arch in list_configs():
        for shape in SHAPES.values():
            for mesh in meshes:
                cells.append((arch, shape.name, mesh))
    for arch, shape, mesh in cells:
        fn = RESULTS / f"{arch}__{shape}__{mesh}.json"
        if fn.exists() and not args.force:
            print(f"skip (cached): {fn.name}")
            continue
        cfg = get_config(arch)
        ok, reason = cell_applicable(cfg, SHAPES[shape])
        if not ok:
            fn.write_text(json.dumps({"arch": arch, "shape": shape,
                                      "mesh": mesh, "status": reason}))
            print(f"{arch} {shape} {mesh}: {reason}")
            continue
        print(f"=== {arch} {shape} {mesh} ===", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=7200)
        print(r.stdout[-2000:])
        if r.returncode != 0:
            print("FAILED:", r.stderr[-3000:])
            fn.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "status": "error", "stderr": r.stderr[-3000:]}))


if __name__ == "__main__":
    main()
