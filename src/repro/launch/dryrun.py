"""Multi-pod dry-run: prove the distribution config is coherent.

For one (arch, shape, mesh) cell:
  lower the step function against ShapeDtypeStruct inputs with explicit
  NamedShardings -> .compile() -> memory_analysis + cost_analysis + the
  loop-corrected HLO collective/flops analysis -> JSON to results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_name: str,
             overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, SHAPES, cell_applicable
    from repro.distributed import sharding as shd
    from repro.distributed.act_sharding import use_mesh
    from repro.distributed.hlo_analysis import analyze, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as specs_lib
    from repro.launch import steps as steps_lib
    from repro.models import lm
    from repro.optim.adamw import adamw
    from repro.nn.module import abstractify

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    n_chips = mesh.devices.size
    t0 = time.time()

    # --- abstract params with shardings ---
    shd.set_moe_expert_axes(cfg.moe_expert_axes)
    pshapes = lm.param_shapes(cfg)
    pspecs = shd.param_specs(pshapes, mesh, cfg.parallelism)
    psharded = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=jax.sharding.NamedSharding(mesh, s)),
        pshapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    kind = shape.kind
    n_micro = cfg.force_microbatches or shape.n_microbatches
    with mesh, use_mesh(mesh, cfg.parallelism):
        if kind == "train":
            opt = adamw(1e-4)
            oshapes = jax.eval_shape(opt.init, pshapes)
            ospecs = shd.opt_state_specs(oshapes, mesh, pspecs)
            osharded = jax.tree_util.tree_map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype,
                    sharding=jax.sharding.NamedSharding(mesh, s)),
                oshapes, ospecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            inputs = specs_lib.train_input_specs(cfg, shape, mesh)
            step = steps_lib.make_train_step(cfg, opt, n_micro)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                psharded, osharded, inputs)
        elif kind == "prefill":
            inputs = specs_lib.prefill_input_specs(cfg, shape, mesh)
            step = steps_lib.make_prefill_step(cfg, shape.seq_len)
            lowered = jax.jit(step).lower(psharded, inputs)
        else:  # decode
            dspecs = specs_lib.decode_input_specs(cfg, shape, mesh)
            step = steps_lib.make_serve_step(cfg)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                psharded, dspecs["cache"], dspecs["token"], dspecs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):        # older jax wraps it in a 1-elem list
        ca = ca[0] if ca else {}
    hlo = analyze(compiled.as_text())

    counts = lm.count_params(cfg)
    # MODEL_FLOPS = 6 N D (train) / 2 N D (fwd) per token, N = active non-embed
    n_active = counts["active"] - counts["embed"]
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    fl_per_tok = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    model_flops = fl_per_tok * n_active * tokens
    rf = roofline(hlo, n_chips, model_flops)

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params_total": counts["total"], "params_active": counts["active"],
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed", 0.0)},
        "hlo": hlo,
        "roofline": rf,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ArchConfig overrides (hillclimb)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result filename (hillclimb variants)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        _sweep(args)
        return

    overrides = json.loads(args.overrides) if args.overrides else None
    res = run_cell(args.arch, args.shape, args.mesh, overrides)
    fn = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}{args.tag}.json"
    fn.write_text(json.dumps(res, indent=1))
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "mesh", "status") if k in res}))
    if res.get("status") == "ok":
        r = res["roofline"]
        print(f"  compile={res['compile_s']}s  "
              f"peak_mem/dev={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB  "
              f"t_comp={r['t_compute_s']:.4f}s t_mem={r['t_memory_s']:.4f}s "
              f"t_coll={r['t_collective_s']:.4f}s  -> {r['bottleneck']}")


def _sweep(args):
    """Run every cell as a subprocess (isolates compiles; survives OOM)."""
    from repro.configs.base import list_configs, SHAPES, get_config, \
        cell_applicable
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    cells = []
    for arch in list_configs():
        for shape in SHAPES.values():
            for mesh in meshes:
                cells.append((arch, shape.name, mesh))
    for arch, shape, mesh in cells:
        fn = RESULTS / f"{arch}__{shape}__{mesh}.json"
        if fn.exists() and not args.force:
            print(f"skip (cached): {fn.name}")
            continue
        cfg = get_config(arch)
        ok, reason = cell_applicable(cfg, SHAPES[shape])
        if not ok:
            fn.write_text(json.dumps({"arch": arch, "shape": shape,
                                      "mesh": mesh, "status": reason}))
            print(f"{arch} {shape} {mesh}: {reason}")
            continue
        print(f"=== {arch} {shape} {mesh} ===", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=7200)
        print(r.stdout[-2000:])
        if r.returncode != 0:
            print("FAILED:", r.stderr[-3000:])
            fn.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "status": "error", "stderr": r.stderr[-3000:]}))


if __name__ == "__main__":
    main()
