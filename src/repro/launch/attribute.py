"""Attribution profiler for dry-run cells: where do the roofline bytes go?

    PYTHONPATH=src python -m repro.launch.attribute --arch X --shape Y \
        [--mesh pod1] [--overrides JSON] [--top 15] [--what mem|coll|flops]

Re-lowers the cell exactly like dryrun.run_cell, then ranks ops by
loop-corrected contribution to HBM bytes / collective bytes / FLOPs. This is
the "profile" of the §Perf hypothesis loop (no real hardware: the lowered
IR is the profile, per the brief).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")

import argparse
import json
from collections import defaultdict


def attribute(arch, shape_name, mesh_name="pod1", overrides=None,
              top=15, what="mem"):
    import jax
    from repro.configs.base import get_config, SHAPES
    from repro.distributed import sharding as shd
    from repro.distributed import hlo_analysis as H
    from repro.distributed.act_sharding import use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as specs_lib, steps as steps_lib
    from repro.models import lm
    from repro.optim.adamw import adamw

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))

    shd.set_moe_expert_axes(cfg.moe_expert_axes)
    pshapes = lm.param_shapes(cfg)
    pspecs = shd.param_specs(pshapes, mesh, cfg.parallelism)
    sds = lambda t, ss: jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=jax.sharding.NamedSharding(mesh, s)),
        t, ss, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    ps = sds(pshapes, pspecs)
    n_micro = cfg.force_microbatches or shape.n_microbatches
    with mesh, use_mesh(mesh, cfg.parallelism):
        if shape.kind == "train":
            opt = adamw(1e-4)
            oshapes = jax.eval_shape(opt.init, pshapes)
            os_ = sds(oshapes, shd.opt_state_specs(oshapes, mesh, pspecs))
            inputs = specs_lib.train_input_specs(cfg, shape, mesh)
            step = steps_lib.make_train_step(cfg, opt, n_micro)
            comp = jax.jit(step, donate_argnums=(0, 1)).lower(
                ps, os_, inputs).compile()
        elif shape.kind == "prefill":
            inputs = specs_lib.prefill_input_specs(cfg, shape, mesh)
            comp = jax.jit(steps_lib.make_prefill_step(
                cfg, shape.seq_len)).lower(ps, inputs).compile()
        else:
            d = specs_lib.decode_input_specs(cfg, shape, mesh)
            comp = jax.jit(steps_lib.make_serve_step(cfg),
                           donate_argnums=(1,)).lower(
                ps, d["cache"], d["token"], d["pos"]).compile()

    comps = H.parse_hlo(comp.as_text())
    mult, fused_bodies, entry = H.computation_multipliers(comps)
    rows = []
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fused_bodies
        for op in c.ops:
            res_b, res_e = H._type_bytes_elems(op.type_str)
            if what == "coll" and op.kind in H.COLLECTIVES:
                ob = sum(H._type_bytes_elems(c.types.get(o, ""))[0]
                         for o in op.operands)
                f = 2.0 if op.kind == "all-reduce" else 1.0
                rows.append((m * ob * f, op.kind, op.type_str[:60],
                             m, cname[:48]))
            elif what == "mem" and not in_fusion and \
                    op.kind not in H._SKIP_MEM:
                ob = sum(H._type_bytes_elems(c.types.get(o, ""))[0]
                         for o in op.operands)
                rows.append((m * (ob + res_b), op.kind, op.type_str[:60],
                             m, cname[:48]))
            elif what == "flops" and op.kind in ("dot", "convolution"):
                rows.append((m * H._dot_flops(op, c), op.kind,
                             op.type_str[:60], m, cname[:48]))
    rows.sort(reverse=True)
    unit = 1e9
    total = sum(r[0] for r in rows)
    print(f"total {what}: {total/unit:.2f} G ({arch} {shape_name} "
          f"{mesh_name} overrides={overrides})")
    agg = defaultdict(float)
    for val, kind, tstr, m, cn in rows:
        agg[(kind, tstr.split('{')[0])] += val
    for (kind, t), val in sorted(agg.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {val/unit:10.2f} G  {val/total*100:5.1f}%  {kind:22s} {t}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--overrides", default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--what", default="mem", choices=["mem", "coll", "flops"])
    args = ap.parse_args()
    attribute(args.arch, args.shape, args.mesh,
              json.loads(args.overrides) if args.overrides else None,
              args.top, args.what)


if __name__ == "__main__":
    main()
