"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these. Modality frontends are stubs per the brief: the VLM gets
precomputed patch embeddings, whisper gets post-conv frame embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import batch_spec, cache_specs, to_shardings
from repro.models import lm


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def train_input_specs(cfg: ArchConfig, shape: ShapeCell,
                      mesh: Mesh | None = None) -> Dict:
    B, T = shape.global_batch, shape.seq_len
    bs = batch_spec(mesh, B, profile=cfg.parallelism) \
        if mesh is not None else None
    sp = lambda extra=1: (bs if extra == 1
                          else P(*(tuple(bs)[:1] + (None,) * extra))) \
        if mesh is not None else None
    out = {
        "tokens": _sds((B, T), jnp.int32, mesh, bs),
        "labels": _sds((B, T), jnp.int32, mesh, bs),
    }
    if cfg.family == "vlm":
        out["vision"] = _sds(
            (B, cfg.n_vision_tokens, cfg.d_model), cfg.dtype(), mesh,
            batch_spec(mesh, B, 2, profile=cfg.parallelism)
            if mesh else None)
    if cfg.family == "encdec":
        out["frames"] = _sds(
            (B, cfg.n_audio_frames, cfg.d_model), cfg.dtype(), mesh,
            batch_spec(mesh, B, 2, profile=cfg.parallelism)
            if mesh else None)
    return out


def prefill_input_specs(cfg: ArchConfig, shape: ShapeCell,
                        mesh: Mesh | None = None) -> Dict:
    specs = train_input_specs(cfg, shape, mesh)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeCell,
                       mesh: Mesh | None = None) -> Dict:
    """-> {token, pos, cache} specs for one serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, S))
    if mesh is not None:
        cspecs = cache_specs(cache_shapes, mesh, B)
        cache = jax.tree_util.tree_map(
            lambda l, s: _sds(l.shape, l.dtype, mesh, s),
            cache_shapes, cspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    else:
        cache = cache_shapes
    token = _sds((B,), jnp.int32, mesh,
                 batch_spec(mesh, B, 0, profile=cfg.parallelism)
                 if mesh else None)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"token": token, "pos": pos, "cache": cache}
