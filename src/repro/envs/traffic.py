"""Traffic-grid domain (paper §5.2), as a pure-JAX cellular automaton.

Global simulator (GS): a G x G grid of intersections (paper: 5x5 = 25). Each
intersection has four incoming lanes of L cells, indexed by direction of
travel d: 0=southbound, 1=northbound, 2=westbound, 3=eastbound. Cars advance
one cell per step when the next cell is free; at the stop line they cross iff
their approach has green and the downstream tail cell is free, entering the
same-direction lane of the neighbouring intersection (no turning — the
paper's influence structure only needs through traffic). Boundary lanes
inject cars with prob ``p_in`` (paper uses 0.1, App. E). Non-agent lights run
an actuated queue-comparison controller (stand-in for the Flow-optimized
controllers); the agent sets its intersection's phase each step.

Local simulator (LS): only the agent's four incoming lanes. Cars enter the
tails according to the influence sources u_t (4 bits — exactly the paper's
"car entering from each of the four incoming lanes"); crossing cars leave the
local region (open boundary).

d-set (paper: 37-bit car-location vector, lights EXCLUDED to avoid the App. B
spurious correlation): occupancy of the 4 incoming lanes = 4L bits.
``dset_full`` appends the light phase (the confounder) for the ablation.

Multi-agent (Distributed IALS): ``make_multi_traffic_env(cfg, agents)`` puts
an agent at every listed intersection — agent coordinates are ordinary traced
int arrays, so the per-agent obs/reward/u/d-set extraction is a ``vmap`` over
them and the whole grid (up to all G*G intersections) steps in one program.

``ext_influence`` widens u_t from 4 to 8 bits: the extra 4 bits mark "the
downstream tail of lane d is occupied" — the congestion feedback the 4-bit
paper version ignores. With them the LS replay of a GS rollout is *exact*
(same obs/reward sequence given the true u_t), which is what the GS<->LS
consistency tests check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .api import (BatchedEnv, BatchedLocalEnv, Env, EnvSpec, LocalEnv,
                  squeeze_agent_env)


@dataclass(frozen=True)
class TrafficConfig:
    grid: int = 5
    lane_len: int = 10
    p_in: float = 0.1
    agent: Tuple[int, int] = (2, 2)
    min_phase: int = 2          # actuated controller hysteresis (steps)
    queue_window: int = 5       # cells from stop line counted as queue
    ext_influence: bool = False  # 8-bit u_t (+4 downstream-blocked bits)


class TrafficState(NamedTuple):
    lanes: jax.Array   # (G, G, 4, L) bool occupancy
    phase: jax.Array   # (G, G) int8: 0 = NS green (d 0,1), 1 = EW green
    timer: jax.Array   # (G, G) int32 steps since last switch


class LocalTrafficState(NamedTuple):
    lanes: jax.Array   # (4, L) bool
    phase: jax.Array   # () int8 (agent's own light, part of x_t)


def _green(phase, G):
    """(G,G) phase -> (G,G,4) approach-green mask."""
    ns = (phase == 0)
    return jnp.stack([ns, ns, ~ns, ~ns], axis=-1)


def _advance_lane(occ, can_cross):
    """One lane (..., L) synchronous advance. Returns (new_occ, moved_mask,
    crossed).

    Closed form of the backward induction
        moved[L-1] = occ[L-1] & can_cross
        moved[c]   = occ[c] & (~occ[c+1] | moved[c+1]):
    a car moves iff some cell strictly ahead is free, or everything ahead
    is occupied and the stop-line car crosses. The suffix-OR is log2(L)
    rounds of shift-and-or on the boolean lane — O(log L) fused ops
    instead of an L-stage dependent chain, which matters because this runs
    per tick in every simulator's hot loop (GS and LS alike)."""
    L = occ.shape[-1]
    g = ~occ                                  # suffix-OR of free cells
    s = 1
    while s < L:
        g = g.at[..., :L - s].set(g[..., :L - s] | g[..., s:])
        s *= 2
    gap = jnp.concatenate(                    # a free cell strictly ahead
        [g[..., 1:], jnp.zeros_like(g[..., :1])], axis=-1)
    moved = occ & (gap | can_cross[..., None])
    stay = occ & ~moved
    shifted = jnp.concatenate(
        [jnp.zeros_like(occ[..., :1]), moved[..., :-1]], axis=-1)
    return stay | shifted, moved, moved[..., -1]


# directions: 0 south(+i), 1 north(-i), 2 west(-j), 3 east(+j)
_DI = (1, -1, 0, 0)
_DJ = (0, 0, -1, 1)


def local_traffic_state(state: TrafficState, i, j) -> LocalTrafficState:
    """Local view of a global state at intersection (i, j). ``i``/``j`` may
    be traced, so this vmaps over a vector of agent coordinates."""
    return LocalTrafficState(lanes=state.lanes[i, j], phase=state.phase[i, j])


def make_multi_traffic_env(cfg: TrafficConfig, agents) -> Env:
    """GS with an agent at every listed intersection.

    ``agents``: (A, 2) int array of (i, j) coordinates. ``step`` takes (A,)
    actions; obs / reward / info leaves carry a leading agent axis.
    """
    G, L = cfg.grid, cfg.lane_len
    agents = jnp.asarray(agents, jnp.int32)
    A = agents.shape[0]
    ais, ajs = agents[:, 0], agents[:, 1]
    agent_mask = jnp.zeros((G, G), bool).at[ais, ajs].set(True)
    M = 8 if cfg.ext_influence else 4
    spec = EnvSpec(name="traffic-gs-multi", obs_dim=4 * L + 1, n_actions=2,
                   n_influence=M, dset_dim=4 * L, dset_full_dim=4 * L + 1,
                   n_agents=A)

    def observe(state: TrafficState):
        def one(i, j):
            local = state.lanes[i, j].reshape(-1).astype(jnp.float32)
            return jnp.concatenate(
                [local, state.phase[i, j][None].astype(jnp.float32)])
        return jax.vmap(one)(ais, ajs)

    def reset(key):
        k1, k2 = jax.random.split(key)
        lanes = jax.random.bernoulli(k1, 0.15, (G, G, 4, L))
        phase = jax.random.randint(k2, (G, G), 0, 2).astype(jnp.int8)
        return TrafficState(lanes=lanes, phase=phase,
                            timer=jnp.zeros((G, G), jnp.int32))

    def step(state: TrafficState, actions, key):
        lanes, phase, timer = state
        phase = phase.at[ais, ajs].set(actions.astype(jnp.int8))
        green = _green(phase, G)

        # crossing feasibility: downstream tail must be free (edges exit)
        dest_free = jnp.ones((G, G, 4), bool)
        for d in range(4):
            tails = lanes[:, :, d, 0]
            rolled = jnp.roll(tails, shift=(-_DI[d], -_DJ[d]), axis=(0, 1))
            free = ~rolled
            if d == 0:
                free = free.at[G - 1, :].set(True)
            elif d == 1:
                free = free.at[0, :].set(True)
            elif d == 2:
                free = free.at[:, 0].set(True)
            else:
                free = free.at[:, G - 1].set(True)
            dest_free = dest_free.at[:, :, d].set(free)

        new_lanes, moved, crossed = _advance_lane(lanes, green & dest_free)

        # injections: crossings arriving from upstream, else boundary inflow
        inj = jnp.zeros((G, G, 4), bool)
        key, kin = jax.random.split(key)
        inflow = jax.random.bernoulli(kin, cfg.p_in, (G, G, 4))
        for d in range(4):
            arriving = jnp.roll(crossed[:, :, d], shift=(_DI[d], _DJ[d]),
                                axis=(0, 1))
            boundary = jnp.zeros((G, G), bool)
            if d == 0:
                arriving = arriving.at[0, :].set(False)
                boundary = boundary.at[0, :].set(True)
            elif d == 1:
                arriving = arriving.at[G - 1, :].set(False)
                boundary = boundary.at[G - 1, :].set(True)
            elif d == 2:
                arriving = arriving.at[:, G - 1].set(False)
                boundary = boundary.at[:, G - 1].set(True)
            else:
                arriving = arriving.at[:, 0].set(False)
                boundary = boundary.at[:, 0].set(True)
            inj = inj.at[:, :, d].set(
                arriving | (boundary & inflow[:, :, d]))
        tail_free = ~new_lanes[:, :, :, 0]
        inj = inj & tail_free
        new_lanes = new_lanes.at[:, :, :, 0].set(
            new_lanes[:, :, :, 0] | inj)

        # actuated controllers (non-agent intersections)
        q = lanes[:, :, :, L - cfg.queue_window:].sum(-1)       # (G,G,4)
        q_ns, q_ew = q[..., 0] + q[..., 1], q[..., 2] + q[..., 3]
        green_q = jnp.where(phase == 0, q_ns, q_ew)
        red_q = jnp.where(phase == 0, q_ew, q_ns)
        want_switch = (red_q > green_q) & (timer >= cfg.min_phase)
        new_phase = jnp.where(want_switch, 1 - phase, phase).astype(jnp.int8)
        new_timer = jnp.where(want_switch, 0, timer + 1)
        new_phase = jnp.where(agent_mask, phase, new_phase).astype(jnp.int8)
        new_timer = jnp.where(agent_mask, 0, new_timer)

        new_state = TrafficState(lanes=new_lanes, phase=new_phase,
                                 timer=new_timer)

        def view(i, j):
            # reward: average speed over this agent's incoming lanes
            n_cars = lanes[i, j].sum()
            n_moved = moved[i, j].sum()
            reward = jnp.where(n_cars > 0,
                               n_moved / jnp.maximum(n_cars, 1), 1.0)
            dset = lanes[i, j].reshape(-1).astype(jnp.float32)   # x_t
            u = inj[i, j].astype(jnp.float32)                    # u_t (4,)
            if cfg.ext_influence:
                u = jnp.concatenate(
                    [u, (~dest_free[i, j]).astype(jnp.float32)])
            obs = jnp.concatenate(
                [new_lanes[i, j].reshape(-1).astype(jnp.float32),
                 new_phase[i, j][None].astype(jnp.float32)])
            info = {
                "u": u,
                "dset": dset,
                "dset_full": jnp.concatenate(
                    [dset, phase[i, j][None].astype(jnp.float32)]),
                "n_cars": n_cars,
            }
            return obs, reward, info

        obs, reward, info = jax.vmap(view)(ais, ajs)
        return new_state, obs, reward, info

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_multi_traffic_env(cfg: TrafficConfig,
                                   agents) -> BatchedEnv:
    """Natively batched multi-agent GS: B whole G x G grids advance as one
    vectorized program — state leaves carry a leading (B,) env axis, every
    tick draws its boundary inflow with a single bulk Bernoulli call, and
    per-agent extraction is one vmap over the agent list (out axis 1, so
    obs/reward/info leaves are (B, A, ...)).

    Same dynamics as ``make_multi_traffic_env``; with ``p_in == 0`` (the
    only internal randomness switched off) the two agree exactly, which is
    what the engine-vs-engine parity tests pin down. This is what makes
    the ``gs-multi`` benchmark row an engine-vs-engine comparison instead
    of engine-vs-vmap-of-scalar."""
    G, L = cfg.grid, cfg.lane_len
    agents = jnp.asarray(agents, jnp.int32)
    A = agents.shape[0]
    ais, ajs = agents[:, 0], agents[:, 1]
    agent_mask = jnp.zeros((G, G), bool).at[ais, ajs].set(True)
    M = 8 if cfg.ext_influence else 4
    spec = EnvSpec(name="traffic-gs-multi-b", obs_dim=4 * L + 1,
                   n_actions=2, n_influence=M, dset_dim=4 * L,
                   dset_full_dim=4 * L + 1, n_agents=A)

    def observe(state: TrafficState):
        B = state.lanes.shape[0]

        def one(i, j):
            local = state.lanes[:, i, j].reshape(B, -1).astype(jnp.float32)
            return jnp.concatenate(
                [local, state.phase[:, i, j, None].astype(jnp.float32)],
                axis=-1)

        return jax.vmap(one, out_axes=1)(ais, ajs)      # (B, A, obs)

    def reset(key, n_envs: int):
        k1, k2 = jax.random.split(key)
        lanes = jax.random.bernoulli(k1, 0.15, (n_envs, G, G, 4, L))
        phase = jax.random.randint(k2, (n_envs, G, G), 0, 2
                                   ).astype(jnp.int8)
        return TrafficState(lanes=lanes, phase=phase,
                            timer=jnp.zeros((n_envs, G, G), jnp.int32))

    def noise_fn(key, n_envs: int):
        kin = jax.random.split(key)[1]
        return jax.random.bernoulli(kin, cfg.p_in, (n_envs, G, G, 4))

    def step_det(state: TrafficState, actions, inflow):
        lanes, phase, timer = state       # (B,G,G,4,L), (B,G,G), (B,G,G)
        B = lanes.shape[0]
        phase = phase.at[:, ais, ajs].set(actions.astype(jnp.int8))
        green = _green(phase, G)                         # (B, G, G, 4)

        # crossing feasibility: downstream tail must be free (edges exit)
        dest_free = jnp.ones((B, G, G, 4), bool)
        for d in range(4):
            tails = lanes[:, :, :, d, 0]
            rolled = jnp.roll(tails, shift=(-_DI[d], -_DJ[d]), axis=(1, 2))
            free = ~rolled
            if d == 0:
                free = free.at[:, G - 1, :].set(True)
            elif d == 1:
                free = free.at[:, 0, :].set(True)
            elif d == 2:
                free = free.at[:, :, 0].set(True)
            else:
                free = free.at[:, :, G - 1].set(True)
            dest_free = dest_free.at[:, :, :, d].set(free)

        new_lanes, moved, crossed = _advance_lane(lanes, green & dest_free)

        # injections: crossings arriving from upstream, else boundary
        # inflow — drawn for the whole batch in ``noise_fn``
        inj = jnp.zeros((B, G, G, 4), bool)
        for d in range(4):
            arriving = jnp.roll(crossed[:, :, :, d],
                                shift=(_DI[d], _DJ[d]), axis=(1, 2))
            boundary = jnp.zeros((G, G), bool)
            if d == 0:
                arriving = arriving.at[:, 0, :].set(False)
                boundary = boundary.at[0, :].set(True)
            elif d == 1:
                arriving = arriving.at[:, G - 1, :].set(False)
                boundary = boundary.at[G - 1, :].set(True)
            elif d == 2:
                arriving = arriving.at[:, :, G - 1].set(False)
                boundary = boundary.at[:, G - 1].set(True)
            else:
                arriving = arriving.at[:, :, 0].set(False)
                boundary = boundary.at[:, 0].set(True)
            inj = inj.at[:, :, :, d].set(
                arriving | (boundary & inflow[:, :, :, d]))
        tail_free = ~new_lanes[:, :, :, :, 0]
        inj = inj & tail_free
        new_lanes = new_lanes.at[:, :, :, :, 0].set(
            new_lanes[:, :, :, :, 0] | inj)

        # actuated controllers (non-agent intersections)
        q = lanes[:, :, :, :, L - cfg.queue_window:].sum(-1)   # (B,G,G,4)
        q_ns, q_ew = q[..., 0] + q[..., 1], q[..., 2] + q[..., 3]
        green_q = jnp.where(phase == 0, q_ns, q_ew)
        red_q = jnp.where(phase == 0, q_ew, q_ns)
        want_switch = (red_q > green_q) & (timer >= cfg.min_phase)
        new_phase = jnp.where(want_switch, 1 - phase,
                              phase).astype(jnp.int8)
        new_timer = jnp.where(want_switch, 0, timer + 1)
        new_phase = jnp.where(agent_mask, phase, new_phase).astype(jnp.int8)
        new_timer = jnp.where(agent_mask, 0, new_timer)

        new_state = TrafficState(lanes=new_lanes, phase=new_phase,
                                 timer=new_timer)

        def view(i, j):
            n_cars = lanes[:, i, j].sum(axis=(1, 2))
            n_moved = moved[:, i, j].sum(axis=(1, 2))
            reward = jnp.where(n_cars > 0,
                               n_moved / jnp.maximum(n_cars, 1), 1.0)
            dset = lanes[:, i, j].reshape(B, -1).astype(jnp.float32)
            u = inj[:, i, j].astype(jnp.float32)
            if cfg.ext_influence:
                u = jnp.concatenate(
                    [u, (~dest_free[:, i, j]).astype(jnp.float32)],
                    axis=-1)
            obs = jnp.concatenate(
                [new_lanes[:, i, j].reshape(B, -1).astype(jnp.float32),
                 new_phase[:, i, j, None].astype(jnp.float32)], axis=-1)
            info = {
                "u": u,
                "dset": dset,
                "dset_full": jnp.concatenate(
                    [dset, phase[:, i, j, None].astype(jnp.float32)],
                    axis=-1),
                "n_cars": n_cars,
            }
            return obs, reward, info

        obs, reward, info = jax.vmap(view, out_axes=1)(ais, ajs)
        return new_state, obs, reward, info

    def step(state: TrafficState, actions, key):
        return step_det(state, actions,
                        noise_fn(key, state.lanes.shape[0]))

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe,
                      noise_fn=noise_fn, step_det=step_det)


def make_traffic_env(cfg: TrafficConfig = TrafficConfig()):
    """Single-agent GS: the multi-agent env at ``cfg.agent``, squeezed."""
    multi = make_multi_traffic_env(cfg, jnp.array([cfg.agent], jnp.int32))
    return squeeze_agent_env(multi, "traffic-gs")


def make_local_traffic_env(cfg: TrafficConfig = TrafficConfig()):
    """LS: the agent's 4 incoming lanes; u_t drives boundary injection (and,
    with ``ext_influence``, blocks crossing on congested downstream tails)."""
    L = cfg.lane_len
    M = 8 if cfg.ext_influence else 4
    spec = EnvSpec(name="traffic-ls", obs_dim=4 * L + 1, n_actions=2,
                   n_influence=M, dset_dim=4 * L, dset_full_dim=4 * L + 1)

    def observe(state: LocalTrafficState):
        return jnp.concatenate(
            [state.lanes.reshape(-1).astype(jnp.float32),
             state.phase[None].astype(jnp.float32)])

    def reset(key):
        lanes = jax.random.bernoulli(key, 0.15, (4, L))
        return LocalTrafficState(lanes=lanes, phase=jnp.int8(0))

    def step(state: LocalTrafficState, action, u, key):
        lanes = state.lanes
        phase = action.astype(jnp.int8)
        ns = (phase == 0)
        green = jnp.stack([ns, ns, ~ns, ~ns])                    # (4,)
        # crossing cars exit the local region freely (open boundary) unless
        # the 8-bit u_t marks the downstream tail as occupied
        can_cross = green
        if cfg.ext_influence:
            can_cross = green & ~u[4:].astype(bool)
        new_lanes, moved, _ = _advance_lane(lanes, can_cross)
        inj = u[:4].astype(bool) & ~new_lanes[:, 0]
        new_lanes = new_lanes.at[:, 0].set(new_lanes[:, 0] | inj)

        n_cars = lanes.sum()
        n_moved = moved.sum()
        reward = jnp.where(n_cars > 0, n_moved / jnp.maximum(n_cars, 1), 1.0)
        new_state = LocalTrafficState(lanes=new_lanes, phase=phase)
        dset = lanes.reshape(-1).astype(jnp.float32)
        info = {"dset": dset,
                "dset_full": jnp.concatenate(
                    [dset, state.phase[None].astype(jnp.float32)]),
                "n_cars": n_cars}
        return new_state, observe(new_state), reward, info

    def dset_fn(state: LocalTrafficState, action):
        return state.lanes.reshape(-1).astype(jnp.float32)

    return LocalEnv(spec=spec, reset=reset, step=step, observe=observe,
                    dset_fn=dset_fn)


def make_batched_local_traffic_env(
        cfg: TrafficConfig = TrafficConfig()) -> BatchedLocalEnv:
    """Natively batched LS: every leaf carries a leading (B,) env axis and
    one step is one vectorized lane advance for the whole batch — the fused
    IALS rollout engine's transition. Same dynamics as
    ``make_local_traffic_env`` (the traffic LS draws no randomness of its
    own, so batched and vmapped-scalar steps agree exactly, ``noise_fn``
    is leafless, and ``rollout_tick`` — the transition+reward core the
    whole-horizon kernel inlines — is pure boolean lane algebra)."""
    L = cfg.lane_len
    M = 8 if cfg.ext_influence else 4
    spec = EnvSpec(name="traffic-ls-b", obs_dim=4 * L + 1, n_actions=2,
                   n_influence=M, dset_dim=4 * L, dset_full_dim=4 * L + 1)

    def observe(state: LocalTrafficState):
        B = state.lanes.shape[0]
        return jnp.concatenate(
            [state.lanes.reshape(B, -1).astype(jnp.float32),
             state.phase[:, None].astype(jnp.float32)], axis=-1)

    def reset(key, n_envs: int):
        lanes = jax.random.bernoulli(key, 0.15, (n_envs, 4, L))
        return LocalTrafficState(
            lanes=lanes, phase=jnp.zeros((n_envs,), jnp.int8))

    def noise_fn(key, n_envs: int):
        return None          # the traffic LS is deterministic given u_t

    def rollout_tick(state: LocalTrafficState, actions, u, noise):
        del noise
        lanes = state.lanes                              # (B, 4, L)
        phase = actions.astype(jnp.int8)                 # (B,)
        ns = (phase == 0)[:, None]
        green = jnp.concatenate([ns, ns, ~ns, ~ns], axis=-1)   # (B, 4)
        can_cross = green
        if cfg.ext_influence:
            can_cross = green & ~u[:, 4:].astype(bool)
        new_lanes, moved, _ = _advance_lane(lanes, can_cross)
        inj = u[:, :4].astype(bool) & ~new_lanes[:, :, 0]
        new_lanes = new_lanes.at[:, :, 0].set(new_lanes[:, :, 0] | inj)

        n_cars = lanes.sum(axis=(1, 2))
        n_moved = moved.sum(axis=(1, 2))
        reward = jnp.where(n_cars > 0,
                           n_moved / jnp.maximum(n_cars, 1), 1.0)
        return LocalTrafficState(lanes=new_lanes, phase=phase), reward

    def step_det(state: LocalTrafficState, actions, u, noise):
        new_state, reward = rollout_tick(state, actions, u, noise)
        lanes = state.lanes
        B = lanes.shape[0]
        dset = lanes.reshape(B, -1).astype(jnp.float32)
        info = {"dset": dset,
                "dset_full": jnp.concatenate(
                    [dset, state.phase[:, None].astype(jnp.float32)],
                    axis=-1),
                "n_cars": lanes.sum(axis=(1, 2))}
        return new_state, observe(new_state), reward, info

    def step(state: LocalTrafficState, actions, u, key):
        return step_det(state, actions, u,
                        noise_fn(key, state.lanes.shape[0]))

    def dset_fn(state: LocalTrafficState, actions):
        B = state.lanes.shape[0]
        return state.lanes.reshape(B, -1).astype(jnp.float32)

    return BatchedLocalEnv(spec=spec, reset=reset, step=step,
                           observe=observe, dset_fn=dset_fn,
                           noise_fn=noise_fn, step_det=step_det,
                           rollout_tick=rollout_tick,
                           # reshape + astype + concat only: already
                           # kernel-safe, so the policy-rollout kernel
                           # traces the real observe
                           obs_fn=observe)
