"""Warehouse-commissioning domain (paper §5.3), pure JAX.

A grid of R x R robots (paper: 36), each confined to a 5x5 region. The 12
item cells of a region sit on its edges and are SHARED with the neighbouring
region (paper Fig. 4): globally the items live on horizontal shelf segments
``items_h (R+1, R, 3)`` and vertical segments ``items_v (R, R+1, 3)``. Items
appear with prob 0.02, age every step, and are collected when a robot steps
onto them. Scripted ("blue") robots greedily chase the oldest active item in
their region. The agent ("purple") robot is trained; it sees a 25-bit
position bitmap + its region's 12 item bits, but NOT the neighbour robots —
their effect arrives only through items vanishing = the influence sources.

u_t (12 bits): for each of the agent's item cells, whether a neighbour robot
sits on that (shared) cell after this step's moves — the IALS removes such
items ("that item is removed and the purple robot can no longer collect it").

d-set (paper §5.3.1): the 12 item bits + 12 bits "agent was/is at that item
cell" (distinguishes own pickups from neighbour pickups). The agent's full
location-history bitmap is the confounder left out; ``dset_full`` includes it
for the App. B-style ablation.

``vanish_after`` (paper §5.4): items disappear after exactly k steps
(default 0 = disabled) — the finite-memory experiment's modified dynamics.

Multi-agent (Distributed IALS): ``make_multi_warehouse_env(cfg, agents)``
trains the robot of every listed region — the rest stay scripted. Agent
coordinates are traced int arrays; the per-agent extraction vmaps over them,
so the full 6x6 = 36-robot floor steps as one program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .api import (BatchedEnv, BatchedLocalEnv, Env, EnvSpec, LocalEnv,
                  squeeze_agent_env)

# item cell coordinates inside a 5x5 region, in fixed order:
# top edge (0,1..3), bottom (4,1..3), left (1..3,0), right (1..3,4)
_ITEM_RC = tuple(
    [(0, c) for c in (1, 2, 3)] + [(4, c) for c in (1, 2, 3)] +
    [(r, 0) for r in (1, 2, 3)] + [(r, 4) for r in (1, 2, 3)])


@dataclass(frozen=True)
class WarehouseConfig:
    grid: int = 6               # R x R robots (6x6 = 36)
    region: int = 5
    p_item: float = 0.02
    agent: Tuple[int, int] = (2, 2)
    vanish_after: int = 0       # >0: §5.4 deterministic disappearance
    max_age: int = 64


class WarehouseState(NamedTuple):
    pos: jax.Array       # (R, R, 2) int32 robot positions (region coords)
    items_h: jax.Array   # (R+1, R, 3) int32 age+1 of active item, 0=empty
    items_v: jax.Array   # (R, R+1, 3) int32


class LocalWarehouseState(NamedTuple):
    pos: jax.Array       # (2,) int32
    items: jax.Array     # (12,) int32 age+1, 0 = empty


def _region_items(items_h, items_v, i, j):
    """-> (12,) ages for region (i, j), in _ITEM_RC order."""
    return jnp.concatenate([
        items_h[i, j], items_h[i + 1, j], items_v[i, j], items_v[i, j + 1]])


def _set_region_items(items_h, items_v, i, j, vals):
    items_h = items_h.at[i, j].set(vals[0:3])
    items_h = items_h.at[i + 1, j].set(vals[3:6])
    items_v = items_v.at[i, j].set(vals[6:9])
    items_v = items_v.at[i, j + 1].set(vals[9:12])
    return items_h, items_v


_ITEM_R = jnp.array([rc[0] for rc in _ITEM_RC])
_ITEM_C = jnp.array([rc[1] for rc in _ITEM_RC])

# actions: 0 stay, 1 up(-r), 2 down(+r), 3 left(-c), 4 right(+c)
_MOVE = jnp.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])


def _greedy_action(pos, ages):
    """Scripted policy: L1-greedy toward the oldest active item (12,)."""
    has = ages > 0
    target = jnp.argmax(jnp.where(has, ages, -1))
    tr, tc = _ITEM_R[target], _ITEM_C[target]
    dr, dc = tr - pos[0], tc - pos[1]
    act = jnp.where(dr < 0, 1, jnp.where(dr > 0, 2,
                    jnp.where(dc < 0, 3, jnp.where(dc > 0, 4, 0))))
    return jnp.where(has.any(), act, 0)


def _at_item_mask(pos):
    """(12,) bool: which item cells the robot at ``pos`` stands on."""
    return (_ITEM_R == pos[0]) & (_ITEM_C == pos[1])


def _obs_from(pos, ages, region):
    bitmap = jnp.zeros((region, region), jnp.float32).at[
        pos[0], pos[1]].set(1.0).reshape(-1)
    return jnp.concatenate([bitmap, (ages > 0).astype(jnp.float32)])


def local_warehouse_state(state: WarehouseState, i, j) -> LocalWarehouseState:
    """Local view of a global state for region (i, j). ``i``/``j`` may be
    traced, so this vmaps over a vector of agent coordinates."""
    return LocalWarehouseState(
        pos=state.pos[i, j],
        items=_region_items(state.items_h, state.items_v, i, j))


def make_multi_warehouse_env(cfg: WarehouseConfig, agents) -> Env:
    """GS with a trained agent in every listed region.

    ``agents``: (A, 2) int array of region coordinates. ``step`` takes (A,)
    actions; obs / reward / info leaves carry a leading agent axis.
    """
    R, S = cfg.grid, cfg.region
    agents = jnp.asarray(agents, jnp.int32)
    A = agents.shape[0]
    ais, ajs = agents[:, 0], agents[:, 1]
    nobs = S * S + 12
    spec = EnvSpec(name="warehouse-gs-multi", obs_dim=nobs, n_actions=5,
                   n_influence=12, dset_dim=24, dset_full_dim=24 + S * S,
                   n_agents=A)

    def observe(state: WarehouseState):
        def one(i, j):
            ages = _region_items(state.items_h, state.items_v, i, j)
            return _obs_from(state.pos[i, j], ages, S)
        return jax.vmap(one)(ais, ajs)

    def reset(key):
        k1, k2, k3 = jax.random.split(key, 3)
        pos = jax.random.randint(k1, (R, R, 2), 0, S)
        items_h = (jax.random.bernoulli(k2, 0.3, (R + 1, R, 3))
                   ).astype(jnp.int32)
        items_v = (jax.random.bernoulli(k3, 0.3, (R, R + 1, 3))
                   ).astype(jnp.int32)
        return WarehouseState(pos=pos, items_h=items_h, items_v=items_v)

    ii, jj = jnp.meshgrid(jnp.arange(R), jnp.arange(R), indexing="ij")

    def step(state: WarehouseState, actions, key):
        pos, items_h, items_v = state

        # all regions' item views (R, R, 12)
        region_ages = jax.vmap(jax.vmap(
            lambda i, j: _region_items(items_h, items_v, i, j)))(ii, jj)

        # scripted actions for every robot; agents overridden
        acts = jax.vmap(jax.vmap(_greedy_action))(pos, region_ages)
        acts = acts.at[ais, ajs].set(actions.astype(acts.dtype))

        new_pos = jnp.clip(pos + _MOVE[acts], 0, S - 1)

        # pickups: robot on an item cell collects it. Build a global
        # "robot standing here" count per shelf cell from all regions.
        at_mask = jax.vmap(jax.vmap(_at_item_mask))(new_pos)   # (R,R,12)
        occ_h = jnp.zeros((R + 1, R, 3), jnp.int32)
        occ_v = jnp.zeros((R, R + 1, 3), jnp.int32)
        # scatter each region's 12-bit mask onto the global shelves
        occ_h = occ_h.at[ii, jj].add(at_mask[:, :, 0:3].astype(jnp.int32))
        occ_h = occ_h.at[ii + 1, jj].add(at_mask[:, :, 3:6].astype(jnp.int32))
        occ_v = occ_v.at[ii, jj].add(at_mask[:, :, 6:9].astype(jnp.int32))
        occ_v = occ_v.at[ii, jj + 1].add(
            at_mask[:, :, 9:12].astype(jnp.int32))

        collected_h = (occ_h > 0) & (items_h > 0)
        collected_v = (occ_v > 0) & (items_v > 0)

        # age / vanish / spawn
        key, kh, kv = jax.random.split(key, 3)
        def upd(items, collected, kk):
            items = jnp.where(collected, 0, items)
            items = jnp.where(items > 0,
                              jnp.minimum(items + 1, cfg.max_age), 0)
            if cfg.vanish_after > 0:
                items = jnp.where(items > cfg.vanish_after, 0, items)
            spawn = jax.random.bernoulli(kk, cfg.p_item, items.shape)
            return jnp.where((items == 0) & spawn, 1, items)
        new_h = upd(items_h, collected_h, kh)
        new_v = upd(items_v, collected_v, kv)

        new_state = WarehouseState(pos=new_pos, items_h=new_h, items_v=new_v)

        def view(i, j):
            ages_before = region_ages[i, j]
            agent_at = _at_item_mask(new_pos[i, j])
            # agent reward: items the agent itself stands on (active ones)
            reward = jnp.sum(agent_at & (ages_before > 0)).astype(jnp.float32)

            # influence sources: neighbour robots standing on the agent's
            # cells (exclude the agent's own occupancy)
            occ_agent_region = jnp.concatenate([
                occ_h[i, j], occ_h[i + 1, j],
                occ_v[i, j], occ_v[i, j + 1]])
            u = ((occ_agent_region - agent_at.astype(jnp.int32)) > 0)
            if cfg.vanish_after > 0:
                # §5.4 variant: the influence event is the deterministic
                # disappearance itself (age hit the limit this step)
                u = u | (ages_before >= cfg.vanish_after)

            at_before = _at_item_mask(pos[i, j])
            dset = jnp.concatenate(
                [(ages_before > 0).astype(jnp.float32),
                 (at_before | agent_at).astype(jnp.float32)])
            bitmap = jnp.zeros((S, S), jnp.float32).at[
                pos[i, j, 0], pos[i, j, 1]].set(1.0).reshape(-1)
            obs = _obs_from(new_pos[i, j],
                            _region_items(new_h, new_v, i, j), S)
            info = {"u": u.astype(jnp.float32), "dset": dset,
                    "dset_full": jnp.concatenate([dset, bitmap]),
                    "ages": ages_before}
            return obs, reward, info

        obs, reward, info = jax.vmap(view)(ais, ajs)
        return new_state, obs, reward, info

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_multi_warehouse_env(cfg: WarehouseConfig,
                                     agents) -> BatchedEnv:
    """Natively batched multi-agent GS: B whole warehouse floors advance as
    one vectorized program. The scripted-robot policy, pickups, and item
    updates are written with an explicit (B,) leading axis (no vmap of the
    scalar step), both shelf spawns come from one bulk Bernoulli pair per
    tick (``noise_fn``), and per-agent extraction is a single vmap over the
    agent list (out axis 1 -> (B, A, ...) leaves).

    Same dynamics as ``make_multi_warehouse_env``; with ``p_item == 0``
    (the only internal randomness switched off) the two agree exactly —
    the engine-vs-engine parity tests pin this. The ``gs-multi`` benchmark
    row steps this construction."""
    R, S = cfg.grid, cfg.region
    agents = jnp.asarray(agents, jnp.int32)
    A = agents.shape[0]
    ais, ajs = agents[:, 0], agents[:, 1]
    nobs = S * S + 12
    spec = EnvSpec(name="warehouse-gs-multi-b", obs_dim=nobs, n_actions=5,
                   n_influence=12, dset_dim=24, dset_full_dim=24 + S * S,
                   n_agents=A)

    def _region_ages_all(items_h, items_v):
        """(B, R+1, R, 3)/(B, R, R+1, 3) shelves -> (B, R, R, 12) per-
        region ages in _ITEM_RC order (top, bottom, left, right)."""
        return jnp.concatenate(
            [items_h[:, :R], items_h[:, 1:],
             items_v[:, :, :R], items_v[:, :, 1:]], axis=-1)

    def _at_masks(pos):
        """(B, R, R, 2) positions -> (B, R, R, 12) item-cell occupancy."""
        return ((_ITEM_R == pos[..., 0:1]) & (_ITEM_C == pos[..., 1:2]))

    def _bitmap(pos):
        """(B, 2) agent positions -> (B, S*S) one-hot location bitmaps."""
        B = pos.shape[0]
        return jnp.zeros((B, S, S), jnp.float32).at[
            jnp.arange(B), pos[:, 0], pos[:, 1]].set(1.0).reshape(B, -1)

    def observe(state: WarehouseState):
        ages = _region_ages_all(state.items_h, state.items_v)

        def one(i, j):
            return jnp.concatenate(
                [_bitmap(state.pos[:, i, j]),
                 (ages[:, i, j] > 0).astype(jnp.float32)], axis=-1)

        return jax.vmap(one, out_axes=1)(ais, ajs)      # (B, A, obs)

    def reset(key, n_envs: int):
        k1, k2, k3 = jax.random.split(key, 3)
        pos = jax.random.randint(k1, (n_envs, R, R, 2), 0, S)
        items_h = (jax.random.bernoulli(k2, 0.3, (n_envs, R + 1, R, 3))
                   ).astype(jnp.int32)
        items_v = (jax.random.bernoulli(k3, 0.3, (n_envs, R, R + 1, 3))
                   ).astype(jnp.int32)
        return WarehouseState(pos=pos, items_h=items_h, items_v=items_v)

    def noise_fn(key, n_envs: int):
        _, kh, kv = jax.random.split(key, 3)
        return {
            "spawn_h": jax.random.bernoulli(kh, cfg.p_item,
                                            (n_envs, R + 1, R, 3)),
            "spawn_v": jax.random.bernoulli(kv, cfg.p_item,
                                            (n_envs, R, R + 1, 3)),
        }

    def step_det(state: WarehouseState, actions, noise):
        pos, items_h, items_v = state     # (B,R,R,2), (B,R+1,R,3), ...
        B = pos.shape[0]
        region_ages = _region_ages_all(items_h, items_v)   # (B,R,R,12)

        # scripted policy for every robot, vectorized (L1-greedy toward
        # the oldest active item); agents overridden
        has = region_ages > 0
        target = jnp.argmax(jnp.where(has, region_ages, -1), axis=-1)
        tr, tc = _ITEM_R[target], _ITEM_C[target]          # (B,R,R)
        dr, dc = tr - pos[..., 0], tc - pos[..., 1]
        acts = jnp.where(dr < 0, 1, jnp.where(dr > 0, 2,
                         jnp.where(dc < 0, 3, jnp.where(dc > 0, 4, 0))))
        acts = jnp.where(has.any(-1), acts, 0)
        acts = acts.at[:, ais, ajs].set(actions.astype(acts.dtype))

        new_pos = jnp.clip(pos + _MOVE[acts], 0, S - 1)

        # pickups: per-shelf-cell robot counts via slice-adds (each shelf
        # segment is shared by the two adjacent regions)
        at_mask = _at_masks(new_pos).astype(jnp.int32)     # (B,R,R,12)
        occ_h = jnp.zeros((B, R + 1, R, 3), jnp.int32)
        occ_v = jnp.zeros((B, R, R + 1, 3), jnp.int32)
        occ_h = occ_h.at[:, :R].add(at_mask[..., 0:3])
        occ_h = occ_h.at[:, 1:].add(at_mask[..., 3:6])
        occ_v = occ_v.at[:, :, :R].add(at_mask[..., 6:9])
        occ_v = occ_v.at[:, :, 1:].add(at_mask[..., 9:12])

        collected_h = (occ_h > 0) & (items_h > 0)
        collected_v = (occ_v > 0) & (items_v > 0)

        def upd(items, collected, spawn):
            items = jnp.where(collected, 0, items)
            items = jnp.where(items > 0,
                              jnp.minimum(items + 1, cfg.max_age), 0)
            if cfg.vanish_after > 0:
                items = jnp.where(items > cfg.vanish_after, 0, items)
            return jnp.where((items == 0) & spawn, 1, items)

        new_h = upd(items_h, collected_h, noise["spawn_h"])
        new_v = upd(items_v, collected_v, noise["spawn_v"])
        new_state = WarehouseState(pos=new_pos, items_h=new_h,
                                   items_v=new_v)
        new_ages = _region_ages_all(new_h, new_v)

        def view(i, j):
            ages_before = region_ages[:, i, j]             # (B, 12)
            agent_at = _at_item_mask_b(new_pos[:, i, j])
            reward = (agent_at & (ages_before > 0)).sum(-1
                                                        ).astype(jnp.float32)
            occ_agent_region = jnp.concatenate(
                [occ_h[:, i, j], occ_h[:, i + 1, j],
                 occ_v[:, i, j], occ_v[:, i, j + 1]], axis=-1)
            u = ((occ_agent_region - agent_at.astype(jnp.int32)) > 0)
            if cfg.vanish_after > 0:
                u = u | (ages_before >= cfg.vanish_after)
            at_before = _at_item_mask_b(pos[:, i, j])
            dset = jnp.concatenate(
                [(ages_before > 0).astype(jnp.float32),
                 (at_before | agent_at).astype(jnp.float32)], axis=-1)
            obs = jnp.concatenate(
                [_bitmap(new_pos[:, i, j]),
                 (new_ages[:, i, j] > 0).astype(jnp.float32)], axis=-1)
            info = {"u": u.astype(jnp.float32), "dset": dset,
                    "dset_full": jnp.concatenate(
                        [dset, _bitmap(pos[:, i, j])], axis=-1),
                    "ages": ages_before}
            return obs, reward, info

        obs, reward, info = jax.vmap(view, out_axes=1)(ais, ajs)
        return new_state, obs, reward, info

    def step(state: WarehouseState, actions, key):
        return step_det(state, actions,
                        noise_fn(key, state.pos.shape[0]))

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe,
                      noise_fn=noise_fn, step_det=step_det)


def make_warehouse_env(cfg: WarehouseConfig = WarehouseConfig()):
    """Single-agent GS: the multi-agent env at ``cfg.agent``, squeezed."""
    multi = make_multi_warehouse_env(cfg, jnp.array([cfg.agent], jnp.int32))
    return squeeze_agent_env(multi, "warehouse-gs")


def make_local_warehouse_env(cfg: WarehouseConfig = WarehouseConfig()):
    """LS: the agent's 5x5 region only; u_t removes neighbour-taken items."""
    S = cfg.region
    nobs = S * S + 12
    spec = EnvSpec(name="warehouse-ls", obs_dim=nobs, n_actions=5,
                   n_influence=12, dset_dim=24, dset_full_dim=24 + S * S)

    def observe(state: LocalWarehouseState):
        return _obs_from(state.pos, state.items, S)

    def reset(key):
        k1, k2 = jax.random.split(key)
        pos = jax.random.randint(k1, (2,), 0, S)
        items = jax.random.bernoulli(k2, 0.3, (12,)).astype(jnp.int32)
        return LocalWarehouseState(pos=pos, items=items)

    def step(state: LocalWarehouseState, action, u, key):
        pos, items = state
        new_pos = jnp.clip(pos + _MOVE[action], 0, S - 1)
        agent_at = _at_item_mask(new_pos)
        reward = jnp.sum(agent_at & (items > 0)).astype(jnp.float32)
        collected = agent_at | (u > 0.5)           # neighbours take theirs
        new_items = jnp.where(collected, 0, items)
        new_items = jnp.where(new_items > 0,
                              jnp.minimum(new_items + 1, cfg.max_age), 0)
        if cfg.vanish_after > 0:
            new_items = jnp.where(new_items > cfg.vanish_after, 0, new_items)
        key, ks = jax.random.split(key)
        spawn = jax.random.bernoulli(ks, cfg.p_item, (12,))
        new_items = jnp.where((new_items == 0) & spawn, 1, new_items)

        new_state = LocalWarehouseState(pos=new_pos, items=new_items)
        at_before = _at_item_mask(pos)
        dset = jnp.concatenate([(items > 0).astype(jnp.float32),
                                (at_before | agent_at).astype(jnp.float32)])
        bitmap = jnp.zeros((S, S), jnp.float32).at[
            pos[0], pos[1]].set(1.0).reshape(-1)
        info = {"dset": dset,
                "dset_full": jnp.concatenate([dset, bitmap]),
                "ages": items}
        return new_state, observe(new_state), reward, info

    def dset_fn(state: LocalWarehouseState, action):
        new_pos = jnp.clip(state.pos + _MOVE[action], 0, S - 1)
        at = _at_item_mask(state.pos) | _at_item_mask(new_pos)
        return jnp.concatenate([(state.items > 0).astype(jnp.float32),
                                at.astype(jnp.float32)])

    return LocalEnv(spec=spec, reset=reset, step=step, observe=observe,
                    dset_fn=dset_fn)


def _at_item_mask_b(pos):
    """(B, 2) positions -> (B, 12) item-cell occupancy masks."""
    return (_ITEM_R[None] == pos[:, :1]) & (_ITEM_C[None] == pos[:, 1:])


def _at_item_mask_k(pos, S: int):
    """``_at_item_mask_b`` without the ``_ITEM_R``/``_ITEM_C`` constant
    tables: the 12 item-cell coordinates are rebuilt from a 2D iota
    (groups of 3 per edge, in ``_ITEM_RC`` order — top, bottom, left,
    right). Pallas kernel bodies reject captured array constants, and
    this function is traced into the whole-horizon kernel; the values
    are integer-identical to the table lookup."""
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, 12), 1)
    g, w = idx // 3, idx % 3
    r = jnp.where(g == 0, 0, jnp.where(g == 1, S - 1, w + 1))
    c = jnp.where(g == 2, 0, jnp.where(g == 3, S - 1, w + 1))
    return (r == pos[:, 0:1]) & (c == pos[:, 1:2])


def _move_delta_k(actions):
    """``_MOVE[actions]`` as a select chain (same integers, no table
    gather) — kernel-safe companion to ``_at_item_mask_k``."""
    dr = jnp.where(actions == 1, -1, jnp.where(actions == 2, 1, 0))
    dc = jnp.where(actions == 3, -1, jnp.where(actions == 4, 1, 0))
    return jnp.stack([dr, dc], axis=-1)


def make_batched_local_warehouse_env(
        cfg: WarehouseConfig = WarehouseConfig()) -> BatchedLocalEnv:
    """Natively batched LS: (B,) leading env axis on every leaf, one
    vectorized transition per tick, and the whole batch's item spawns drawn
    with a single bulk Bernoulli call — the fused IALS rollout engine's
    transition. Dynamics identical to ``make_local_warehouse_env``."""
    S = cfg.region
    nobs = S * S + 12
    spec = EnvSpec(name="warehouse-ls-b", obs_dim=nobs, n_actions=5,
                   n_influence=12, dset_dim=24, dset_full_dim=24 + S * S)

    def observe(state: LocalWarehouseState):
        B = state.pos.shape[0]
        bitmap = jnp.zeros((B, S, S), jnp.float32).at[
            jnp.arange(B), state.pos[:, 0], state.pos[:, 1]].set(1.0)
        return jnp.concatenate(
            [bitmap.reshape(B, -1),
             (state.items > 0).astype(jnp.float32)], axis=-1)

    def reset(key, n_envs: int):
        k1, k2 = jax.random.split(key)
        pos = jax.random.randint(k1, (n_envs, 2), 0, S)
        items = jax.random.bernoulli(k2, 0.3,
                                     (n_envs, 12)).astype(jnp.int32)
        return LocalWarehouseState(pos=pos, items=items)

    def noise_fn(key, n_envs: int):
        return jax.random.bernoulli(key, cfg.p_item, (n_envs, 12))

    def rollout_tick(state: LocalWarehouseState, actions, u, spawn):
        # traced into the whole-horizon Pallas kernel body: only the
        # constant-free helpers (no table gathers, no captured arrays)
        pos, items = state
        new_pos = jnp.clip(pos + _move_delta_k(actions), 0, S - 1)
        agent_at = _at_item_mask_k(new_pos, S)
        reward = (agent_at & (items > 0)).sum(-1).astype(jnp.float32)
        collected = agent_at | (u > 0.5)
        new_items = jnp.where(collected, 0, items)
        new_items = jnp.where(new_items > 0,
                              jnp.minimum(new_items + 1, cfg.max_age), 0)
        if cfg.vanish_after > 0:
            new_items = jnp.where(new_items > cfg.vanish_after, 0,
                                  new_items)
        new_items = jnp.where((new_items == 0) & spawn, 1, new_items)
        return LocalWarehouseState(pos=new_pos, items=new_items), reward

    def step_det(state: LocalWarehouseState, actions, u, spawn):
        pos, items = state
        new_state, reward = rollout_tick(state, actions, u, spawn)
        agent_at = _at_item_mask_b(new_state.pos)
        at_before = _at_item_mask_b(pos)
        dset = jnp.concatenate(
            [(items > 0).astype(jnp.float32),
             (at_before | agent_at).astype(jnp.float32)], axis=-1)
        B = pos.shape[0]
        bitmap = jnp.zeros((B, S, S), jnp.float32).at[
            jnp.arange(B), pos[:, 0], pos[:, 1]].set(1.0).reshape(B, -1)
        info = {"dset": dset,
                "dset_full": jnp.concatenate([dset, bitmap], axis=-1),
                "ages": items}
        return new_state, observe(new_state), reward, info

    def step(state: LocalWarehouseState, actions, u, key):
        return step_det(state, actions, u,
                        noise_fn(key, state.pos.shape[0]))

    def dset_fn(state: LocalWarehouseState, actions):
        # also traced into the whole-horizon kernel -> constant-free
        new_pos = jnp.clip(state.pos + _move_delta_k(actions), 0, S - 1)
        at = _at_item_mask_k(state.pos, S) | _at_item_mask_k(new_pos, S)
        return jnp.concatenate([(state.items > 0).astype(jnp.float32),
                                at.astype(jnp.float32)], axis=-1)

    def obs_fn(state: LocalWarehouseState):
        # ``observe`` without the dynamic one-hot scatter: the position
        # bitmap is rebuilt by comparing a 2D iota against the robot
        # coordinates (value-identical to the ``.at[].set`` one-hot) —
        # traced into the policy-rollout kernel per grid step
        B = state.pos.shape[0]
        idx = jax.lax.broadcasted_iota(jnp.int32, (B, S * S), 1)
        bitmap = ((idx // S == state.pos[:, 0:1])
                  & (idx % S == state.pos[:, 1:2])).astype(jnp.float32)
        return jnp.concatenate(
            [bitmap, (state.items > 0).astype(jnp.float32)], axis=-1)

    return BatchedLocalEnv(spec=spec, reset=reset, step=step,
                           observe=observe, dset_fn=dset_fn,
                           noise_fn=noise_fn, step_det=step_det,
                           rollout_tick=rollout_tick, obs_fn=obs_fn)
