"""Environment protocol: pure-function JAX environments.

Both the Global Simulator (GS) and Local Simulator (LS) of each domain expose
the same functional API, so PPO rollouts are a single ``lax.scan`` and batch
parallelism is a ``vmap`` — this is the TPU-native answer to the paper's
"make the simulator fast" premise (DESIGN.md §4).

GS step:  (state, action, key)          -> (state, obs, reward, info)
LS step:  (state, action, u_t, key)     -> (state, obs, reward, info)

Batched protocol (the fused rollout engine's native layer): ``BatchedEnv``
and ``BatchedLocalEnv`` carry a leading env-batch axis through every leaf —
``reset(key, n)`` builds n environments from ONE key, ``step`` takes (B, ...)
actions and ONE key and draws all of its randomness in bulk. This is what
lets an IALS tick be one fused AIP kernel + one vectorized LS transition
instead of a vmap of B scalar programs each splitting its own keys.
``batch_env`` / ``batch_local_env`` lift any scalar env into the batched
protocol (vmap adapter); ``unbatch_env`` squeezes a batched env back down to
the scalar signature — so both protocols interoperate everywhere.

Whole-horizon layer (see docs/ARCHITECTURE.md): a ``BatchedEnv`` may
additionally expose
  - ``noise_fn(key, n_envs)`` — draw ONE tick's worth of randomness as a
    pytree (the same derivation ``step`` performs internally), and
  - ``step_det(state, actions, noise)`` — the deterministic remainder of
    the tick, with the invariant
        step(s, a, k) == step_det(s, a, noise_fn(k, B))
    holding *bitwise*.
``env_rollout`` exploits the pair: all T ticks' randomness is drawn in bulk
outside the scan, so the scan body is pure compute — and an env may override
``rollout`` entirely (the unified IALS engine dispatches a Pallas kernel
that keeps AIP recurrent state and LS state VMEM-resident across the whole
horizon on TPU). The override contract carries the agent axis: actions are
(T, B) for a single-agent env and (T, B, A) when ``spec.n_agents = A > 1``,
rewards come back with the same trailing layout, and the (T,) keys are
shared across agents exactly as ``step`` shares them. Every path is
bitwise-equal to scanning ``step``; the overrides only change *where* the
work happens.

Actor-in-the-loop layer (the training-loop contract, see
docs/ARCHITECTURE.md): ``env_rollout`` needs the actions up front, which a
PPO rollout cannot provide (actions depend on observations mid-horizon).
``BatchedEnv.policy_rollout`` closes that gap: the env advances T ticks
with the *policy in the loop* — frame-stacked observation buffer, policy
forward pass, Gumbel-argmax action sampling (bitwise-equal to
``jax.random.categorical`` given the same pre-drawn Gumbel noise), the
env tick, and the periodic episode reset all inside one whole-horizon
program (ONE Pallas dispatch on TPU). All randomness is *passed in*,
pre-drawn: per-tick Gumbel noise for action sampling, the horizon's env
noise (``horizon_noise``), and the per-tick reset states; the callee is a
pure function. Engines set the slot only when their kernel route is
active (TPU, or forced); off-TPU the PPO-side bulk-noise scan is the
default and produces bit-identical batches.

Ragged-batch layer (the serving contract, docs/ARCHITECTURE.md §8): the
fused programs above all run at a *fixed* batch shape, but real request
traffic is ragged — thousands of heterogeneous agent regions submitting
anywhere from 1 to B frames at once. ``pad_lanes`` / ``pad_mask`` are the
one place the padding semantics live: a ragged group of n real lanes is
packed into a fixed ``slot``-lane batch, lanes ``[0, n)`` real and lanes
``[n, slot)`` *pad lanes*. The contract, pinned bitwise by
``tests/test_serving.py``:

  - pad lanes are a documented NO-OP: they are masked at the kernel
    boundary (``kernels/ops.py::serve_forward`` zeroes their outputs
    inside the dispatch), so their contents can NEVER perturb a real
    lane's outputs — a real lane's results are bitwise-identical whatever
    the pad lanes hold (zeros, stale frames, NaN) and wherever in the
    slot the real lanes sit;
  - the fixed slot shape is load-bearing: XLA may pick a different GEMM
    reduction order for a different batch shape, so bitwise
    reproducibility is guaranteed *at a given slot shape*, and the
    serving tier always dispatches the same-shape program (that is what
    makes continuous batching jit-cache-friendly too);
  - ``pad_lanes`` fills pads by replicating lane 0 (a guaranteed-valid
    row — keeps domain math NaN-free) unless ``fill`` overrides it;
    consumers must treat pad outputs as garbage regardless, because the
    no-op guarantee is the mask, not the fill.

``kernel_codec`` is the one place the kernel-boundary dtype rules live:
Pallas VMEM scratch cannot hold bool/int8 leaves, so engines round-trip
them through int32 — domain code never sees encoded leaves.

``info`` carries the IBA quantities extracted from the GS (Algorithm 1):
  - "u": influence sources u_t  (what the AIP learns to predict)
  - "dset": the d-separating-set features d_t (AIP input)
  - "dset_full": d_t plus confounder variables (for the App. B ablation)

Multi-agent GS (Distributed IALS, Suau et al. 2022): the same signature with
``spec.n_agents = A > 1``; ``action`` is (A,), and obs / reward / info leaves
carry a leading (A, ...) agent axis — one local view per agent region, all
extracted from a single global step. Agent coordinates are ordinary traced
arrays, so per-agent extraction vmaps over them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    n_actions: int
    n_influence: int      # M influence source bits
    dset_dim: int         # d-set feature size
    dset_full_dim: int    # d-set + confounders (ablation input)
    n_agents: int = 1     # leading agent axis of obs/action/reward/info


class Env(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, key) -> (state, obs, r, info)
    observe: Callable  # state -> obs


class LocalEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, u, key) -> (state, obs, r, info)
    observe: Callable
    dset_fn: Callable  # (state, action) -> d_t features (used by the IALS
    #                    to query the AIP *before* stepping)


class BatchedEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable   # (key, n_envs) -> state with (B, ...) leaves
    step: Callable    # (state, actions (B, ...), key) -> (state, obs, r,
    #                    info), every output leaf (B, ...)
    observe: Callable  # state -> obs (B, ...)
    rollout: Any = None  # optional (state, actions (T, B, ...), keys (T,))
    #                      -> (state, rewards (T, ...)): a whole-horizon
    #                      native rollout, bitwise-equal to scanning step
    #                      but free to exploit the static horizon (VMEM-
    #                      resident state, bulk noise). Use ``env_rollout``.
    noise_fn: Any = None  # optional (key, n_envs) -> one tick's randomness
    #                       as a pytree, exactly as ``step`` derives it
    step_det: Any = None  # optional (state, actions, noise) -> (state, obs,
    #                       r, info); step(s,a,k) == step_det(s,a,
    #                       noise_fn(k,B)) bitwise
    policy_rollout: Any = None  # optional whole-horizon actor-in-the-loop
    #   rollout: (state, frames (B, [A,] k, obs_dim), t_in_ep (B,) int32,
    #   policy_params, gumbel (T, B, [A,] n_actions), noise (the pytree
    #   ``horizon_noise(noise_fn, keys, B)`` returns), reset_states
    #   (T-stacked env states), *, episode_len, fast_gates) ->
    #   (state, frames, t_in_ep, out) where out carries the PPO batch
    #   streams {"x", "a", "logits", "v", "r", "done"}. Invariant:
    #   0 <= t_in_ep < episode_len on entry (PPO maintains it). Engines
    #   set this ONLY when the fused kernel route is active — absent, the
    #   caller's own bulk-noise scan is the (bit-identical) default.


class BatchedLocalEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable   # (key, n_envs) -> state with (B, ...) leaves
    step: Callable    # (state, actions, u (B, M), key) -> (state, obs, r,
    #                    info)
    observe: Callable
    dset_fn: Callable  # (state, actions) -> d_t features (B, dset_dim)
    noise_fn: Any = None  # optional (key, n_envs) -> the LS's own per-tick
    #                       randomness pytree (None-leaved if deterministic)
    step_det: Any = None  # optional (state, actions, u, noise) -> (state,
    #                       obs, r, info), the deterministic tick
    rollout_tick: Any = None  # optional (state, actions, u, noise) ->
    #                           (state, reward): the transition+reward core
    #                           only (no obs/info), pure jnp on state
    #                           leaves — traceable inside a Pallas kernel
    #                           body, which is what the whole-horizon fused
    #                           engine inlines per grid step
    obs_fn: Any = None  # optional kernel-safe observe: state -> obs
    #                     (B, obs_dim) f32, bitwise-equal to ``observe``
    #                     but written constant-free (no captured array
    #                     tables, no dynamic scatters) so the
    #                     actor-in-the-loop rollout kernel can trace it
    #                     per grid step to refill the policy frame stack


def _batch_size(state) -> int:
    return jax.tree_util.tree_leaves(state)[0].shape[0]


def batch_env(env: Env) -> BatchedEnv:
    """vmap adapter: any scalar Env through the batched protocol.

    Key derivation matches the historical vmap-of-scalar rollout exactly:
    reset and step both fan one key out into B subkeys."""
    vreset, vstep = jax.vmap(env.reset), jax.vmap(env.step)

    def reset(key, n_envs: int):
        return vreset(jax.random.split(key, n_envs))

    def step(state, actions, key):
        return vstep(state, actions, jax.random.split(key,
                                                      _batch_size(state)))

    return BatchedEnv(spec=env.spec, reset=reset, step=step,
                      observe=jax.vmap(env.observe))


def batch_local_env(env: LocalEnv) -> BatchedLocalEnv:
    """vmap adapter for the LS signature (generic fallback; the domains
    provide native batched LS implementations for the hot path)."""
    vreset, vstep = jax.vmap(env.reset), jax.vmap(env.step)

    def reset(key, n_envs: int):
        return vreset(jax.random.split(key, n_envs))

    def step(state, actions, u, key):
        return vstep(state, actions, u,
                     jax.random.split(key, _batch_size(state)))

    return BatchedLocalEnv(spec=env.spec, reset=reset, step=step,
                           observe=jax.vmap(env.observe),
                           dset_fn=jax.vmap(env.dset_fn))


def as_batched(env) -> BatchedEnv:
    """Env | BatchedEnv -> BatchedEnv (identity when already batched)."""
    if isinstance(env, BatchedEnv):
        return env
    return batch_env(env)


# dtypes the whole-horizon kernels cannot hold in VMEM scratch directly;
# engines round-trip them through int32 at the kernel boundary
KERNEL_ENC_DTYPES = (jnp.bool_, jnp.int8)


def kernel_codec(treedef, dtypes):
    """(treedef, leaf dtypes) -> (encode, decode) for the kernel boundary:
    bool/int8 leaves become int32 inside the kernel, and ``decode``
    restores the original dtypes and tree structure. Closes over static
    metadata only, so the closures are safe to cache across traces."""

    def encode(vals):
        return tuple(v.astype(jnp.int32) if v.dtype in KERNEL_ENC_DTYPES
                     else v for v in vals)

    def decode(vals):
        return jax.tree_util.tree_unflatten(
            treedef, [v.astype(dt) for v, dt in zip(vals, dtypes)])

    return encode, decode


def pad_mask(n_valid: int, slot: int):
    """(slot,) bool lane-validity mask: True for the n_valid real lanes,
    False for the pad lanes. The single source of truth for which lanes
    of a packed slot are real — ``kernels/ops.py::serve_forward`` applies
    it at the kernel boundary so pad lanes can never perturb real-lane
    outputs (the ragged-batch contract in the module docstring)."""
    return jnp.arange(slot) < n_valid


def pad_lanes(tree, slot: int, fill: str = "edge"):
    """Pack a ragged batch into a fixed-slot batch: every (n, ...) leaf
    of ``tree`` (n >= 1) becomes (slot, ...), lanes [0, n) the real rows
    and lanes [n, slot) pad lanes. ``fill="edge"`` replicates lane 0 (a
    guaranteed-valid row, so domain math on pads stays finite);
    ``fill="zero"`` writes zeros. Pad-lane *outputs* are garbage by
    contract either way — the no-op guarantee is ``pad_mask`` applied at
    the kernel boundary, never the fill value."""
    if fill not in ("edge", "zero"):
        raise ValueError(f"unknown fill mode: {fill!r}")

    def pad(leaf):
        leaf = jnp.asarray(leaf)
        n = leaf.shape[0]
        if n > slot:
            raise ValueError(f"ragged batch of {n} rows does not fit a "
                             f"{slot}-lane slot")
        pad_rows = (jnp.broadcast_to(leaf[:1], (slot - n,) + leaf.shape[1:])
                    if fill == "edge" else
                    jnp.zeros((slot - n,) + leaf.shape[1:], leaf.dtype))
        return jnp.concatenate([leaf, pad_rows], axis=0)

    return jax.tree_util.tree_map(pad, tree)


def horizon_noise(noise_fn, keys, n_envs: int):
    """Draw a whole horizon's randomness in bulk: (T,) keys -> a pytree
    whose leaves carry a leading T axis, leaf t being exactly
    ``noise_fn(keys[t], n_envs)``."""
    return jax.vmap(lambda k: noise_fn(k, n_envs))(keys)


def env_rollout(benv: BatchedEnv, state, actions, keys, *,
                unroll: int = 8):
    """Whole-horizon rollout: actions (T, B, ...), keys (T,) ->
    (final state, rewards (T, ...)).

    Dispatch order, most fused first — every path agrees bitwise because
    all of them derive per-tick randomness from the same keys:
      1. the env's native ``rollout`` override (the fused IALS engines
         keep state device-resident across the whole horizon there);
      2. bulk-noise scan of ``step_det`` when the env splits its tick
         into ``noise_fn``/``step_det`` — all T ticks' randomness is
         drawn outside the scan, the body is pure compute;
      3. an unrolled scan of ``step``.
    """
    if benv.rollout is not None:
        return benv.rollout(state, actions, keys)

    if benv.step_det is not None and benv.noise_fn is not None:
        B = _batch_size(state)
        noise = horizon_noise(benv.noise_fn, keys, B)

        def step_det(carry, xs):
            a, n = xs
            s, _, r, _ = benv.step_det(carry, a, n)
            return s, r

        return jax.lax.scan(step_det, state, (actions, noise),
                            unroll=unroll)

    def step(carry, xs):
        a, k = xs
        s, _, r, _ = benv.step(carry, a, k)
        return s, r

    return jax.lax.scan(step, state, (actions, keys), unroll=unroll)


def unbatch_env(benv: BatchedEnv, name: str | None = None) -> Env:
    """Squeeze adapter: a batched env through the scalar Env protocol.

    State stays the B=1 batched state internally (it is opaque to
    callers); every exposed leaf has the batch axis squeezed off."""
    spec = (dataclasses.replace(benv.spec, name=name) if name
            else benv.spec)

    def reset(key):
        return benv.reset(key, 1)

    def step(state, action, key):
        state, obs, r, info = benv.step(
            state, jnp.asarray(action)[None], key)
        return state, obs[0], r[0], {k: v[0] for k, v in info.items()}

    def observe(state):
        return benv.observe(state)[0]

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def squeeze_agent_env(multi: Env, name: str) -> Env:
    """A 1-agent multi-agent GS presented through the single-agent protocol:
    scalar action in, the leading agent axis squeezed off every output."""
    spec = dataclasses.replace(multi.spec, name=name, n_agents=1)

    def observe(state):
        return multi.observe(state)[0]

    def step(state, action, key):
        state, obs, r, info = multi.step(state, jnp.asarray(action)[None],
                                         key)
        return state, obs[0], r[0], {k: v[0] for k, v in info.items()}

    return Env(spec=spec, reset=multi.reset, step=step, observe=observe)
