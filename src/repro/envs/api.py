"""Environment protocol: pure-function JAX environments.

Both the Global Simulator (GS) and Local Simulator (LS) of each domain expose
the same functional API, so PPO rollouts are a single ``lax.scan`` and batch
parallelism is a ``vmap`` — this is the TPU-native answer to the paper's
"make the simulator fast" premise (DESIGN.md §4).

GS step:  (state, action, key)          -> (state, obs, reward, info)
LS step:  (state, action, u_t, key)     -> (state, obs, reward, info)

``info`` carries the IBA quantities extracted from the GS (Algorithm 1):
  - "u": influence sources u_t  (what the AIP learns to predict)
  - "dset": the d-separating-set features d_t (AIP input)
  - "dset_full": d_t plus confounder variables (for the App. B ablation)

Multi-agent GS (Distributed IALS, Suau et al. 2022): the same signature with
``spec.n_agents = A > 1``; ``action`` is (A,), and obs / reward / info leaves
carry a leading (A, ...) agent axis — one local view per agent region, all
extracted from a single global step. Agent coordinates are ordinary traced
arrays, so per-agent extraction vmaps over them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple

import jax.numpy as jnp


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    n_actions: int
    n_influence: int      # M influence source bits
    dset_dim: int         # d-set feature size
    dset_full_dim: int    # d-set + confounders (ablation input)
    n_agents: int = 1     # leading agent axis of obs/action/reward/info


class Env(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, key) -> (state, obs, r, info)
    observe: Callable  # state -> obs


class LocalEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, u, key) -> (state, obs, r, info)
    observe: Callable
    dset_fn: Callable  # (state, action) -> d_t features (used by the IALS
    #                    to query the AIP *before* stepping)


def squeeze_agent_env(multi: Env, name: str) -> Env:
    """A 1-agent multi-agent GS presented through the single-agent protocol:
    scalar action in, the leading agent axis squeezed off every output."""
    spec = dataclasses.replace(multi.spec, name=name, n_agents=1)

    def observe(state):
        return multi.observe(state)[0]

    def step(state, action, key):
        state, obs, r, info = multi.step(state, jnp.asarray(action)[None],
                                         key)
        return state, obs[0], r[0], {k: v[0] for k, v in info.items()}

    return Env(spec=spec, reset=multi.reset, step=step, observe=observe)
