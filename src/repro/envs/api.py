"""Environment protocol: pure-function JAX environments.

Both the Global Simulator (GS) and Local Simulator (LS) of each domain expose
the same functional API, so PPO rollouts are a single ``lax.scan`` and batch
parallelism is a ``vmap`` — this is the TPU-native answer to the paper's
"make the simulator fast" premise (DESIGN.md §4).

GS step:  (state, action, key)          -> (state, obs, reward, info)
LS step:  (state, action, u_t, key)     -> (state, obs, reward, info)

``info`` carries the IBA quantities extracted from the GS (Algorithm 1):
  - "u": influence sources u_t  (what the AIP learns to predict)
  - "dset": the d-separating-set features d_t (AIP input)
  - "dset_full": d_t plus confounder variables (for the App. B ablation)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    n_actions: int
    n_influence: int      # M influence source bits
    dset_dim: int         # d-set feature size
    dset_full_dim: int    # d-set + confounders (ablation input)


class Env(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, key) -> (state, obs, r, info)
    observe: Callable  # state -> obs


class LocalEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable   # key -> state
    step: Callable    # (state, action, u, key) -> (state, obs, r, info)
    observe: Callable
    dset_fn: Callable  # (state, action) -> d_t features (used by the IALS
    #                    to query the AIP *before* stepping)
