"""Checkpointing: atomic, keep-N, auto-resume — the fault-tolerance anchor.

Layout (one directory per step):
    <dir>/step_000123/
        arrays.npz      flattened leaves, keyed by index
        meta.msgpack    treedef repr, leaf paths, step, user metadata
        COMMITTED       sentinel written last (torn saves are never loaded)

Writes go to ``step_X.tmp`` and are atomically renamed, so a preemption
mid-save leaves the previous checkpoint intact — ``latest_step`` only ever
sees COMMITTED checkpoints. ``restore`` reshards onto the current device
layout (elastic restarts onto a different mesh work as long as shapes
match). On multi-host this runs on host 0 per process-local shards;
``save`` accepts addressable shards only.
"""
from __future__ import annotations

import io
import os
import re
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/f8 etc. as named numpy dtypes
import msgpack
import numpy as np


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


def save(ckpt_dir: str | Path, step: int, tree: Any,
         metadata: Optional[Dict] = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(tree)]
    arrays, dtypes, shapes = {}, [], []
    for i, x in enumerate(leaves):
        np_x = np.asarray(jax.device_get(x))
        arr = np.ascontiguousarray(np_x)
        dtypes.append(str(arr.dtype))
        shapes.append(list(np_x.shape))  # original shape (0-d stays 0-d)
        # npz can't serialise ml_dtypes (bf16/f8) natively: store raw bytes
        arrays[_leaf_key(i)] = arr.view(np.uint8).reshape(-1)
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {"step": step, "n_leaves": len(leaves), "paths": paths,
            "dtypes": dtypes, "shapes": shapes,
            "user": metadata or {}}
    (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)
    # torn-save debris: a crash mid-write leaves step_X.tmp (or, from a
    # foreign writer, a step dir without COMMITTED) behind. Those are
    # never loaded — latest_step only sees COMMITTED — but they would
    # accumulate forever across crash-restart loops, so each successful
    # save sweeps them (never touching a committed dir).
    for p in ckpt_dir.iterdir():
        torn = (re.fullmatch(r"step_\d+\.tmp", p.name) or
                (re.fullmatch(r"step_\d+", p.name)
                 and not (p / "COMMITTED").exists()))
        if torn:
            shutil.rmtree(p, ignore_errors=True)


def all_steps(ckpt_dir: str | Path):
    ckpt_dir = Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "COMMITTED").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _resolve_step(ckpt_dir: Path, step: Optional[int]) -> int:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    return step


def _load_meta(d: Path) -> Dict:
    """Read + decode ``meta.msgpack`` of one step directory, enforcing the
    COMMITTED contract: a torn layout (missing sentinel, missing or
    truncated/corrupt metadata — everything a crash mid-save or bitrot
    can leave) raises a clear error instead of surfacing garbage.
    ``step=None`` resume paths never get here for torn dirs
    (``latest_step`` skips them); this guards *explicit* step requests
    and committed-but-corrupted files."""
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"{d} is not a committed checkpoint (missing COMMITTED — "
            f"torn save?)")
    try:
        raw = (d / "meta.msgpack").read_bytes()
    except FileNotFoundError:
        raise FileNotFoundError(f"{d} has no meta.msgpack — torn save?")
    try:
        meta = msgpack.unpackb(raw)
    except Exception as e:
        raise ValueError(
            f"corrupt checkpoint metadata in {d / 'meta.msgpack'}: "
            f"{e}") from e
    if not isinstance(meta, dict) or "user" not in meta:
        raise ValueError(
            f"corrupt checkpoint metadata in {d / 'meta.msgpack'}: "
            f"not a checkpoint meta dict")
    return meta


def read_metadata(ckpt_dir: str | Path,
                  step: Optional[int] = None) -> Dict:
    """The ``metadata`` dict a committed checkpoint was saved with,
    without touching the array payload — resume paths read their
    counters (RNG stream positions, learner version, worker count) from
    here before deciding what tree structure to restore into. Torn or
    corrupt metadata raises (``_load_meta``), never returns garbage."""
    ckpt_dir = Path(ckpt_dir)
    step = _resolve_step(ckpt_dir, step)
    return _load_meta(ckpt_dir / f"step_{step:09d}")["user"]


def restore(ckpt_dir: str | Path, target: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, step, user_metadata)."""
    ckpt_dir = Path(ckpt_dir)
    step = _resolve_step(ckpt_dir, step)
    d = ckpt_dir / f"step_{step:09d}"
    meta = _load_meta(d)
    data = np.load(d / "arrays.npz")
    leaves, treedef = jax.tree_util.tree_flatten(target)
    if len(leaves) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, target has "
            f"{len(leaves)} — structure mismatch")
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        raw = data[_leaf_key(i)]
        arr = raw.view(np.dtype(meta["dtypes"][i])).reshape(
            meta["shapes"][i])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {meta['paths'][i]}: checkpoint shape "
                             f"{arr.shape} != target {ref.shape}")
        x = jnp.asarray(arr, dtype=ref.dtype)
        if sh is not None:
            x = jax.device_put(x, sh)
        out.append(x)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, meta["user"]


def restore_subtree(ckpt_dir: str | Path, target: Any, prefix: str,
                    step: Optional[int] = None):
    """Restore ONE subtree of a checkpoint — e.g. just ``['policy']`` out
    of an ``rl_train`` full-RL-state checkpoint — without reading the
    rest of the array payload. Returns (subtree, step, user_metadata).

    ``target`` is a shape-correct pytree of the subtree (the serve-time
    policy template); ``prefix`` is the ``jax.tree_util.keystr`` path of
    the subtree root inside the saved tree (``"['policy']"``). Leaves are
    matched by *path*, not position, and the npz payload is a zip — each
    selected member decompresses individually, so a policy restore from a
    checkpoint whose optimizer/rollout state dwarfs the policy touches
    only the policy's bytes. This is the serving tier's restore path
    (``repro.launch.policy_serve --ckpt-dir``): an inference process
    never materialises training state."""
    ckpt_dir = Path(ckpt_dir)
    step = _resolve_step(ckpt_dir, step)
    d = ckpt_dir / f"step_{step:09d}"
    meta = _load_meta(d)
    index = {p: i for i, p in enumerate(meta["paths"])}
    leaves, treedef = jax.tree_util.tree_flatten(target)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(target)]
    data = np.load(d / "arrays.npz")
    out = []
    for ref, sub_path in zip(leaves, paths):
        full = prefix + sub_path
        i = index.get(full)
        if i is None:
            raise ValueError(
                f"checkpoint step {step} has no leaf {full!r} — "
                f"wrong prefix or structure mismatch "
                f"(saved paths start with e.g. {meta['paths'][0]!r})")
        arr = data[_leaf_key(i)].view(np.dtype(meta["dtypes"][i])).reshape(
            meta["shapes"][i])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {full}: checkpoint shape {arr.shape} "
                             f"!= target {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out), step,
            meta["user"])
