"""Unified LM: one model assembled from ArchConfig.

Covers all assigned families — dense / MoE(+MLA) / hybrid(attn+mamba+MoE) /
SSM(xLSTM) / enc-dec(whisper) / VLM(gated cross-attn) — with three entry
points:

- ``forward(params, cfg, inputs, want_cache)`` — training / prefill; the
  repeating layer pattern runs under ``lax.scan`` over stacked parameters
  (scan-over-layers), optionally rematerialised.
- ``decode_step(params, cfg, cache, token, pos)`` — one serving step against
  a KV/state cache; cache layout mirrors the scanned parameter stack.
- ``init_cache(cfg, batch, max_len)`` — cache pytree (use ``jax.eval_shape``
  on it for allocation-free dry-run specs).

Modality frontends (whisper conv / vision encoder) are stubs per the brief:
``inputs`` carries precomputed frame/patch embeddings.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, LayerSpec
from repro.distributed.act_sharding import constrain
from repro.nn import module as nn
from repro.nn import attention as att
from repro.nn import moe as moe_lib
from repro.nn import moe_ep as moe_ep_lib
from repro.nn import ssm as ssm_lib

Params = Dict[str, Any]


# ===========================================================================
# Per-layer init
# ===========================================================================

def _attn_init(key, cfg: ArchConfig, *, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd()
    H, KH = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype()
    ks = nn.split_keys(key, 4)
    p = {
        "wq": nn.dense_init(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": nn.dense_init(ks[1], d, KH * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": nn.dense_init(ks[2], d, KH * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": nn.dense_init(ks[3], H * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd, dtype=dt)
        p["k_norm"] = nn.rmsnorm_init(hd, dtype=dt)
    return p


def _mla_init(key, cfg: ArchConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dt = cfg.dtype()
    ks = nn.split_keys(key, 5)
    return {
        "wq_a": nn.dense_init(ks[0], d, qr, dtype=dt),
        "q_norm": nn.rmsnorm_init(qr, dtype=dt),
        "wq_b": nn.dense_init(ks[1], qr, H * (dn + dr), dtype=dt),
        "wkv_a": nn.dense_init(ks[2], d, kr + dr, dtype=dt),
        "kv_norm": nn.rmsnorm_init(kr, dtype=dt),
        "wkv_b": nn.dense_init(ks[3], kr, H * (dn + dv), dtype=dt),
        "wo": nn.dense_init(ks[4], H * dv, d, dtype=dt),
    }


def _ffn_init(key, cfg: ArchConfig, ffn: str) -> Params:
    d, dt = cfg.d_model, cfg.dtype()
    if ffn == "gated_mlp":
        return moe_lib.gated_mlp_init(key, d, cfg.d_ff, dtype=dt)
    if ffn == "mlp":
        return moe_lib.mlp_init(key, d, cfg.d_ff, dtype=dt)
    if ffn == "dense_mlp":  # deepseek prologue: gated MLP at dense_d_ff
        return moe_lib.gated_mlp_init(key, d, cfg.dense_d_ff, dtype=dt)
    if ffn == "moe":
        return moe_lib.moe_init(key, d, cfg.d_expert, cfg.n_routed_experts,
                                cfg.n_shared_experts, dtype=dt)
    raise ValueError(ffn)


def _layer_init(key, cfg: ArchConfig, spec: LayerSpec) -> Params:
    norm_init, _ = nn.make_norm(cfg.norm)
    d, dt = cfg.d_model, cfg.dtype()
    k_mix, k_ffn, k_x = jax.random.split(key, 3)
    p: Params = {"norm1": norm_init(d, dtype=dt)}
    if spec.kind == "attn":
        p["mix"] = _attn_init(k_mix, cfg)
    elif spec.kind == "xattn":
        p["mix"] = _attn_init(k_mix, cfg, cross=True)
        p["gate_attn"] = jnp.zeros((), dt)
        p["gate_ffn"] = jnp.zeros((), dt)
    elif spec.kind == "dec_attn":
        p["mix"] = {"self": _attn_init(k_mix, cfg),
                    "cross": _attn_init(k_x, cfg, cross=True)}
        p["norm_cross"] = norm_init(d, dtype=dt)
    elif spec.kind == "mla":
        p["mix"] = _mla_init(k_mix, cfg)
    elif spec.kind == "mamba":
        p["mix"] = ssm_lib.mamba_init(
            k_mix, d, expand=cfg.mamba_expand, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, dtype=dt)
    elif spec.kind == "mlstm":
        p["mix"] = ssm_lib.mlstm_init(
            k_mix, d, cfg.n_heads, proj_factor=cfg.mlstm_proj_factor,
            d_conv=cfg.mamba_d_conv, dtype=dt)
    elif spec.kind == "slstm":
        p["mix"] = ssm_lib.slstm_init(k_mix, d, cfg.n_heads, dtype=dt)
    else:
        raise ValueError(spec.kind)
    if spec.ffn != "none":
        p["norm2"] = norm_init(d, dtype=dt)
        p["ffn"] = _ffn_init(k_ffn, cfg, spec.ffn)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    dt = cfg.dtype()
    prologue, pattern, n_groups = cfg.layer_plan()
    norm_init, _ = nn.make_norm(cfg.norm)
    ks = nn.split_keys(key, 8)
    p: Params = {"embed": nn.embedding_init(ks[0], cfg.vocab_size,
                                            cfg.d_model, dtype=dt)}
    if cfg.learned_pos:
        p["pos_emb"] = nn.embedding_init(
            ks[1], cfg.max_position_embeddings, cfg.d_model, dtype=dt)

    if cfg.family == "encdec":
        enc_spec = LayerSpec("attn", cfg.mlp_kind)
        p["enc"] = {
            "pos": nn.embedding_init(ks[2], cfg.n_audio_frames,
                                     cfg.d_model, dtype=dt),
            "blocks": nn.stack_init(
                lambda k: _layer_init(k, cfg, enc_spec), ks[3],
                cfg.n_encoder_layers),
            "norm": norm_init(cfg.d_model, dtype=dt),
        }
        pattern = [LayerSpec("dec_attn", cfg.mlp_kind)]

    if prologue:
        p["prologue"] = {
            str(i): _layer_init(k, cfg, spec)
            for i, (k, spec) in enumerate(
                zip(nn.split_keys(ks[4], len(prologue)), prologue))
        }

    def group_init(k):
        gks = nn.split_keys(k, len(pattern))
        return {str(i): _layer_init(gks[i], cfg, spec)
                for i, spec in enumerate(pattern)}

    p["blocks"] = nn.stack_init(group_init, ks[5], n_groups)
    p["final_norm"] = norm_init(cfg.d_model, dtype=dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.dense_init(ks[6], cfg.d_model, cfg.vocab_size,
                                     dtype=dt)
    return p


def _pattern(cfg: ArchConfig):
    prologue, pattern, n_groups = cfg.layer_plan()
    if cfg.family == "encdec":
        pattern = [LayerSpec("dec_attn", cfg.mlp_kind)]
    return prologue, pattern, n_groups


# ===========================================================================
# Per-layer forward (full sequence)
# ===========================================================================

def _self_attention(p, cfg: ArchConfig, x, positions, *, causal=True,
                    want_cache=False):
    B, T, d = x.shape
    hd, H, KH = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    q = nn.dense(p["wq"], x).reshape(B, T, H, hd)
    k = nn.dense(p["wk"], x).reshape(B, T, KH, hd)
    v = nn.dense(p["wv"], x).reshape(B, T, KH, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q)
        k = nn.rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        q = att.apply_rope(q, positions, cfg.rope_theta)
        k = att.apply_rope(k, positions, cfg.rope_theta)
    o = att.flash_attention(q, k, v, causal=causal,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    o = checkpoint_name(o, "attn_out")
    out = nn.dense(p["wo"], o.reshape(B, T, H * hd))
    cache = {"k": k, "v": v} if want_cache else None
    return out, cache


def _cross_attention(p, cfg: ArchConfig, x, memory, *, want_cache=False):
    B, T, d = x.shape
    hd, H, KH = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    q = nn.dense(p["wq"], x).reshape(B, T, H, hd)
    k = nn.dense(p["wk"], memory).reshape(B, memory.shape[1], KH, hd)
    v = nn.dense(p["wv"], memory).reshape(B, memory.shape[1], KH, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q)
        k = nn.rmsnorm(p["k_norm"], k)
    o = att.flash_attention(q, k, v, causal=False,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    out = nn.dense(p["wo"], o.reshape(B, T, H * hd))
    cache = {"mk": k, "mv": v} if want_cache else None
    return out, cache


def _mla_attention(p, cfg: ArchConfig, x, positions, *, want_cache=False):
    B, T, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    q = nn.dense(p["wq_b"], nn.rmsnorm(p["q_norm"], nn.dense(p["wq_a"], x)))
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = att.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = nn.dense(p["wkv_a"], x)
    ckv = nn.rmsnorm(p["kv_norm"], kv_a[..., :kr])           # (B,T,R)
    krope = att.apply_rope(kv_a[..., kr:].reshape(B, T, 1, dr), positions,
                           cfg.rope_theta)                   # (B,T,1,dr)
    kv = nn.dense(p["wkv_b"], ckv).reshape(B, T, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope, (B, T, H, dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = att.flash_attention(qf, k, v, causal=True,
                            scale=(dn + dr) ** -0.5,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    out = nn.dense(p["wo"], o.reshape(B, T, H * dv))
    cache = {"ckv": ckv, "krope": krope[:, :, 0]} if want_cache else None
    return out, cache


def _ffn_apply(p, cfg: ArchConfig, x, ffn: str, *, full_capacity=False):
    zero = jnp.zeros((), jnp.float32)
    aux = {"lb_loss": zero, "z_loss": zero, "drop_frac": zero}
    if ffn in ("gated_mlp", "dense_mlp"):
        return moe_lib.gated_mlp(p, x, cfg.act), aux
    if ffn == "mlp":
        return moe_lib.mlp(p, x, cfg.act), aux
    if ffn == "moe":
        cf = cfg.capacity_factor
        if full_capacity:  # decode is dropless: capacity == token count
            cf = cfg.n_routed_experts / cfg.moe_top_k
        if cfg.moe_impl == "ep":
            out, aux = moe_ep_lib.moe_apply_ep(
                p, x, top_k=cfg.moe_top_k, act=cfg.act, capacity_factor=cf,
                expert_axes=cfg.moe_expert_axes)
        else:
            out, aux = moe_lib.moe_apply(
                p, x, top_k=cfg.moe_top_k, act=cfg.act, capacity_factor=cf)
        return out, aux
    raise ValueError(ffn)


def _layer_apply(p, cfg: ArchConfig, spec: LayerSpec, h, ctx, *,
                 want_cache=False):
    """-> (h, aux, cache)."""
    _, norm = nn.make_norm(cfg.norm)
    x = norm(p["norm1"], h)
    cache: Dict[str, Any] = {}
    zero = jnp.zeros((), jnp.float32)
    aux = {"lb_loss": zero, "z_loss": zero, "drop_frac": zero}

    if spec.kind == "attn":
        out, c = _self_attention(p["mix"], cfg, x, ctx["positions"],
                                 causal=ctx.get("causal", True),
                                 want_cache=want_cache)
        h = h + out
        if want_cache:
            cache["self"] = c
    elif spec.kind == "mla":
        out, c = _mla_attention(p["mix"], cfg, x, ctx["positions"],
                                want_cache=want_cache)
        h = h + out
        if want_cache:
            cache["self"] = c
    elif spec.kind == "xattn":
        out, c = _cross_attention(p["mix"], cfg, x, ctx["memory"],
                                  want_cache=want_cache)
        h = h + jnp.tanh(p["gate_attn"]) * out
        if want_cache:
            cache["cross"] = c
        if spec.ffn != "none":
            f, aux = _ffn_apply(p["ffn"], cfg, norm(p["norm2"], h), spec.ffn)
            h = h + jnp.tanh(p["gate_ffn"]) * f
        return h, aux, (cache if want_cache else None)
    elif spec.kind == "dec_attn":
        out, c = _self_attention(p["mix"]["self"], cfg, x, ctx["positions"],
                                 causal=True, want_cache=want_cache)
        h = h + out
        xc = norm(p["norm_cross"], h)
        out2, c2 = _cross_attention(p["mix"]["cross"], cfg, xc,
                                    ctx["memory"], want_cache=want_cache)
        h = h + out2
        if want_cache:
            cache["self"], cache["cross"] = c, c2
    elif spec.kind == "mamba":
        res = ssm_lib.mamba_apply(p["mix"], x, d_state=cfg.mamba_d_state,
                                  chunk=cfg.mamba_chunk,
                                  return_state=want_cache)
        out, st = res if want_cache else (res, None)
        h = h + out
        if want_cache:
            cache["state"] = st
    elif spec.kind == "mlstm":
        res = ssm_lib.mlstm_apply(p["mix"], x, cfg.n_heads,
                                  chunk=cfg.rnn_chunk,
                                  return_state=want_cache)
        out, st = res if want_cache else (res, None)
        h = h + out
        if want_cache:
            cache["state"] = st
    elif spec.kind == "slstm":
        res = ssm_lib.slstm_apply(p["mix"], x, cfg.n_heads,
                                  chunk=cfg.rnn_chunk,
                                  return_state=want_cache)
        out, st = res if want_cache else (res, None)
        h = h + out
        if want_cache:
            cache["state"] = st
    else:
        raise ValueError(spec.kind)

    if spec.ffn != "none":
        f, aux = _ffn_apply(p["ffn"], cfg, norm(p["norm2"], h), spec.ffn)
        h = h + f
    return h, aux, (cache if want_cache else None)


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


# ===========================================================================
# Encoder (whisper)
# ===========================================================================

def encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) post-conv stub embeddings -> (B, F, d)."""
    _, norm = nn.make_norm(cfg.norm)
    enc = params["enc"]
    h = frames + enc["pos"]["table"][None, :frames.shape[1]]
    spec = LayerSpec("attn", cfg.mlp_kind)
    ctx = {"positions": jnp.arange(frames.shape[1]), "causal": False}

    def body(h, lp):
        h, _, _ = _layer_apply(lp, cfg, spec, h, ctx)
        return constrain(h, "dp", None, None), None

    h, _ = lax.scan(body, h, enc["blocks"])
    return norm(enc["norm"], h)


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================

def forward(params: Params, cfg: ArchConfig, inputs: Dict[str, jax.Array],
            *, want_cache: bool = False):
    """inputs: {tokens (B,T)[, vision (B,Nv,d) | frames (B,F,d)]}.

    -> (h_final (B,T,d), aux, cache|None). Apply ``logits``/``loss`` on top.
    """
    prologue, pattern, n_groups = _pattern(cfg)
    _, norm = nn.make_norm(cfg.norm)
    tokens = inputs["tokens"]
    B, T = tokens.shape
    h = nn.embedding(params["embed"], tokens)
    h = constrain(h, "dp", None, None)
    positions = jnp.arange(T)
    if cfg.learned_pos:
        h = h + params["pos_emb"]["table"][None, :T]

    memory = None
    if cfg.family == "encdec":
        memory = encode(params, cfg, inputs["frames"])
    elif cfg.family == "vlm":
        memory = inputs["vision"]
    ctx = {"positions": positions, "memory": memory, "causal": True}

    zero = jnp.zeros((), jnp.float32)
    aux = {"lb_loss": zero, "z_loss": zero, "drop_frac": zero}

    pro_caches = {}
    for i, spec in enumerate(prologue):
        h, a, c = _layer_apply(params["prologue"][str(i)], cfg, spec, h, ctx,
                               want_cache=want_cache)
        aux = _add_aux(aux, a)
        if want_cache:
            pro_caches[str(i)] = c

    def group_body(carry, gp):
        h, aux = carry
        caches = {}
        for i, spec in enumerate(pattern):
            h, a, c = _layer_apply(gp[str(i)], cfg, spec, h, ctx,
                                   want_cache=want_cache)
            h = constrain(h, "dp", None, None)
            aux = _add_aux(aux, a)
            if want_cache:
                caches[str(i)] = c
        return (h, aux), (caches if want_cache else None)

    if cfg.remat == "full":
        group_body = jax.checkpoint(group_body)
    elif cfg.remat == "dots":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat == "names":
        # save attention outputs (small, bf16) so the backward never
        # re-runs the flash forward; everything else recomputes
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.save_only_these_names("attn_out"))

    (h, aux), blk_caches = lax.scan(group_body, (h, aux), params["blocks"])
    h = norm(params["final_norm"], h)

    cache = None
    if want_cache:
        cache = {"prologue": pro_caches, "blocks": blk_caches,
                 "memory": memory}
    return h, aux, cache


def logits(params: Params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, params["embed"]["table"])
    return nn.dense(params["lm_head"], h)


def loss_fn(params: Params, cfg: ArchConfig, inputs: Dict[str, jax.Array],
            *, loss_chunk: int = 512):
    """Next-token CE, chunked over T so (B,T,V) logits are never resident."""
    h, aux, _ = forward(params, cfg, inputs)
    labels = inputs["labels"]
    B, T, d = h.shape
    ck = min(loss_chunk, T)
    while T % ck:
        ck //= 2
    nck = T // ck

    if cfg.tie_embeddings:
        head = params["embed"]["table"]           # (V, d)
        proj = lambda x: jnp.einsum("btd,vd->btv", x, head)
    else:
        w = params["lm_head"]["w"]                # (d, V)
        proj = lambda x: jnp.einsum("btd,dv->btv", x, w)

    def body(carry, i):
        ce_sum, n_tok = carry
        hs = lax.dynamic_slice_in_dim(h, i * ck, ck, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, i * ck, ck, axis=1)
        lg = constrain(proj(hs).astype(jnp.float32), "dp", None, "tp")
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, ls[..., None], axis=-1)[..., 0]
        valid = (ls >= 0).astype(jnp.float32)
        ce_sum = ce_sum + jnp.sum((lse - ll) * valid)
        n_tok = n_tok + jnp.sum(valid)
        return (ce_sum, n_tok), None

    (ce_sum, n_tok), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(nck))
    ce = ce_sum / jnp.maximum(n_tok, 1.0)
    total = ce + cfg.lb_loss_weight * aux["lb_loss"] \
        + cfg.z_loss_weight * aux["z_loss"]
    metrics = {"ce": ce, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"],
               "drop_frac": aux["drop_frac"]}
    return total, metrics


# ===========================================================================
# Cache + decode
# ===========================================================================

def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int):
    dt = cfg.dtype()
    hd, KH = cfg.hd(), cfg.n_kv_heads
    d = cfg.d_model
    if spec.kind in ("attn",):
        return {"self": {"k": jnp.zeros((batch, max_len, KH, hd), dt),
                         "v": jnp.zeros((batch, max_len, KH, hd), dt)}}
    if spec.kind == "mla":
        return {"self": {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt)}}
    if spec.kind == "xattn":
        nv = cfg.n_vision_tokens
        return {"cross": {"mk": jnp.zeros((batch, nv, KH, hd), dt),
                          "mv": jnp.zeros((batch, nv, KH, hd), dt)}}
    if spec.kind == "dec_attn":
        nf = cfg.n_audio_frames
        return {"self": {"k": jnp.zeros((batch, max_len, KH, hd), dt),
                         "v": jnp.zeros((batch, max_len, KH, hd), dt)},
                "cross": {"mk": jnp.zeros((batch, nf, KH, hd), dt),
                          "mv": jnp.zeros((batch, nf, KH, hd), dt)}}
    if spec.kind == "mamba":
        dI = cfg.mamba_expand * d
        return {"state": ssm_lib.mamba_init_state(
            batch, dI, cfg.mamba_d_conv, cfg.mamba_d_state, dt)}
    if spec.kind == "mlstm":
        dI = int(cfg.mlstm_proj_factor * d)
        return {"state": ssm_lib.mlstm_init_state(
            batch, dI, cfg.n_heads, cfg.mamba_d_conv, dt)}
    if spec.kind == "slstm":
        return {"state": ssm_lib.slstm_init_state(
            batch, cfg.n_heads, d // cfg.n_heads)}
    raise ValueError(spec.kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    prologue, pattern, n_groups = _pattern(cfg)

    def group_cache(_):
        return {str(i): _layer_cache(cfg, spec, batch, max_len)
                for i, spec in enumerate(pattern)}

    blocks = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy()
        if n_groups > 0 else x,
        group_cache(None))
    pro = {str(i): _layer_cache(cfg, spec, batch, max_len)
           for i, spec in enumerate(prologue)}
    cache = {"prologue": pro, "blocks": blocks}
    if cfg.family in ("encdec", "vlm"):
        pass  # cross kv lives inside the per-layer caches
    return cache


def _attn_decode(p, cfg: ArchConfig, x, c, pos):
    """x: (B, d); c: {"k","v"} caches; write at ``pos`` then attend."""
    B, d = x.shape
    hd, H, KH = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    q = nn.dense(p["wq"], x).reshape(B, 1, H, hd)
    k = nn.dense(p["wk"], x).reshape(B, 1, KH, hd)
    v = nn.dense(p["wv"], x).reshape(B, 1, KH, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q)
        k = nn.rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        pp = jnp.full((1,), pos)
        q = att.apply_rope(q, pp, cfg.rope_theta)
        k = att.apply_rope(k, pp, cfg.rope_theta)
    ck = lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype),
                                         pos, axis=1)
    cv = lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype),
                                         pos, axis=1)
    o = att.decode_attention(q[:, 0], ck, cv, pos)
    return nn.dense(p["wo"], o.reshape(B, H * hd)), {"k": ck, "v": cv}


def _cross_decode(p, cfg: ArchConfig, x, c):
    B, d = x.shape
    hd, H = cfg.hd(), cfg.n_heads
    q = nn.dense(p["wq"], x).reshape(B, 1, H, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q)
    S = c["mk"].shape[1]
    o = att.decode_attention(q[:, 0], c["mk"], c["mv"], jnp.int32(S - 1))
    return nn.dense(p["wo"], o.reshape(B, H * hd))


def _mla_decode(p, cfg: ArchConfig, x, c, pos):
    B, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    q = nn.dense(p["wq_b"], nn.rmsnorm(p["q_norm"], nn.dense(p["wq_a"], x)))
    q = q.reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pp = jnp.full((1,), pos)
    q_rope = att.apply_rope(q_rope, pp, cfg.rope_theta)

    kv_a = nn.dense(p["wkv_a"], x)
    ckv_new = nn.rmsnorm(p["kv_norm"], kv_a[..., :kr]).reshape(B, 1, kr)
    krope_new = att.apply_rope(kv_a[..., kr:].reshape(B, 1, 1, dr), pp,
                               cfg.rope_theta)[:, :, 0]
    ckv = lax.dynamic_update_slice_in_dim(
        c["ckv"], ckv_new.astype(c["ckv"].dtype), pos, axis=1)
    krope = lax.dynamic_update_slice_in_dim(
        c["krope"], krope_new.astype(c["krope"].dtype), pos, axis=1)

    wkv_b = p["wkv_b"]["w"].reshape(kr, H, dn + dv)
    w_kb_k = wkv_b[..., :dn].transpose(1, 0, 2)   # (H, R, dn)
    w_kb_v = wkv_b[..., dn:].transpose(1, 0, 2)   # (H, R, dv)
    o = att.mla_decode_attention(q_nope[:, 0], q_rope[:, 0], ckv, krope,
                                 w_kb_k, w_kb_v, pos,
                                 scale=(dn + dr) ** -0.5)
    return nn.dense(p["wo"], o.reshape(B, H * dv)), \
        {"ckv": ckv, "krope": krope}


def _layer_decode(p, cfg: ArchConfig, spec: LayerSpec, h, c, pos):
    """h: (B, d) -> (h, new_cache)."""
    _, norm = nn.make_norm(cfg.norm)
    x = norm(p["norm1"], h)
    new_c = dict(c)
    if spec.kind == "attn":
        out, new_c["self"] = _attn_decode(p["mix"], cfg, x, c["self"], pos)
        h = h + out
    elif spec.kind == "mla":
        out, new_c["self"] = _mla_decode(p["mix"], cfg, x, c["self"], pos)
        h = h + out
    elif spec.kind == "xattn":
        out = _cross_decode(p["mix"], cfg, x, c["cross"])
        h = h + jnp.tanh(p["gate_attn"]) * out
        if spec.ffn != "none":
            f, _ = _ffn_apply(p["ffn"], cfg, norm(p["norm2"], h)[:, None],
                              spec.ffn, full_capacity=True)
            h = h + jnp.tanh(p["gate_ffn"]) * f[:, 0]
        return h, new_c
    elif spec.kind == "dec_attn":
        out, new_c["self"] = _attn_decode(p["mix"]["self"], cfg, x,
                                          c["self"], pos)
        h = h + out
        xc = norm(p["norm_cross"], h)
        h = h + _cross_decode(p["mix"]["cross"], cfg, xc, c["cross"])
    elif spec.kind == "mamba":
        out, new_c["state"] = ssm_lib.mamba_step(
            p["mix"], c["state"], x, d_state=cfg.mamba_d_state)
        h = h + out
    elif spec.kind == "mlstm":
        out, new_c["state"] = ssm_lib.mlstm_step(p["mix"], c["state"], x,
                                                 cfg.n_heads)
        h = h + out
    elif spec.kind == "slstm":
        out, new_c["state"] = ssm_lib.slstm_step(p["mix"], c["state"], x,
                                                 cfg.n_heads)
        h = h + out
    else:
        raise ValueError(spec.kind)

    if spec.ffn != "none":
        f, _ = _ffn_apply(p["ffn"], cfg, norm(p["norm2"], h)[:, None],
                          spec.ffn, full_capacity=True)
        h = h + f[:, 0]
    return h, new_c


def decode_step(params: Params, cfg: ArchConfig, cache, token: jax.Array,
                pos: jax.Array):
    """token: (B,) int32; pos: scalar int32 (index the new token is written
    at, i.e. current length). -> (logits (B, V), new cache)."""
    prologue, pattern, n_groups = _pattern(cfg)
    _, norm = nn.make_norm(cfg.norm)
    h = nn.embedding(params["embed"], token)
    if cfg.learned_pos:
        h = h + jnp.take(params["pos_emb"]["table"], pos, axis=0)

    new_pro = {}
    for i, spec in enumerate(prologue):
        h, new_pro[str(i)] = _layer_decode(
            params["prologue"][str(i)], cfg, spec, h,
            cache["prologue"][str(i)], pos)

    def body(h, xs):
        gp, gc = xs
        new_gc = {}
        for i, spec in enumerate(pattern):
            h, new_gc[str(i)] = _layer_decode(gp[str(i)], cfg, spec, h,
                                              gc[str(i)], pos)
        return h, new_gc

    h, new_blocks = lax.scan(body, h, (params["blocks"], cache["blocks"]))
    h = norm(params["final_norm"], h)
    lg = logits(params, cfg, h)
    return lg, {"prologue": new_pro, "blocks": new_blocks}


def prefill(params: Params, cfg: ArchConfig, inputs: Dict[str, jax.Array],
            max_len: int):
    """Run the full prompt, return (last_logits (B,V), decode-ready cache).

    Attention K/V (and MLA latent) caches are padded from prompt length T to
    ``max_len`` capacity; recurrent states transfer as-is.
    """
    h, aux, cache = forward(params, cfg, inputs, want_cache=True)
    T = inputs["tokens"].shape[1]

    def pad(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if any(k in ("mk", "mv") for k in keys):
            return leaf  # cross-attn memory KV: fixed length
        if any(k in ("k", "v", "ckv", "krope") for k in keys):
            axis = 2 if keys[0] == "blocks" else 1
            padw = [(0, 0)] * leaf.ndim
            padw[axis] = (0, max_len - T)
            return jnp.pad(leaf, padw)
        return leaf

    pro = jax.tree_util.tree_map_with_path(
        pad, {"prologue": cache["prologue"], "blocks": cache["blocks"]})
    lg = logits(params, cfg, h[:, -1])
    return lg, {"prologue": pro["prologue"], "blocks": pro["blocks"]}


# ===========================================================================
# Parameter accounting (allocation-free via eval_shape)
# ===========================================================================

def param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ArchConfig) -> Dict[str, float]:
    """-> {total, active, embed} parameter counts (MoE-aware)."""
    shapes = param_shapes(cfg)
    total = active = embed = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = 1
        for s in leaf.shape:
            n *= s
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        total += n
        if "embed" in keys or "pos_emb" in keys or "lm_head" in keys:
            embed += n
            active += n
        elif "experts" in keys:
            active += n * cfg.moe_top_k / max(cfg.n_routed_experts, 1)
        else:
            active += n
    return {"total": float(total), "active": float(active),
            "embed": float(embed)}
