"""Deterministic, shard-aware token data pipeline.

Sources: synthetic (seeded zipfian over the vocab — used by examples and the
dry-run-scale train driver) or a memmapped token file. Every host computes
its own shard of each global batch purely from (seed, step, host_id) — no
coordination, bitwise-reproducible across restarts, and an elastic resize
just changes (n_hosts, host_id) while the global stream stays identical.
A tiny background-thread prefetcher overlaps host compute with batch
assembly.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"        # synthetic | file
    path: Optional[str] = None       # token file (np.int32 memmap) for "file"


class TokenPipeline:
    """get_batch(step, host_id, n_hosts) -> {"tokens","labels"} host shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.source == "file":
            assert cfg.path, "file source needs a path"
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def host_batch_size(self, n_hosts: int) -> int:
        assert self.cfg.global_batch % n_hosts == 0
        return self.cfg.global_batch // n_hosts

    def get_batch(self, step: int, host_id: int = 0, n_hosts: int = 1):
        cfg = self.cfg
        bh = self.host_batch_size(n_hosts)
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        if cfg.source == "synthetic":
            # zipfian-ish ranks: realistic logits distribution for LM loss
            ranks = rng.zipf(1.3, size=(bh, cfg.seq_len + 1))
            tokens = np.minimum(ranks, cfg.vocab_size - 1).astype(np.int32)
        else:
            n = len(self._mm) - cfg.seq_len - 1
            starts = rng.integers(0, n, size=(bh,))
            tokens = np.stack([self._mm[s:s + cfg.seq_len + 1]
                               for s in starts]).astype(np.int32)
            tokens = np.minimum(tokens, cfg.vocab_size - 1)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def iterator(self, start_step: int = 0, host_id: int = 0,
                 n_hosts: int = 1, prefetch: int = 2) -> Iterator:
        """Prefetching iterator from ``start_step`` (resume-friendly).

        The producer thread is leak-free: a full queue is waited on with a
        timeout so the producer re-checks ``stop`` (a producer blocked on a
        plain ``q.put`` would never observe ``stop.set()`` after the
        consumer exits), and the ``finally`` drains the queue and joins the
        thread, so closing the iterator releases the thread immediately."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                batch = self.get_batch(step, host_id, n_hosts)
                while not stop.is_set():
                    try:
                        q.put(batch, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            try:                     # unblock a producer mid-put
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=2.0)


def write_token_file(path: str | Path, tokens: np.ndarray):
    np.asarray(tokens, np.int32).tofile(path)
