"""PPO (Schulman et al. 2017) — the paper's RL algorithm (§5.1), pure JAX.

Policies are FNNs over a stack of the last k observations (Appendix F:
"policies are fed with a stack of the last 8 observations" in the warehouse;
k=1 in traffic). One training iteration = vectorised rollout (vmap over
environments, lax.scan over time) + GAE + clipped-objective epochs — a single
jitted program, so it runs identically on a GS, an IALS, or any F-IALS
variant, and shards over the mesh's data axes at scale.

Multi-agent (``PPOConfig.n_agents = A > 1``, parameter-shared): the env emits
(A, ...) per-agent obs/rewards; the agent axis rides along as an extra batch
dimension everywhere — one policy network, T * n_envs * A samples per update.
``shard_rollout`` places the env batch on the mesh ``data`` axis so rollouts
scale across devices.

Rollouts run on the batched env protocol: a native ``BatchedEnv`` (the
fused IALS engine) steps the whole env batch with one key per tick and its
randomness drawn in bulk; a scalar ``Env`` is lifted through the
``batch_env`` vmap adapter, which reproduces the historical
split-keys-then-vmap derivation exactly. When the env exposes the
whole-horizon pair ``noise_fn``/``step_det`` (see ``envs/api.py``), the
rollout draws ALL of the horizon's env randomness before the scan and the
scan body steps the deterministic tick — the policy stays in the loop (it
has to: actions depend on observations), but the env side of every tick
is pure compute, bitwise-equal to the keyed path. ``train_iteration``
donates its (params, opt_state, rollout-state) arguments, so each PPO
iteration updates in place instead of round-tripping fresh buffers.
"""
from __future__ import annotations

import functools

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.envs.api import BatchedEnv, Env, as_batched, horizon_noise
from repro.nn.act import fast_tanh
from repro.nn.module import dense_init, dense
from repro.optim.adamw import adamw


@dataclass(frozen=True)
class PPOConfig:
    obs_dim: int
    n_actions: int
    frame_stack: int = 1
    hidden: int = 128
    n_envs: int = 16
    rollout_len: int = 128
    episode_len: int = 256        # periodic env reset (episodic tasks)
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    lr: float = 3e-4
    epochs: int = 4
    n_minibatches: int = 4
    n_agents: int = 1             # leading agent axis of the env (1 = none)
    fast_gates: bool = True       # rational tanh (nn/act.py) in the policy
    #                               net — the same transcendental diet the
    #                               AIP tick got; False = exact jnp.tanh

    @property
    def agent_shape(self) -> tuple:
        return (self.n_agents,) if self.n_agents > 1 else ()


# ---------------------------------------------------------------------------
# Actor-critic network (FNN on frame-stacked obs)
# ---------------------------------------------------------------------------

def init_policy(cfg: PPOConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in = cfg.obs_dim * cfg.frame_stack
    return {
        "l1": dense_init(k1, d_in, cfg.hidden, bias=True),
        "l2": dense_init(k2, cfg.hidden, cfg.hidden, bias=True),
        "pi": dense_init(k3, cfg.hidden, cfg.n_actions, bias=True,
                         scale=0.01),
        "v": dense_init(k4, cfg.hidden, 1, bias=True, scale=0.1),
    }


def policy_forward(params, x, *, fast_gates: bool):
    """Actor-critic forward pass. ``fast_gates`` (required — thread
    ``PPOConfig.fast_gates`` so the config stays the single source of
    truth) evaluates the hidden tanh layers with the shared rational
    gates from ``nn/act.py`` (|err| < 1e-4, exact saturation) — the exact
    tanh transcendentals were the last per-tick policy cost the ROADMAP
    flagged on the rollout hot path. Training and rollout use the same
    setting, so PPO optimises exactly the network it acts with."""
    act = fast_tanh if fast_gates else jnp.tanh
    h = act(dense(params["l1"], x))
    h = act(dense(params["l2"], h))
    return dense(params["pi"], h), dense(params["v"], h)[..., 0]


# ---------------------------------------------------------------------------
# Vectorised rollout with frame stacking + periodic resets
# ---------------------------------------------------------------------------

class RolloutState(NamedTuple):
    env_state: Any
    frames: jax.Array      # (n_envs, *agent_shape, k, obs_dim)
    t_in_ep: jax.Array     # (n_envs,) int32


def _stack_obs(frames):
    return frames.reshape(frames.shape[:-2] + (-1,))


def init_rollout_state(env, cfg: PPOConfig, key) -> RolloutState:
    benv = as_batched(env)
    env_state = benv.reset(key, cfg.n_envs)
    obs = benv.observe(env_state)
    frames = jnp.zeros((cfg.n_envs,) + cfg.agent_shape
                       + (cfg.frame_stack, cfg.obs_dim))
    frames = frames.at[..., -1, :].set(obs)
    return RolloutState(env_state=env_state, frames=frames,
                        t_in_ep=jnp.zeros((cfg.n_envs,), jnp.int32))


def shard_rollout(rs: RolloutState, mesh) -> RolloutState:
    """Place the env batch on the mesh ``data`` axis (n_envs must divide).

    Under jit the computation follows the input sharding, so the whole
    rollout (env steps included) executes data-parallel across devices.
    No-op when the mesh has a single data device."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if mesh is None or mesh.shape.get("data", 1) == 1:
        return rs
    n_data = mesh.shape["data"]

    def put(x):
        spec = (P("data") if x.ndim >= 1 and x.shape[0] % n_data == 0
                else P())
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, rs)


def rollout(env, cfg: PPOConfig, params, rs: RolloutState, key):
    """-> (new RolloutState, batch with (T, n_envs, *agent_shape, ...)
    leaves). The agent axis (if any) is just extra batch dimension: one
    parameter-shared policy acts for every agent of every env copy.

    ``env`` may be a scalar ``Env`` or a native ``BatchedEnv``; either
    way the scan body is one batched env step per tick, with the per-step
    key array pre-split outside the scan. When the env exposes
    ``noise_fn``/``step_det``, the whole horizon's env randomness is
    drawn in bulk before the scan and the body runs the deterministic
    tick — bit-identical trajectories, no per-tick key derivation on the
    hot path."""
    benv = as_batched(env)
    whole_horizon = (benv.step_det is not None
                     and benv.noise_fn is not None)

    def step(carry, xs):
        rs = carry
        ka, ks, kr = xs
        x = _stack_obs(rs.frames)
        logits, value = policy_forward(params, x,
                                       fast_gates=cfg.fast_gates)
        a = jax.random.categorical(ka, logits)
        logp = jnp.take_along_axis(jax.nn.log_softmax(logits),
                                   a[..., None], -1)[..., 0]

        if whole_horizon:
            env_state, obs, r, _ = benv.step_det(rs.env_state, a, ks)
        else:
            env_state, obs, r, _ = benv.step(rs.env_state, a, ks)
        frames = jnp.concatenate(
            [rs.frames[..., 1:, :], obs[..., None, :]], axis=-2)

        t = rs.t_in_ep + 1
        done = t >= cfg.episode_len
        reset_state = benv.reset(kr, cfg.n_envs)
        env_state = jax.tree_util.tree_map(
            lambda n, i: jnp.where(
                done.reshape((-1,) + (1,) * (n.ndim - 1)), i, n),
            env_state, reset_state)
        obs0 = benv.observe(env_state)
        frames0 = jnp.zeros_like(frames).at[..., -1, :].set(obs0)
        done_f = done.reshape((-1,) + (1,) * (frames.ndim - 1))
        frames = jnp.where(done_f, frames0, frames)
        t = jnp.where(done, 0, t)

        done_b = jnp.broadcast_to(
            done.reshape((-1,) + (1,) * (r.ndim - 1)), r.shape)
        out = {"x": x, "a": a, "logp": logp, "v": value, "r": r,
               "done": done_b.astype(jnp.float32)}
        return RolloutState(env_state, frames, t), out

    keys = jax.random.split(key, cfg.rollout_len)
    # the per-tick (action, env, reset) keys, pre-split outside the scan —
    # the same values the historical in-body jax.random.split(k, 3) drew
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
    ka, ks, kr = k3[:, 0], k3[:, 1], k3[:, 2]
    env_xs = (horizon_noise(benv.noise_fn, ks, cfg.n_envs)
              if whole_horizon else ks)
    rs, batch = lax.scan(step, rs, (ka, env_xs, kr))
    x_last = _stack_obs(rs.frames)
    _, v_last = policy_forward(params, x_last, fast_gates=cfg.fast_gates)
    return rs, batch, v_last


def gae(batch, v_last, gamma, lam):
    def back(carry, xs):
        adv_next, v_next = carry
        v, r, done = xs
        nonterm = 1.0 - done
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = lax.scan(
        back, (jnp.zeros_like(v_last), v_last),
        (batch["v"], batch["r"], batch["done"]), reverse=True)
    returns = advs + batch["v"]
    return advs, returns


# ---------------------------------------------------------------------------
# PPO update
# ---------------------------------------------------------------------------

def ppo_loss(params, cfg: PPOConfig, mb):
    logits, v = policy_forward(params, mb["x"],
                               fast_gates=cfg.fast_gates)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, mb["a"][..., None], -1)[..., 0]
    ratio = jnp.exp(logp - mb["logp"])
    adv = mb["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg = -jnp.minimum(
        ratio * adv,
        jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv).mean()
    v_loss = jnp.square(v - mb["ret"]).mean()
    ent = -(jnp.exp(logp_all) * logp_all).sum(-1).mean()
    total = pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
    return total, {"pg_loss": pg, "v_loss": v_loss, "entropy": ent}


def make_train_iteration(env, cfg: PPOConfig):
    opt = adamw(cfg.lr, weight_decay=0.0, b2=0.999, clip_norm=0.5)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def train_iteration(params, opt_state, rs: RolloutState, key):
        k_roll, k_upd = jax.random.split(key)
        rs, batch, v_last = rollout(env, cfg, params, rs, k_roll)
        adv, ret = gae(batch, v_last, cfg.gamma, cfg.lam)
        total = batch["a"].size          # T * n_envs * n_agents samples
        flat = {
            "x": batch["x"].reshape(total, -1),
            "a": batch["a"].reshape(total),
            "logp": batch["logp"].reshape(total),
            "adv": adv.reshape(total),
            "ret": ret.reshape(total),
        }
        n_mb = cfg.n_minibatches
        mb_size = total // n_mb

        def epoch(carry, k):
            params, opt_state = carry
            perm = jax.random.permutation(k, total)[:n_mb * mb_size]
            perm = perm.reshape(n_mb, mb_size)

            def mb_step(carry, idx):
                params, opt_state = carry
                mb = jax.tree_util.tree_map(lambda v: v[idx], flat)
                (l, m), g = jax.value_and_grad(ppo_loss, has_aux=True)(
                    params, cfg, mb)
                params, opt_state, _ = opt.update(g, opt_state, params)
                return (params, opt_state), l

            (params, opt_state), ls = lax.scan(mb_step,
                                               (params, opt_state), perm)
            return (params, opt_state), ls.mean()

        (params, opt_state), losses = lax.scan(
            epoch, (params, opt_state), jax.random.split(k_upd, cfg.epochs))
        metrics = {"loss": losses.mean(),
                   "mean_reward": batch["r"].mean(),
                   "mean_value": batch["v"].mean()}
        return params, opt_state, rs, metrics

    return opt, train_iteration


def evaluate(env: Env, cfg: PPOConfig, params, key, *, n_episodes: int = 8,
             ep_len: int | None = None, per_agent: bool = False):
    """Mean per-step reward of the greedy policy on ``env`` (the paper's
    periodic evaluation on the GS). With ``per_agent`` on a multi-agent env,
    returns the (n_agents,) per-agent means instead of the overall mean."""
    ep_len = ep_len or cfg.episode_len
    ash = cfg.agent_shape

    def episode(key):
        k0, key = jax.random.split(key)
        state = env.reset(k0)
        frames = jnp.zeros(ash + (cfg.frame_stack, cfg.obs_dim))
        frames = frames.at[..., -1, :].set(env.observe(state))

        def step(carry, k):
            state, frames = carry
            x = frames.reshape(ash + (-1,)) if ash else frames.reshape(1, -1)
            logits, _ = policy_forward(params, x,
                                       fast_gates=cfg.fast_gates)
            a = (jnp.argmax(logits, -1) if ash else jnp.argmax(logits[0]))
            state, obs, r, _ = env.step(state, a, k)
            frames = jnp.concatenate(
                [frames[..., 1:, :], obs[..., None, :]], axis=-2)
            return (state, frames), r

        _, rs = lax.scan(step, (state, frames), jax.random.split(key, ep_len))
        return rs.mean(axis=0)        # () or (n_agents,)

    keys = jax.random.split(key, n_episodes)
    rewards = jax.jit(jax.vmap(episode))(keys).mean(axis=0)
    if per_agent and ash:
        return rewards
    return float(rewards.mean())
