"""PPO (Schulman et al. 2017) — the paper's RL algorithm (§5.1), pure JAX.

Policies are FNNs over a stack of the last k observations (Appendix F:
"policies are fed with a stack of the last 8 observations" in the warehouse;
k=1 in traffic). One training iteration = vectorised rollout (vmap over
environments, lax.scan over time) + GAE + clipped-objective epochs — a single
jitted program, so it runs identically on a GS, an IALS, or any F-IALS
variant, and shards over the mesh's data axes at scale.

Multi-agent (``PPOConfig.n_agents = A > 1``, parameter-shared): the env emits
(A, ...) per-agent obs/rewards; the agent axis rides along as an extra batch
dimension everywhere — one policy network, T * n_envs * A samples per update.
``shard_rollout`` places the env batch on the mesh ``data`` axis so rollouts
scale across devices.

The training-loop contract (see docs/ARCHITECTURE.md §"training-loop
contract"): when the env exposes the whole-horizon pair
``noise_fn``/``step_det``, the rollout hoists ALL of its randomness out of
the scan — the horizon's env noise (``horizon_noise``), per-tick Gumbel
noise for action sampling (``bulk_gumbel``; ``gumbel_argmax(logits, g)`` is
bitwise-equal to ``jax.random.categorical`` on the same key, which is
exactly how jax itself derives the draw), and the per-tick episode-reset
states — so the scan body is fully deterministic: frame-stack shift +
policy forward + ``step_det`` fuse into one pure-compute tick with zero
in-scan key derivation. When the env additionally provides
``policy_rollout`` (the unified IALS engine sets it when its kernel route
is active), the ENTIRE acting loop — act + AIP + LS + reward + resets —
is handed to the env as one whole-horizon dispatch — bit-identical to
the scan on every leaf except the value stream ``v`` (the fused routes
compute both policy heads as one GEMM, a 1-ulp drift documented in
ARCHITECTURE §4). The scan paths themselves are fully bit-identical;
``PPOConfig.hoist_rollout_noise=False`` is the documented opt-out that
preserves the keyed per-tick derivation exactly.

Learner side: GAE is a log-depth ``lax.associative_scan`` over the affine
recurrence (not a T-step sequential scan), minibatch epochs do ONE
permutation gather per epoch and stream contiguous slices through the
update scan (no per-minibatch gather copies), and ``train_iteration``
donates its (params, opt_state, rollout-state) arguments so each PPO
iteration updates in place instead of round-tripping fresh buffers.
"""
from __future__ import annotations

import functools

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.envs.api import BatchedEnv, Env, as_batched, horizon_noise
from repro.nn.act import fast_tanh
from repro.nn.module import dense_init, dense
from repro.optim.adamw import adamw


@dataclass(frozen=True)
class PPOConfig:
    obs_dim: int
    n_actions: int
    frame_stack: int = 1
    hidden: int = 128
    n_envs: int = 16
    rollout_len: int = 128
    episode_len: int = 256        # periodic env reset (episodic tasks)
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    lr: float = 3e-4
    epochs: int = 4
    n_minibatches: int = 4
    n_agents: int = 1             # leading agent axis of the env (1 = none)
    fast_gates: bool = True       # rational tanh (nn/act.py) in the policy
    #                               net — the same transcendental diet the
    #                               AIP tick got; False = exact jnp.tanh
    hoist_rollout_noise: bool = True  # pre-draw Gumbel action noise + reset
    #                               states alongside the bulk env noise so
    #                               the rollout scan body is deterministic;
    #                               False = the keyed per-tick derivation,
    #                               preserved exactly (the documented
    #                               opt-out — batches are bitwise-equal
    #                               either way)

    @property
    def agent_shape(self) -> tuple:
        return (self.n_agents,) if self.n_agents > 1 else ()


# ---------------------------------------------------------------------------
# Actor-critic network (FNN on frame-stacked obs)
# ---------------------------------------------------------------------------

def init_policy(cfg: PPOConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in = cfg.obs_dim * cfg.frame_stack
    return {
        "l1": dense_init(k1, d_in, cfg.hidden, bias=True),
        "l2": dense_init(k2, cfg.hidden, cfg.hidden, bias=True),
        "pi": dense_init(k3, cfg.hidden, cfg.n_actions, bias=True,
                         scale=0.01),
        "v": dense_init(k4, cfg.hidden, 1, bias=True, scale=0.1),
    }


def flat_policy_weights(params):
    """The flat ``(w1, b1, w2, b2, piw, pib, vw, vb)`` weight tuple — the
    policy-forward ABI shared by every fused consumer of this network:
    the kernels' ``_policy_cell`` / ``_policy_fwd_ref`` (actor-in-the-loop
    rollout), the unified engine's ``policy_rollout`` wiring, and the
    serving tier's slot forward (``kernels/ops.py::serve_forward``). One
    definition, so a params-layout change cannot silently skew the
    kernel routes."""
    return (params["l1"]["w"], params["l1"]["b"],
            params["l2"]["w"], params["l2"]["b"],
            params["pi"]["w"], params["pi"]["b"],
            params["v"]["w"], params["v"]["b"])


def stack_policy_weights(params_list):
    """Stack N checkpoints' ``flat_policy_weights`` tuples into one
    tuple of (N, ...) arrays — the cross-policy serving ABI consumed by
    ``kernels/ops.py::serve_forward_multi`` (one server, many
    checkpoints: lane p of a packed slot runs checkpoint
    ``policy_index[p]``). All checkpoints must share one architecture
    (same PPOConfig shapes); ``jnp.stack`` raises otherwise. Index 0 of
    every leading axis is ``params_list[0]``, so a one-entry stack is
    the single-policy ABI with a size-1 policy axis."""
    flats = [flat_policy_weights(p) for p in params_list]
    return tuple(jnp.stack(ws) for ws in zip(*flats))


def policy_forward(params, x, *, fast_gates: bool):
    """Actor-critic forward pass. ``fast_gates`` (required — thread
    ``PPOConfig.fast_gates`` so the config stays the single source of
    truth) evaluates the hidden tanh layers with the shared rational
    gates from ``nn/act.py`` (|err| < 1e-4, exact saturation) — the exact
    tanh transcendentals were the last per-tick policy cost the ROADMAP
    flagged on the rollout hot path. Training and rollout use the same
    setting, so PPO optimises exactly the network it acts with."""
    act = fast_tanh if fast_gates else jnp.tanh
    h = act(dense(params["l1"], x))
    h = act(dense(params["l2"], h))
    return dense(params["pi"], h), dense(params["v"], h)[..., 0]


# ---------------------------------------------------------------------------
# Action sampling: the hoisted Gumbel-max derivation
# ---------------------------------------------------------------------------

def bulk_gumbel(keys, shape, dtype=jnp.float32):
    """(T,) keys -> (T,) + shape Gumbel noise, row t being exactly
    ``jax.random.gumbel(keys[t], shape, dtype)`` — the same values
    ``jax.random.categorical(keys[t], logits)`` derives internally, drawn
    for the whole horizon before the rollout scan."""
    return jax.vmap(lambda k: jax.random.gumbel(k, shape, dtype))(keys)


def gumbel_argmax(logits, g):
    """Gumbel-max sampling on pre-drawn noise: bitwise-equal to
    ``jax.random.categorical(key, logits)`` when ``g`` came from
    ``jax.random.gumbel(key, logits.shape, logits.dtype)`` (float addition
    is commutative, and jax's categorical IS argmax(gumbel + logits) —
    pinned by the property test in tests/test_train_engine.py)."""
    return jnp.argmax(logits + g, axis=-1)


# ---------------------------------------------------------------------------
# Vectorised rollout with frame stacking + periodic resets
# ---------------------------------------------------------------------------

class RolloutState(NamedTuple):
    env_state: Any
    frames: jax.Array      # (n_envs, *agent_shape, k, obs_dim)
    t_in_ep: jax.Array     # (n_envs,) int32


def _stack_obs(frames):
    return frames.reshape(frames.shape[:-2] + (-1,))


def init_rollout_state(env, cfg: PPOConfig, key,
                       mesh=None) -> RolloutState:
    benv = as_batched(env)
    env_state = benv.reset(key, cfg.n_envs)
    obs = benv.observe(env_state)
    frames = jnp.zeros((cfg.n_envs,) + cfg.agent_shape
                       + (cfg.frame_stack, cfg.obs_dim))
    frames = frames.at[..., -1, :].set(obs)
    rs = RolloutState(env_state=env_state, frames=frames,
                      t_in_ep=jnp.zeros((cfg.n_envs,), jnp.int32))
    return shard_rollout(rs, mesh, n_agents=cfg.n_agents)


def shard_rollout(rs: RolloutState, mesh,
                  n_agents: int = 1) -> RolloutState:
    """Place the rollout state on the mesh under the IALS partition rules
    (``distributed/sharding.py``): env lanes over the data axes, the
    agent axis (frames' and the engine state's dim 1) co-sharded over
    "model" when it divides, replication fallback otherwise.

    Under jit the computation follows the input sharding, so the whole
    rollout (env steps included) executes data-parallel across devices.
    No-op for ``mesh=None`` or a single-device mesh."""
    if mesh is None:
        return rs
    from repro.distributed import sharding as shd
    return shd.shard_ials_state(rs, mesh, n_agents)


def _split_tick_keys(key, T: int):
    """The per-tick (action, env, reset) keys, pre-split outside the scan —
    the same values the historical in-body ``jax.random.split(k, 3)``
    drew, shared by every rollout path so they stay bitwise-comparable."""
    keys = jax.random.split(key, T)
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
    return k3[:, 0], k3[:, 1], k3[:, 2]


def rollout(env, cfg: PPOConfig, params, rs: RolloutState, key):
    """-> (new RolloutState, batch with (T, n_envs, *agent_shape, ...)
    leaves, v_last). The agent axis (if any) is just extra batch
    dimension: one parameter-shared policy acts for every agent of every
    env copy.

    Dispatch, most fused first — every path derives its randomness from
    the same pre-split keys, so the scan paths (2, 3) are bit-identical
    and path 1 matches them on every leaf except the 1-ulp ``v`` value
    stream (see the module docstring):
      1. ``benv.policy_rollout`` (the unified IALS engine sets it when
         its kernel route is active): the whole acting loop — frame
         stack, policy forward, Gumbel-argmax sampling, AIP + LS tick,
         reward, periodic resets — is ONE whole-horizon env dispatch
         (a single Pallas call on TPU).
      2. The hoisted deterministic scan (the off-TPU default when the env
         has ``noise_fn``/``step_det``): Gumbel action noise, env noise,
         and reset states are all pre-drawn, so the body is pure compute
         with zero in-scan key derivation.
      3. ``cfg.hoist_rollout_noise=False`` or no whole-horizon pair: the
         keyed per-tick path (``jax.random.categorical`` + in-scan
         resets; env noise still bulk when available) — the historical
         derivation, preserved exactly.
    """
    benv = as_batched(env)
    whole_horizon = (benv.step_det is not None
                     and benv.noise_fn is not None)
    hoist = cfg.hoist_rollout_noise and whole_horizon
    ka, ks, kr = _split_tick_keys(key, cfg.rollout_len)

    def finish_tick(rs, x, logits, value, a, env_state, obs, r,
                    reset_state):
        """Everything after the env step — frame update, periodic reset,
        batch row — shared verbatim by the keyed and hoisted bodies so
        they stay bitwise-equal by construction."""
        logp = jnp.take_along_axis(jax.nn.log_softmax(logits),
                                   a[..., None], -1)[..., 0]
        frames = jnp.concatenate(
            [rs.frames[..., 1:, :], obs[..., None, :]], axis=-2)
        t = rs.t_in_ep + 1
        done = t >= cfg.episode_len
        env_state = jax.tree_util.tree_map(
            lambda n, i: jnp.where(
                done.reshape((-1,) + (1,) * (n.ndim - 1)), i, n),
            env_state, reset_state)
        obs0 = benv.observe(env_state)
        frames0 = jnp.zeros_like(frames).at[..., -1, :].set(obs0)
        done_f = done.reshape((-1,) + (1,) * (frames.ndim - 1))
        frames = jnp.where(done_f, frames0, frames)
        t = jnp.where(done, 0, t)

        done_b = jnp.broadcast_to(
            done.reshape((-1,) + (1,) * (r.ndim - 1)), r.shape)
        out = {"x": x, "a": a, "logp": logp, "v": value, "r": r,
               "done": done_b.astype(jnp.float32)}
        return RolloutState(env_state, frames, t), out

    if hoist:
        gum = bulk_gumbel(
            ka, (cfg.n_envs,) + cfg.agent_shape + (cfg.n_actions,))
        env_noise = horizon_noise(benv.noise_fn, ks, cfg.n_envs)
        reset_states = jax.vmap(lambda k: benv.reset(k, cfg.n_envs))(kr)

        if benv.policy_rollout is not None:
            rs, batch = _engine_policy_rollout(
                benv, cfg, params, rs, gum, env_noise, reset_states)
        else:
            def step_h(carry, xs):
                rs = carry
                g, n, reset_state = xs
                x = _stack_obs(rs.frames)
                logits, value = policy_forward(params, x,
                                               fast_gates=cfg.fast_gates)
                a = gumbel_argmax(logits, g)
                env_state, obs, r, _ = benv.step_det(rs.env_state, a, n)
                return finish_tick(rs, x, logits, value, a, env_state,
                                   obs, r, reset_state)

            rs, batch = lax.scan(step_h, rs,
                                 (gum, env_noise, reset_states))
    else:
        def step_k(carry, xs):
            rs = carry
            ka, ks, kr = xs
            x = _stack_obs(rs.frames)
            logits, value = policy_forward(params, x,
                                           fast_gates=cfg.fast_gates)
            a = jax.random.categorical(ka, logits)
            if whole_horizon:
                env_state, obs, r, _ = benv.step_det(rs.env_state, a, ks)
            else:
                env_state, obs, r, _ = benv.step(rs.env_state, a, ks)
            reset_state = benv.reset(kr, cfg.n_envs)
            return finish_tick(rs, x, logits, value, a, env_state, obs,
                               r, reset_state)

        env_xs = (horizon_noise(benv.noise_fn, ks, cfg.n_envs)
                  if whole_horizon else ks)
        rs, batch = lax.scan(step_k, rs, (ka, env_xs, kr))

    x_last = _stack_obs(rs.frames)
    _, v_last = policy_forward(params, x_last, fast_gates=cfg.fast_gates)
    return rs, batch, v_last


def _engine_policy_rollout(benv: BatchedEnv, cfg: PPOConfig, params, rs,
                           gum, env_noise, reset_states):
    """Hand the whole acting loop to the env's ``policy_rollout`` (the
    unified engine's fused actor-in-the-loop dispatch) and reassemble the
    PPO batch from its streams. The engine computes logits/values with
    the same policy math, so ``logp`` derived from the streamed logits is
    bitwise-equal to the scan path's."""
    env_state, frames, t_in_ep, out = benv.policy_rollout(
        rs.env_state, rs.frames, rs.t_in_ep, params, gum, env_noise,
        reset_states, episode_len=cfg.episode_len,
        fast_gates=cfg.fast_gates)
    logp = jnp.take_along_axis(jax.nn.log_softmax(out["logits"]),
                               out["a"][..., None], -1)[..., 0]
    batch = {"x": out["x"], "a": out["a"], "logp": logp, "v": out["v"],
             "r": out["r"], "done": out["done"]}
    return RolloutState(env_state, frames, t_in_ep), batch


def gae(batch, v_last, gamma, lam):
    """Generalised advantage estimation as a log-depth parallel scan.

    The recurrence adv_t = delta_t + gamma*lam*nonterm_t * adv_{t+1} is a
    composition of affine maps, so it runs as a reverse
    ``lax.associative_scan`` over (coeff, delta) pairs — O(log T) passes
    of vectorised work instead of a T-step sequential dependency chain.
    Matches the sequential scan to float-association accuracy (the
    tests pin it against a hand-rolled backward recursion)."""
    v, r, done = batch["v"], batch["r"], batch["done"]
    nonterm = 1.0 - done
    v_next = jnp.concatenate([v[1:], v_last[None]], axis=0)
    delta = r + gamma * v_next * nonterm - v
    coeff = (gamma * lam) * nonterm

    def compose(a, b):
        # affine map composition — in a reverse associative_scan the
        # SECOND argument is the earlier timestep, which wraps the later
        # suffix: (b ∘ a)(x) = cb*(ca*x + da) + db. Associative, so the
        # scan may regroup freely.
        ca, da = a
        cb, db = b
        return cb * ca, db + cb * da

    _, advs = lax.associative_scan(compose, (coeff, delta), reverse=True)
    returns = advs + v
    return advs, returns


# ---------------------------------------------------------------------------
# PPO update
# ---------------------------------------------------------------------------

def ppo_loss(params, cfg: PPOConfig, mb):
    logits, v = policy_forward(params, mb["x"],
                               fast_gates=cfg.fast_gates)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, mb["a"][..., None], -1)[..., 0]
    ratio = jnp.exp(logp - mb["logp"])
    adv = mb["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg = -jnp.minimum(
        ratio * adv,
        jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv).mean()
    v_loss = jnp.square(v - mb["ret"]).mean()
    ent = -(jnp.exp(logp_all) * logp_all).sum(-1).mean()
    total = pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
    return total, {"pg_loss": pg, "v_loss": v_loss, "entropy": ent}


def make_optimizer(cfg: PPOConfig):
    """The PPO optimizer — one definition shared by the jitted trainer
    and the AOT dry-run lowering (launch/dryrun.py)."""
    return adamw(cfg.lr, weight_decay=0.0, b2=0.999, clip_norm=0.5)


def learner_update_fn(cfg: PPOConfig, opt):
    """The pure learner half of a PPO iteration —
    ``(params, opt_state, batch, v_last, key) -> (params, opt_state,
    metrics)``: GAE + flatten + minibatch epochs over an already-collected
    trajectory batch.

    This is the exact program ``train_iteration`` runs after its rollout
    (the integrated trainer calls it), split out so the *disaggregated*
    actor/learner trainer (``distributed/actor_learner.py``) applies the
    identical update to batches streamed in from rollout workers. PPO's
    clipped ratio ``exp(logp_new - logp_behavior)`` is computed against
    the ``logp`` the batch was *acted* with, so a batch produced by a
    stale policy version is importance-corrected (and clipped) for free —
    that, plus the fleet's ``max_staleness`` drop policy, is the
    off-policy correction story (documented in ARCHITECTURE's
    fault-tolerance contract)."""

    def learner_update(params, opt_state, batch, v_last, key):
        adv, ret = gae(batch, v_last, cfg.gamma, cfg.lam)
        total = batch["a"].size          # T * n_envs * n_agents samples
        flat = {
            "x": batch["x"].reshape(total, -1),
            "a": batch["a"].reshape(total),
            "logp": batch["logp"].reshape(total),
            "adv": adv.reshape(total),
            "ret": ret.reshape(total),
        }
        n_mb = cfg.n_minibatches
        mb_size = total // n_mb

        def epoch(carry, k):
            params, opt_state = carry
            # ONE permutation gather per epoch; the scan then streams
            # contiguous (mb_size, ...) slices — no per-minibatch gather
            # copies (same minibatch contents as gathering row-by-row)
            perm = jax.random.permutation(k, total)[:n_mb * mb_size]
            shuf = jax.tree_util.tree_map(
                lambda v: v[perm].reshape((n_mb, mb_size)
                                          + v.shape[1:]), flat)

            def mb_step(carry, mb):
                params, opt_state = carry
                (l, m), g = jax.value_and_grad(ppo_loss, has_aux=True)(
                    params, cfg, mb)
                params, opt_state, _ = opt.update(g, opt_state, params)
                return (params, opt_state), l

            (params, opt_state), ls = lax.scan(mb_step,
                                               (params, opt_state), shuf)
            return (params, opt_state), ls.mean()

        (params, opt_state), losses = lax.scan(
            epoch, (params, opt_state), jax.random.split(key, cfg.epochs))
        metrics = {"loss": losses.mean(),
                   "mean_reward": batch["r"].mean(),
                   "mean_value": batch["v"].mean()}
        return params, opt_state, metrics

    return learner_update


def train_iteration_fn(env, cfg: PPOConfig, opt, mesh=None):
    """The pure (un-jitted) one-PPO-iteration function —
    ``(params, opt_state, rs, key) -> (params, opt_state, rs, metrics)``.
    ``make_train_iteration`` jits it with donation; the dry-run harness
    lowers it AOT with explicitly sharded arguments instead. ``mesh``
    pins the rollout state to the IALS partition rules at iteration entry
    (params and optimizer state stay replicated — pure DP, gradients
    all-reduce); ``mesh=None`` adds no constraint ops. The learner half
    is ``learner_update_fn`` — shared verbatim with the disaggregated
    actor/learner trainer, so the two trainers apply bitwise-identical
    updates to identical batches."""
    learner_update = learner_update_fn(cfg, opt)

    def train_iteration(params, opt_state, rs: RolloutState, key):
        if mesh is not None:
            from repro.distributed import sharding as shd
            rs = shd.constrain_ials_state(rs, mesh, cfg.n_agents)
        k_roll, k_upd = jax.random.split(key)
        rs, batch, v_last = rollout(env, cfg, params, rs, k_roll)
        params, opt_state, metrics = learner_update(
            params, opt_state, batch, v_last, k_upd)
        return params, opt_state, rs, metrics

    return train_iteration


def make_train_iteration(env, cfg: PPOConfig, mesh=None):
    opt = make_optimizer(cfg)
    # donation audit: params / opt_state / rollout state update in place
    # every iteration; the key is tiny and freshly split by the caller,
    # so it stays undonated
    train_iteration = jax.jit(train_iteration_fn(env, cfg, opt, mesh),
                              donate_argnums=(0, 1, 2))
    return opt, train_iteration


# ---------------------------------------------------------------------------
# Greedy evaluation on the batched whole-horizon path
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _cached_evaluator(env, cfg: PPOConfig, n_episodes: int, ep_len: int):
    benv = as_batched(env)
    whole = benv.step_det is not None and benv.noise_fn is not None
    ash = cfg.agent_shape

    def run(params, key):
        k0, ks = jax.random.split(key)
        state = benv.reset(k0, n_episodes)
        frames = jnp.zeros((n_episodes,) + ash
                           + (cfg.frame_stack, cfg.obs_dim))
        frames = frames.at[..., -1, :].set(benv.observe(state))
        keys = jax.random.split(ks, ep_len)
        xs = (horizon_noise(benv.noise_fn, keys, n_episodes) if whole
              else keys)

        def tick(carry, x):
            state, frames = carry
            logits, _ = policy_forward(params, _stack_obs(frames),
                                       fast_gates=cfg.fast_gates)
            a = jnp.argmax(logits, -1)
            if whole:
                state, obs, r, _ = benv.step_det(state, a, x)
            else:
                state, obs, r, _ = benv.step(state, a, x)
            frames = jnp.concatenate(
                [frames[..., 1:, :], obs[..., None, :]], axis=-2)
            return (state, frames), r

        _, rews = lax.scan(tick, (state, frames), xs, unroll=8)
        return rews.mean(axis=0).mean(axis=0)       # () or (n_agents,)

    return jax.jit(run)


def make_evaluator(env, cfg: PPOConfig, *, n_episodes: int = 8,
                   ep_len: int | None = None):
    """-> cached jitted ``fn(params, key) -> mean rewards`` (scalar array,
    or (n_agents,) on a multi-agent env).

    The greedy policy needs no action noise, so evaluation episodes ride
    the batched env protocol directly: episodes ARE the env batch, env
    randomness is drawn in bulk when the env exposes
    ``noise_fn``/``step_det``, and the whole evaluation is one jitted
    scan-of-batched-ticks instead of a vmap of per-episode scalar keyed
    scans. The evaluator is cached per (env, cfg, sizes), so periodic
    evaluation stops re-tracing every call."""
    return _cached_evaluator(env, cfg, n_episodes,
                             ep_len or cfg.episode_len)


def evaluate(env, cfg: PPOConfig, params, key, *, n_episodes: int = 8,
             ep_len: int | None = None, per_agent: bool = False):
    """Mean per-step reward of the greedy policy on ``env`` (the paper's
    periodic evaluation on the GS). ``env`` may be a scalar ``Env`` or a
    native ``BatchedEnv`` (the fused IALS engines evaluate directly).
    With ``per_agent`` on a multi-agent env, returns the (n_agents,)
    per-agent means instead of the overall mean.

    Estimator note: episodes-as-batch draws env randomness with one key
    per tick (shared across episodes, the batched protocol's derivation)
    instead of the historical per-episode key chains — the same
    distribution over trajectories, not the same key stream; the
    equivalence test pins the two paths together on key-independent
    dynamics."""
    run = make_evaluator(env, cfg, n_episodes=n_episodes, ep_len=ep_len)
    rewards = run(params, key)
    if per_agent and cfg.agent_shape:
        return rewards
    return float(jnp.asarray(rewards).mean())
