"""Approximate Influence Predictor (AIP) — paper §4, Appendix F.

``Î_θ(u_t | d_t)``: a sequence model over d-set features emitting M
independent Bernoulli heads (Eq. 12). Two backbones, as in the paper:

- "gru": recurrent, processes d_t one at a time (Eq. 11) — memoryful.
- "fnn": feedforward over a stack of the last ``stack`` d-sets — the
  finite-memory (k-step) predictor of Theorem 1; stack=1 is memoryless
  (the NM-AIP of §5.4).

Training (Algorithm 1's dataset): expected cross-entropy (Eq. 3) == summed
binary CE over heads, minimised with AdamW. ``train_aip`` optionally
truncates BPTT windows to k steps — the practical Theorem-1 knob
(Appendix F: "the sequence length should be at least as long as the
agent's").

The framework also exposes every assigned LM architecture as an AIP
backbone at scale (see repro/launch and DESIGN.md §3); this module is the
paper-scale implementation.
"""
from __future__ import annotations

import functools

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.act import fast_sigmoid, uniform_from_bits
from repro.nn.module import dense_init, dense
from repro.nn.rnn import gru_init, gru_cell
from repro.optim.adamw import adamw

Params = Dict[str, Any]


@dataclass(frozen=True)
class AIPConfig:
    kind: str           # "gru" | "fnn"
    d_in: int           # d-set feature size
    n_out: int          # M influence sources
    hidden: int = 64
    stack: int = 1      # fnn memory length (ignored for gru)


def init_aip(cfg: AIPConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.kind == "gru":
        return {"gru": gru_init(k1, cfg.d_in, cfg.hidden),
                "head": dense_init(k2, cfg.hidden, cfg.n_out, bias=True)}
    if cfg.kind == "fnn":
        return {"l1": dense_init(k1, cfg.d_in * cfg.stack, cfg.hidden,
                                 bias=True),
                "l2": dense_init(k2, cfg.hidden, cfg.hidden, bias=True),
                "head": dense_init(k3, cfg.hidden, cfg.n_out, bias=True)}
    raise ValueError(cfg.kind)


# --- single-step API (used inside the IALS rollout scan) -------------------

def init_state(cfg: AIPConfig, batch_shape: tuple = ()) -> jax.Array:
    if cfg.kind == "gru":
        return jnp.zeros(batch_shape + (cfg.hidden,), jnp.float32)
    return jnp.zeros(batch_shape + (cfg.stack, cfg.d_in), jnp.float32)


def step(params: Params, cfg: AIPConfig, state, d_t: jax.Array):
    """d_t: (..., d_in) -> (logits (..., M), new state)."""
    if cfg.kind == "gru":
        h = gru_cell(params["gru"], state, d_t)
        return dense(params["head"], h), h
    buf = jnp.concatenate([state[..., 1:, :], d_t[..., None, :]], axis=-2)
    x = buf.reshape(*buf.shape[:-2], -1)
    h = jax.nn.relu(dense(params["l1"], x))
    h = jax.nn.relu(dense(params["l2"], h))
    return dense(params["head"], h), buf


def step_sample(params: Params, cfg: AIPConfig, state, d_t: jax.Array,
                bits: jax.Array):
    """One fused AIP tick WITH the Bernoulli draw: d_t (B, d_in) and
    counter-based random bits (B, M) uint32 -> (logits, new state, u).

    This is the rollout engine's inner call: for the GRU backbone it routes
    through ``kernels.ops.aip_step`` — one Pallas invocation on TPU (cell +
    head + sigmoid + threshold-compare in VMEM), the identical-math jnp
    oracle elsewhere. The FNN backbone has no recurrent matmul to fuse, so
    it reuses ``step`` and applies the same threshold-compare convention.
    """
    from repro.kernels import ops  # deferred: keeps kernels optional

    if cfg.kind == "gru":
        h2, logits, u = ops.aip_step(
            d_t, state, params["gru"]["wx"], params["gru"]["wh"],
            params["gru"]["b"], params["head"]["w"], params["head"]["b"],
            bits)
        return logits, h2, u
    logits, new_state = step(params, cfg, state, d_t)
    u = (uniform_from_bits(bits) < fast_sigmoid(logits)
         ).astype(jnp.float32)
    return logits, new_state, u


def _fnn_step_multi(params: Params, cfg: AIPConfig, state, d_t):
    """Per-agent FNN step in (B, A, ...) layout without moving the stack
    buffer: params leaves are (A, ...); einsum contracts per agent in
    place. (The vmap-over-agents alternative transposes the whole
    (B, A, stack, d_in) buffer twice per tick — measurably slower.)"""
    buf = jnp.concatenate([state[..., 1:, :], d_t[..., None, :]], axis=-2)
    x = buf.reshape(*buf.shape[:-2], -1)
    h = jax.nn.relu(jnp.einsum('baf,afk->bak', x, params["l1"]["w"])
                    + params["l1"]["b"])
    h = jax.nn.relu(jnp.einsum('bak,akj->baj', h, params["l2"]["w"])
                    + params["l2"]["b"])
    logits = jnp.einsum('baj,ajm->bam', h, params["head"]["w"]) \
        + params["head"]["b"]
    return logits, buf


def step_multi(params: Params, cfg: AIPConfig, state, d_t):
    """A per-agent AIPs in one call: params leaves (A, ...), state/d_t
    leading (B, A). -> (logits (B, A, M), new state).

    FNN runs as the in-place stacked einsum (``_fnn_step_multi`` — a
    vmap would transpose the whole frame buffer twice per tick); GRU
    vmaps the single-agent step over the agent axis, which XLA CPU
    schedules measurably faster than the equivalent stacked einsum (the
    stacked formulation lives at the whole-horizon kernel boundary,
    where the grid structurally needs it — see ``kernels/aip_step.py``
    and the ``--ab`` bench's stacked-vs-vmapped rows)."""
    if cfg.kind == "fnn":
        return _fnn_step_multi(params, cfg, state, d_t)
    return jax.vmap(lambda p, h, d: step(p, cfg, h, d),
                    in_axes=(0, 1, 1), out_axes=(1, 1))(params, state, d_t)


def step_sample_multi(params: Params, cfg: AIPConfig, state, d_t, bits):
    """``step_sample`` for A per-agent AIPs: bits (B, A, M) uint32 ->
    (logits, new state, u), all leading (B, A). GRU routes through
    ``kernels.ops.aip_step_multi`` — on TPU an agent-axis vmap of the
    fused ``aip_step`` kernel, elsewhere the vmapped-per-agent oracle
    (the same computation the whole-horizon rollout oracle scans); FNN
    samples on top of the in-place einsum step."""
    if cfg.kind == "fnn":
        logits, new_state = _fnn_step_multi(params, cfg, state, d_t)
        u = (uniform_from_bits(bits) < fast_sigmoid(logits)
             ).astype(jnp.float32)
        return logits, new_state, u
    from repro.kernels import ops  # deferred: keeps kernels optional
    h2, logits, u = ops.aip_step_multi(
        d_t, state, params["gru"]["wx"], params["gru"]["wh"],
        params["gru"]["b"], params["head"]["w"], params["head"]["b"], bits)
    return logits, h2, u


def apply_sequence(params: Params, cfg: AIPConfig, dsets: jax.Array):
    """dsets: (B, T, d_in) -> logits (B, T, M). Scan of ``step``."""
    B = dsets.shape[0]
    st0 = init_state(cfg, (B,))

    def body(st, d):
        lg, st = step(params, cfg, st, d)
        return st, lg

    _, lgs = lax.scan(body, st0, jnp.moveaxis(dsets, 1, 0))
    return jnp.moveaxis(lgs, 0, 1)


# --- loss / training --------------------------------------------------------

def xent_loss(params: Params, cfg: AIPConfig, dsets, us) -> jax.Array:
    """Eq. 3: mean summed binary cross-entropy over the M heads."""
    logits = apply_sequence(params, cfg, dsets)
    ll = us * jax.nn.log_sigmoid(logits) + \
        (1.0 - us) * jax.nn.log_sigmoid(-logits)
    return -ll.sum(-1).mean()


def accuracy(params: Params, cfg: AIPConfig, dsets, us) -> jax.Array:
    logits = apply_sequence(params, cfg, dsets)
    pred = (logits > 0).astype(jnp.float32)
    return (pred == us).astype(jnp.float32).mean()


def _train_core(cfg: AIPConfig, dsets, us, key, *, epochs: int,
                batch_size: int, lr: float, window: int):
    """Pure training loop: (N, T, ...) data -> (params, (epochs,) losses).

    Everything is scanned (epochs included), so the whole fit is one jitted
    program — and, crucially, it vmaps: ``train_aip_batched`` maps it over a
    leading agent axis to fit N per-agent AIPs in a single batched pass.
    """
    N, T = dsets.shape[:2]
    if window and window < T:
        n_win = T // window
        dsets = dsets[:, :n_win * window].reshape(N * n_win, window, -1)
        us = us[:, :n_win * window].reshape(N * n_win, window, us.shape[-1])
        N, T = dsets.shape[:2]
    params = init_aip(cfg, key)
    opt = adamw(lr, weight_decay=0.0, clip_norm=1.0)
    ost = opt.init(params)
    batch_size = min(batch_size, N)
    n_batches = max(1, N // batch_size)

    # same split chain as the historical per-epoch Python loop
    def split_chain(k, _):
        k, ke = jax.random.split(k)
        return k, ke
    _, epoch_keys = lax.scan(split_chain, key, None, length=epochs)

    def epoch(carry, ke):
        params, ost = carry
        perm = jax.random.permutation(ke, N)[:n_batches * batch_size]
        perm = perm.reshape(n_batches, batch_size)

        def body(carry, idx):
            params, ost = carry
            l, g = jax.value_and_grad(xent_loss)(
                params, cfg, dsets[idx], us[idx])
            params, ost, _ = opt.update(g, ost, params)
            return (params, ost), l

        (params, ost), losses = lax.scan(body, (params, ost), perm)
        return (params, ost), losses.mean()

    (params, _), losses = lax.scan(epoch, (params, ost), epoch_keys)
    return params, losses


# The jitted fit entry points live at module level with the config
# threaded through static_argnames, so repeated fits at the same
# shapes/config reuse one compiled program — the historical closure-jit
# re-traced on EVERY train_aip call.
#
# Donation audit (the ``donate=True`` flag): XLA input-output aliasing
# is structurally UNUSABLE at this boundary — the only outputs are the
# fitted params and the (epochs,) losses, and neither matches the
# dataset buffers, so ``jit(donate_argnums=...)`` would be a warning and
# a no-op on every backend. What the callers actually want from
# "donating the epoch buffers" is ownership: the fit consumes the
# dataset, so its memory is released the moment training returns
# instead of lingering until the caller's references die. ``donate=True``
# implements exactly that — the buffers are deleted after the fit (the
# caller's arrays become invalid), the fitted params are identical
# either way.

_FIT_STATICS = ("cfg", "epochs", "batch_size", "lr", "window")


@functools.partial(jax.jit, static_argnames=_FIT_STATICS)
def _fit(dsets, us, key, *, cfg, epochs, batch_size, lr, window):
    return _train_core(cfg, dsets, us, key, epochs=epochs,
                       batch_size=batch_size, lr=lr, window=window)


@functools.partial(jax.jit, static_argnames=_FIT_STATICS)
def _fit_batched(dsets, us, keys, *, cfg, epochs, batch_size, lr,
                 window):
    return jax.vmap(lambda d, u, k: _train_core(
        cfg, d, u, k, epochs=epochs, batch_size=batch_size, lr=lr,
        window=window))(dsets, us, keys)


def _consume(*bufs):
    for b in bufs:
        if hasattr(b, "delete"):
            b.delete()


def train_aip(cfg: AIPConfig, dsets, us, key, *, epochs: int = 10,
              batch_size: int = 32, lr: float = 3e-3, window: int = 0,
              donate: bool = False) -> Tuple[Params, Dict]:
    """Fit the AIP on (N, T, d_in)/(N, T, M) sequences from Algorithm 1.

    ``window`` > 0 truncates each sampled sequence to that many steps
    (Theorem 1: match it to the agent's memory k). ``donate=True``
    donates the (dsets, us) epoch buffers to the fit: their memory is
    released as soon as training returns and the caller's arrays become
    invalid — pass it when the dataset is dead after the fit (the
    production drivers do; diagnostics that re-read the data keep the
    default). Fitted params are identical either way.
    """
    params, losses = _fit(dsets, us, key, cfg=cfg, epochs=epochs,
                          batch_size=batch_size, lr=lr, window=window)
    history = [float(l) for l in losses]
    if donate:
        _consume(dsets, us)
    metrics = {"loss_history": history,
               "final_loss": history[-1] if history else float("nan")}
    return params, metrics


def train_aip_batched(cfg: AIPConfig, dsets, us, keys, *, epochs: int = 10,
                      batch_size: int = 32, lr: float = 3e-3,
                      window: int = 0,
                      donate: bool = False) -> Tuple[Params, Dict]:
    """Fit A independent AIPs in one batched pass — ``vmap`` of the training
    loop over a leading agent axis (the Distributed-IALS construction).

    ``dsets``: (A, N, T, d_in), ``us``: (A, N, T, M), ``keys``: (A,) PRNG
    keys. Returns params with (A, ...) stacked leaves + per-agent losses.
    ``donate`` as in ``train_aip``.
    """
    params, losses = _fit_batched(dsets, us, keys, cfg=cfg, epochs=epochs,
                                  batch_size=batch_size, lr=lr,
                                  window=window)
    final = losses[:, -1] if losses.shape[-1] else losses.sum(-1)
    metrics = {"final_loss_per_agent": [float(l) for l in final],
               "final_loss": float(final.mean())}
    if donate:
        _consume(dsets, us)
    return params, metrics
