"""Distributed IALS (Suau et al. 2022): N local simulators in one program.

Every agent region gets its own IALS — a LocalEnv plus a per-agent AIP — and
all N are stacked into a single ``Env`` whose step is one ``vmap`` over the
agent axis. Combined with the PPO rollout's vmap over environments and scan
over time, the whole 5x5 traffic grid (25 agents) or 6x6 warehouse floor
(36 agents) simulates as one jitted program; this is the batched-simulation
throughput lever (Shacklett et al. 2021) applied to the IALS construction.

State / action / obs / reward all carry a leading (A, ...) agent axis, the
same convention as the multi-agent GS factories in ``repro.envs``, so the
RL layer treats an A-agent IALS exactly like a multi-agent GS.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.envs.api import Env, LocalEnv


class MultiIALSState(NamedTuple):
    ls_state: object      # LocalEnv state with (A, ...) stacked leaves
    aip_state: jax.Array  # (A, ...) per-agent AIP recurrent state


def make_multi_ials(local_env: LocalEnv, aip_params,
                    aip_cfg: influence.AIPConfig, n_agents: int, *,
                    fixed_marginal: Optional[float] = None,
                    fixed_marginal_vec=None) -> Env:
    """-> Env with the multi-agent GS signature.

    ``aip_params``: pytree with (A, ...) stacked leaves — one AIP per agent
    (from ``influence.train_aip_batched`` or a ``vmap`` of ``init_aip``).
    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) shared or
    (A, M) per-agent) switch every simulator into F-IALS mode.
    """
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key):
        ls = jax.vmap(local_env.reset)(jax.random.split(key, A))
        return MultiIALSState(ls_state=ls,
                              aip_state=influence.init_state(aip_cfg, (A,)))

    def single_step(params, ls_state, aip_state, action, u_probs_fixed, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(ls_state, action)
        logits, new_aip = influence.step(params, aip_cfg, aip_state, d_t)
        probs = (u_probs_fixed if marg is not None
                 else jax.nn.sigmoid(logits))
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return ls2, new_aip, obs, r, info

    vstep = jax.vmap(single_step)

    def step(state: MultiIALSState, actions, key):
        keys = jax.random.split(key, A)
        fixed = (marg if marg is not None
                 else jnp.zeros((A, M), jnp.float32))
        ls2, new_aip, obs, r, info = vstep(
            aip_params, state.ls_state, state.aip_state, actions, fixed,
            keys)
        return MultiIALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: MultiIALSState):
        return jax.vmap(local_env.observe)(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)
