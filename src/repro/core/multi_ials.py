"""Distributed IALS (Suau et al. 2022): N local simulators in one program.

Every agent region gets its own IALS — a LocalEnv plus a per-agent AIP — and
all N are stacked into a single ``Env`` whose step is one ``vmap`` over the
agent axis. Combined with the PPO rollout's vmap over environments and scan
over time, the whole 5x5 traffic grid (25 agents) or 6x6 warehouse floor
(36 agents) simulates as one jitted program; this is the batched-simulation
throughput lever (Shacklett et al. 2021) applied to the IALS construction.

State / action / obs / reward all carry a leading (A, ...) agent axis, the
same convention as the multi-agent GS factories in ``repro.envs``, so the
RL layer treats an A-agent IALS exactly like a multi-agent GS.

``make_multi_ials`` is the scalar-protocol construction (vmap of scalar
simulators). ``make_batched_multi_ials`` is the fused rollout engine: all
A·B lanes (A agents x B env copies) advance as ONE vectorized LS
transition, and the A per-agent AIPs run as one agent-vmapped fused AIP
step (``kernels/aip_step.py``) per tick, with the whole tick's random bits
drawn in bulk — the Distributed-IALS scaling story made real.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.core.ials import IALSState
from repro.envs.api import BatchedEnv, BatchedLocalEnv, Env, LocalEnv
from repro.nn.act import fast_sigmoid, uniform_from_bits


class MultiIALSState(NamedTuple):
    ls_state: object      # LocalEnv state with (A, ...) stacked leaves
    aip_state: jax.Array  # (A, ...) per-agent AIP recurrent state


def make_multi_ials(local_env: LocalEnv, aip_params,
                    aip_cfg: influence.AIPConfig, n_agents: int, *,
                    fixed_marginal: Optional[float] = None,
                    fixed_marginal_vec=None) -> Env:
    """-> Env with the multi-agent GS signature.

    ``aip_params``: pytree with (A, ...) stacked leaves — one AIP per agent
    (from ``influence.train_aip_batched`` or a ``vmap`` of ``init_aip``).
    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) shared or
    (A, M) per-agent) switch every simulator into F-IALS mode.
    """
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key):
        ls = jax.vmap(local_env.reset)(jax.random.split(key, A))
        return MultiIALSState(ls_state=ls,
                              aip_state=influence.init_state(aip_cfg, (A,)))

    def single_step(params, ls_state, aip_state, action, u_probs_fixed, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(ls_state, action)
        logits, new_aip = influence.step(params, aip_cfg, aip_state, d_t)
        probs = (u_probs_fixed if marg is not None
                 else fast_sigmoid(logits))
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return ls2, new_aip, obs, r, info

    vstep = jax.vmap(single_step)

    def step(state: MultiIALSState, actions, key):
        keys = jax.random.split(key, A)
        fixed = (marg if marg is not None
                 else jnp.zeros((A, M), jnp.float32))
        ls2, new_aip, obs, r, info = vstep(
            aip_params, state.ls_state, state.aip_state, actions, fixed,
            keys)
        return MultiIALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: MultiIALSState):
        return jax.vmap(local_env.observe)(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_multi_ials(local_env: BatchedLocalEnv, aip_params,
                            aip_cfg: influence.AIPConfig, n_agents: int, *,
                            fixed_marginal: Optional[float] = None,
                            fixed_marginal_vec=None) -> BatchedEnv:
    """Fused Distributed IALS: (B, A, ...) leaves, one fused tick.

    ``local_env`` is a natively batched LS; its (B·A,)-lane batch axis
    carries every agent of every env copy, so the LS transition is a single
    vectorized call. The A per-agent AIPs ((A, ...)-stacked ``aip_params``)
    advance as one agent-axis vmap of the fused AIP step. Exposes the
    multi-agent ``BatchedEnv`` signature PPO consumes: actions (B, A), obs
    (B, A, obs_dim).
    """
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def _flat(tree, B):
        return jax.tree_util.tree_map(
            lambda l: l.reshape((B * A,) + l.shape[2:]), tree)

    def _unflat(tree, B):
        return jax.tree_util.tree_map(
            lambda l: l.reshape((B, A) + l.shape[1:]), tree)

    def reset(key, n_envs: int):
        ls = _unflat(local_env.reset(key, n_envs * A), n_envs)
        return IALSState(
            ls_state=ls,
            aip_state=influence.init_state(aip_cfg, (n_envs, A)))

    def step(state: IALSState, actions, key):
        B = actions.shape[0]
        k_u, k_env = jax.random.split(key)
        ls_flat = _flat(state.ls_state, B)
        a_flat = actions.reshape(B * A)
        d_t = local_env.dset_fn(ls_flat, a_flat)       # (B·A, Dd)
        d_t = d_t.reshape(B, A, -1)
        bits = jax.random.bits(k_u, (B, A, M), jnp.uint32)
        if marg is None:
            logits, new_aip, u = influence.step_sample_multi(
                aip_params, aip_cfg, state.aip_state, d_t, bits)
            probs = fast_sigmoid(logits)
        else:
            _, new_aip = influence.step_multi(aip_params, aip_cfg,
                                              state.aip_state, d_t)
            probs = jnp.broadcast_to(marg, (B, A, M))
            u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(ls_flat, a_flat,
                                           u.reshape(B * A, M), k_env)
        info = dict(_unflat(info, B))
        info["u"] = u
        info["u_probs"] = probs
        return (IALSState(ls_state=_unflat(ls2, B), aip_state=new_aip),
                obs.reshape(B, A, -1), r.reshape(B, A), info)

    def observe(state: IALSState):
        B = jax.tree_util.tree_leaves(state.ls_state)[0].shape[0]
        obs = local_env.observe(_flat(state.ls_state, B))
        return obs.reshape(B, A, -1)

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe)
