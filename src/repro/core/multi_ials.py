"""Distributed IALS (Suau et al. 2022) — compatibility shim.

The duplicated multi-agent stepping logic that used to live here is
gone: since PR 4 the agent axis is just another batch/grid dimension of
the ONE unified engine in ``repro.core.engine``
(``make_unified_ials``), and the scalar vmap-of-simulators baseline
lives with its single-agent sibling in ``repro.core.ials``. This module
only re-exports the historical names.
"""
from __future__ import annotations

from repro.core.engine import (IALSState,  # noqa: F401
                               make_batched_multi_ials, make_unified_ials)
from repro.core.ials import MultiIALSState, make_multi_ials  # noqa: F401
