"""Distributed IALS (Suau et al. 2022): N local simulators in one program.

Every agent region gets its own IALS — a LocalEnv plus a per-agent AIP — and
all N are stacked into a single ``Env`` whose step is one ``vmap`` over the
agent axis. Combined with the PPO rollout's vmap over environments and scan
over time, the whole 5x5 traffic grid (25 agents) or 6x6 warehouse floor
(36 agents) simulates as one jitted program; this is the batched-simulation
throughput lever (Shacklett et al. 2021) applied to the IALS construction.

State / action / obs / reward all carry a leading (A, ...) agent axis, the
same convention as the multi-agent GS factories in ``repro.envs``, so the
RL layer treats an A-agent IALS exactly like a multi-agent GS.

``make_multi_ials`` is the scalar-protocol construction (vmap of scalar
simulators). ``make_batched_multi_ials`` is the fused rollout engine: all
A·B lanes (A agents x B env copies) advance as ONE vectorized LS
transition, and the A per-agent AIPs run as one agent-vmapped fused AIP
step (``kernels/aip_step.py``) per tick, with the whole tick's random bits
drawn in bulk — the Distributed-IALS scaling story made real. The batched
engine also implements the whole-horizon split (``noise_fn`` /
``step_det``, see ``envs/api.py``), so ``env_rollout`` draws every tick's
randomness for the whole horizon up front and scans the pure fused tick —
bitwise-equal to scanning ``step``. (An agent-vmapped lift of the
single-agent ``aip_rollout`` Pallas kernel is the open TPU step — it
would land as a ``rollout`` override; per-agent AIP weights keep the
agents out of the single kernel's shared-weight batch block.)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.core.ials import IALSState, _check_stateless
from repro.envs.api import BatchedEnv, BatchedLocalEnv, Env, LocalEnv
from repro.nn.act import fast_sigmoid, uniform_from_bits


class MultiIALSState(NamedTuple):
    ls_state: object      # LocalEnv state with (A, ...) stacked leaves
    aip_state: jax.Array  # (A, ...) per-agent AIP recurrent state


def make_multi_ials(local_env: LocalEnv, aip_params,
                    aip_cfg: influence.AIPConfig, n_agents: int, *,
                    fixed_marginal: Optional[float] = None,
                    fixed_marginal_vec=None,
                    stateless: bool = False) -> Env:
    """-> Env with the multi-agent GS signature.

    ``aip_params``: pytree with (A, ...) stacked leaves — one AIP per agent
    (from ``influence.train_aip_batched`` or a ``vmap`` of ``init_aip``).
    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) shared or
    (A, M) per-agent) switch every simulator into F-IALS mode;
    ``stateless=True`` freezes the ignored per-agent AIP states at init
    (see ``make_ials`` for the state-shape-parity tradeoff).
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key):
        ls = jax.vmap(local_env.reset)(jax.random.split(key, A))
        return MultiIALSState(ls_state=ls,
                              aip_state=influence.init_state(aip_cfg, (A,)))

    def single_step(params, ls_state, aip_state, action, u_probs_fixed, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(ls_state, action)
        if stateless:
            new_aip = aip_state
            probs = u_probs_fixed
        else:
            logits, new_aip = influence.step(params, aip_cfg, aip_state,
                                             d_t)
            probs = (u_probs_fixed if marg is not None
                     else fast_sigmoid(logits))
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return ls2, new_aip, obs, r, info

    vstep = jax.vmap(single_step)

    def step(state: MultiIALSState, actions, key):
        keys = jax.random.split(key, A)
        fixed = (marg if marg is not None
                 else jnp.zeros((A, M), jnp.float32))
        ls2, new_aip, obs, r, info = vstep(
            aip_params, state.ls_state, state.aip_state, actions, fixed,
            keys)
        return MultiIALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: MultiIALSState):
        return jax.vmap(local_env.observe)(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_multi_ials(local_env: BatchedLocalEnv, aip_params,
                            aip_cfg: influence.AIPConfig, n_agents: int, *,
                            fixed_marginal: Optional[float] = None,
                            fixed_marginal_vec=None,
                            stateless: bool = False) -> BatchedEnv:
    """Fused Distributed IALS: (B, A, ...) leaves, one fused tick.

    ``local_env`` is a natively batched LS; its (B·A,)-lane batch axis
    carries every agent of every env copy, so the LS transition is a single
    vectorized call. The A per-agent AIPs ((A, ...)-stacked ``aip_params``)
    advance as one agent-axis vmap of the fused AIP step. Exposes the
    multi-agent ``BatchedEnv`` signature PPO consumes: actions (B, A), obs
    (B, A, obs_dim). ``stateless`` as in ``make_ials`` (F-IALS only).

    Whole-horizon layer: ``noise_fn``/``step_det`` split the tick, so
    ``env_rollout`` draws the full horizon's bits and LS noise up front
    and scans the deterministic fused tick — no per-tick key derivation,
    bitwise-equal to scanning ``step``. No ``rollout`` override yet: it
    would duplicate exactly that path; the override slot is where the
    agent-vmapped whole-horizon kernel lands (ROADMAP open item).
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def _flat(tree, B):
        return jax.tree_util.tree_map(
            lambda l: l.reshape((B * A,) + l.shape[2:]), tree)

    def _unflat(tree, B):
        return jax.tree_util.tree_map(
            lambda l: l.reshape((B, A) + l.shape[1:]), tree)

    def reset(key, n_envs: int):
        ls = _unflat(local_env.reset(key, n_envs * A), n_envs)
        return IALSState(
            ls_state=ls,
            aip_state=influence.init_state(aip_cfg, (n_envs, A)))

    def noise_fn(key, n_envs: int):
        k_u, k_env = jax.random.split(key)
        bits = jax.random.bits(k_u, (n_envs, A, M), jnp.uint32)
        env = (local_env.noise_fn(k_env, n_envs * A)
               if local_env.noise_fn is not None else k_env)
        return {"bits": bits, "env": env}

    def _ls_step(ls_flat, a_flat, u_flat, env_noise):
        if local_env.step_det is not None:
            return local_env.step_det(ls_flat, a_flat, u_flat, env_noise)
        return local_env.step(ls_flat, a_flat, u_flat, env_noise)

    def step_det(state: IALSState, actions, noise):
        B = actions.shape[0]
        ls_flat = _flat(state.ls_state, B)
        a_flat = actions.reshape(B * A)
        d_t = local_env.dset_fn(ls_flat, a_flat)       # (B·A, Dd)
        d_t = d_t.reshape(B, A, -1)
        bits = noise["bits"]
        if marg is None:
            logits, new_aip, u = influence.step_sample_multi(
                aip_params, aip_cfg, state.aip_state, d_t, bits)
            probs = fast_sigmoid(logits)
        else:
            if stateless:
                new_aip = state.aip_state
            else:
                _, new_aip = influence.step_multi(aip_params, aip_cfg,
                                                  state.aip_state, d_t)
            probs = jnp.broadcast_to(marg, (B, A, M))
            u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
        ls2, obs, r, info = _ls_step(ls_flat, a_flat,
                                     u.reshape(B * A, M), noise["env"])
        info = dict(_unflat(info, B))
        info["u"] = u
        info["u_probs"] = probs
        return (IALSState(ls_state=_unflat(ls2, B), aip_state=new_aip),
                obs.reshape(B, A, -1), r.reshape(B, A), info)

    def step(state: IALSState, actions, key):
        return step_det(state, actions, noise_fn(key, actions.shape[0]))

    def observe(state: IALSState):
        B = jax.tree_util.tree_leaves(state.ls_state)[0].shape[0]
        obs = local_env.observe(_flat(state.ls_state, B))
        return obs.reshape(B, A, -1)

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe,
                      noise_fn=noise_fn, step_det=step_det)
