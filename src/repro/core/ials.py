"""IALS — Influence-Augmented Local Simulator (paper Fig. 1 right, Alg. 2).

Composes a Local Simulator with an AIP into something that *looks like a
global simulator* to the RL loop:

    step: 1. d_t   = dset_fn(x_t, a_t)
          2. p     = sigmoid(Î_θ(d_t | aip_state))     (or a fixed marginal)
          3. u_t   ~ Bernoulli(p)                       (per head, Eq. 12)
          4. x_t+1 ~ LS(x_t, a_t, u_t)

AIP variants from the paper's experiment grid:
  - trained AIP  -> IALS
  - freshly-initialised AIP -> untrained-IALS (§5.1)
  - fixed marginal P(u)=const -> F-IALS (App. E)

The whole step is pure JAX, so IALS rollouts vmap over thousands of
environments and shard over the ``data``/``pod`` mesh axes — each pod
simulates its own batch; this is the framework's scaling story for the
paper's "make data generation fast" contribution.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.envs.api import Env, LocalEnv


class IALSState(NamedTuple):
    ls_state: object
    aip_state: jax.Array


def make_ials(local_env: LocalEnv, aip_params, aip_cfg: influence.AIPConfig,
              *, fixed_marginal: Optional[float] = None,
              fixed_marginal_vec=None) -> Env:
    """-> Env with the GS signature (state, action, key)->(state,obs,r,info).

    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) per-head
    probabilities) switch the simulator into F-IALS mode: the AIP is ignored
    and u_t ~ Bernoulli(const), as in Appendix E.
    """
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")

    def reset(key):
        k1, k2 = jax.random.split(key)
        ls = local_env.reset(k1)
        return IALSState(ls_state=ls,
                         aip_state=influence.init_state(aip_cfg))

    def step(state: IALSState, action, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(state.ls_state, action)
        logits, new_aip = influence.step(aip_params, aip_cfg,
                                         state.aip_state, d_t)
        if fixed_marginal_vec is not None:
            probs = jnp.asarray(fixed_marginal_vec, jnp.float32)
        elif fixed_marginal is not None:
            probs = jnp.full((spec.n_influence,), fixed_marginal)
        else:
            probs = jax.nn.sigmoid(logits)
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(state.ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)
