"""IALS — Influence-Augmented Local Simulator (paper Fig. 1 right, Alg. 2).

Composes a Local Simulator with an AIP into something that *looks like a
global simulator* to the RL loop:

    step: 1. d_t   = dset_fn(x_t, a_t)
          2. p     = sigmoid(Î_θ(d_t | aip_state))     (or a fixed marginal)
          3. u_t   ~ Bernoulli(p)                       (per head, Eq. 12)
          4. x_t+1 ~ LS(x_t, a_t, u_t)

AIP variants from the paper's experiment grid:
  - trained AIP  -> IALS
  - freshly-initialised AIP -> untrained-IALS (§5.1)
  - fixed marginal P(u)=const -> F-IALS (App. E); ``stateless=True``
    additionally freezes the (ignored) AIP recurrent state instead of
    advancing it every tick

The whole step is pure JAX, so IALS rollouts vmap over thousands of
environments and shard over the ``data``/``pod`` mesh axes — each pod
simulates its own batch; this is the framework's scaling story for the
paper's "make data generation fast" contribution.

This module holds the *scalar-protocol* constructions (one simulator;
batch by vmapping it — kept for composability and the loop baselines):
``make_ials`` (single agent) and ``make_multi_ials`` (N agent regions
stacked by vmap, the Distributed-IALS construction of Suau et al. 2022).

The production simulators are the **unified fused rollout engine** in
``repro.core.engine``: ONE ``make_unified_ials`` implementation serves
{gru, fnn} backbones x {single, multi} agent multiplicity, with a
whole-horizon kernel route for every combination. ``make_batched_ials``
and ``make_batched_multi_ials`` are re-exported here as the historical
entry points.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
# the unified engine owns the batched protocol; re-exported for the
# historical import sites (core.ials was the engine before PR 4)
from repro.core.engine import (IALSState, _check_stateless,  # noqa: F401
                               make_batched_ials, make_batched_multi_ials,
                               make_unified_ials)
from repro.envs.api import Env, LocalEnv
from repro.nn.act import fast_sigmoid


def make_ials(local_env: LocalEnv, aip_params, aip_cfg: influence.AIPConfig,
              *, fixed_marginal: Optional[float] = None,
              fixed_marginal_vec=None, stateless: bool = False) -> Env:
    """-> Env with the GS signature (state, action, key)->(state,obs,r,info).

    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) per-head
    probabilities) switch the simulator into F-IALS mode: the AIP is ignored
    and u_t ~ Bernoulli(const), as in Appendix E.

    ``stateless=True`` (F-IALS only): skip the AIP forward pass entirely
    instead of advancing a recurrent state the sampler then ignores. The
    state *leaf* is kept — frozen at its init value — so the stateless
    F-IALS state pytree stays shape-compatible with every other variant
    (checkpoints, donated PPO rollout buffers, and `jax.lax.scan` carries
    are interchangeable across simulators). The tradeoff of that parity
    choice: the frozen leaf is NOT a warmed-up AIP state, so you cannot
    hand a stateless F-IALS rollout state to a trained-AIP simulator and
    expect the GRU to resume mid-history — swap simulators only at reset
    boundaries. Trajectories are bit-identical to the stateful F-IALS
    (the marginal sampler never reads the state); only the dead AIP
    compute disappears.
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")

    def reset(key):
        k1, k2 = jax.random.split(key)
        ls = local_env.reset(k1)
        return IALSState(ls_state=ls,
                         aip_state=influence.init_state(aip_cfg))

    def step(state: IALSState, action, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(state.ls_state, action)
        if stateless:
            new_aip = state.aip_state
        else:
            logits, new_aip = influence.step(aip_params, aip_cfg,
                                             state.aip_state, d_t)
        if fixed_marginal_vec is not None:
            probs = jnp.asarray(fixed_marginal_vec, jnp.float32)
        elif fixed_marginal is not None:
            probs = jnp.full((spec.n_influence,), fixed_marginal)
        else:
            probs = fast_sigmoid(logits)
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(state.ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)


class MultiIALSState(NamedTuple):
    ls_state: object      # LocalEnv state with (A, ...) stacked leaves
    aip_state: jax.Array  # (A, ...) per-agent AIP recurrent state


def make_multi_ials(local_env: LocalEnv, aip_params,
                    aip_cfg: influence.AIPConfig, n_agents: int, *,
                    fixed_marginal: Optional[float] = None,
                    fixed_marginal_vec=None,
                    stateless: bool = False) -> Env:
    """-> Env with the multi-agent GS signature (scalar protocol): N local
    simulators + N per-agent AIPs stacked into one vmapped step — the
    Distributed-IALS construction, kept as the vmap-of-scalar baseline
    the unified engine is benchmarked against.

    ``aip_params``: pytree with (A, ...) stacked leaves — one AIP per agent
    (from ``influence.train_aip_batched`` or a ``vmap`` of ``init_aip``).
    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) shared or
    (A, M) per-agent) switch every simulator into F-IALS mode;
    ``stateless=True`` freezes the ignored per-agent AIP states at init
    (see ``make_ials`` for the state-shape-parity tradeoff).
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    A = n_agents
    M = local_env.spec.n_influence
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+multi-ials",
                               n_agents=A)
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), (A, M))
    elif fixed_marginal is not None:
        marg = jnp.full((A, M), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key):
        ls = jax.vmap(local_env.reset)(jax.random.split(key, A))
        return MultiIALSState(ls_state=ls,
                              aip_state=influence.init_state(aip_cfg, (A,)))

    def single_step(params, ls_state, aip_state, action, u_probs_fixed, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(ls_state, action)
        if stateless:
            new_aip = aip_state
            probs = u_probs_fixed
        else:
            logits, new_aip = influence.step(params, aip_cfg, aip_state,
                                             d_t)
            probs = (u_probs_fixed if marg is not None
                     else fast_sigmoid(logits))
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return ls2, new_aip, obs, r, info

    vstep = jax.vmap(single_step)

    def step(state: MultiIALSState, actions, key):
        keys = jax.random.split(key, A)
        fixed = (marg if marg is not None
                 else jnp.zeros((A, M), jnp.float32))
        ls2, new_aip, obs, r, info = vstep(
            aip_params, state.ls_state, state.aip_state, actions, fixed,
            keys)
        return MultiIALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: MultiIALSState):
        return jax.vmap(local_env.observe)(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)
