"""IALS — Influence-Augmented Local Simulator (paper Fig. 1 right, Alg. 2).

Composes a Local Simulator with an AIP into something that *looks like a
global simulator* to the RL loop:

    step: 1. d_t   = dset_fn(x_t, a_t)
          2. p     = sigmoid(Î_θ(d_t | aip_state))     (or a fixed marginal)
          3. u_t   ~ Bernoulli(p)                       (per head, Eq. 12)
          4. x_t+1 ~ LS(x_t, a_t, u_t)

AIP variants from the paper's experiment grid:
  - trained AIP  -> IALS
  - freshly-initialised AIP -> untrained-IALS (§5.1)
  - fixed marginal P(u)=const -> F-IALS (App. E); ``stateless=True``
    additionally freezes the (ignored) AIP recurrent state instead of
    advancing it every tick

The whole step is pure JAX, so IALS rollouts vmap over thousands of
environments and shard over the ``data``/``pod`` mesh axes — each pod
simulates its own batch; this is the framework's scaling story for the
paper's "make data generation fast" contribution.

Two constructions:
  - ``make_ials``: the scalar ``Env`` protocol (one simulator; batch by
    vmapping it) — kept for composability and the loop baselines.
  - ``make_batched_ials``: the fused rollout engine — a ``BatchedEnv``
    whose step is ONE fused AIP invocation (GRU cell + head + sigmoid +
    Bernoulli threshold-compare, ``kernels/aip_step.py`` on TPU) plus ONE
    vectorized LS transition for the whole env batch, with all per-tick
    randomness drawn in bulk from a single key. This is what makes the
    IALS actually faster than the GS (ISSUE 2 / paper Fig. 3/5 middle).

The batched engine additionally implements the whole-horizon protocol
(``noise_fn`` / ``step_det`` / ``rollout`` — see ``envs/api.py`` and
docs/ARCHITECTURE.md): ``rollout`` advances all T ticks in one call, on
TPU as ONE ``aip_rollout`` Pallas dispatch with the AIP hidden state and
the LS state leaves VMEM-resident across the horizon, elsewhere as a
bulk-noise scan of the fused per-tick step. Every path is bitwise-equal
to scanning ``step`` with the same keys.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.envs.api import (BatchedEnv, BatchedLocalEnv, Env, LocalEnv,
                            horizon_noise)
from repro.nn.act import fast_sigmoid, uniform_from_bits

# dtypes the whole-horizon kernel cannot hold in VMEM scratch directly;
# the engine round-trips them through int32 at the kernel boundary
_ENC_DTYPES = (jnp.bool_, jnp.int8)


def _codec(treedef, dtypes):
    """(treedef, leaf dtypes) -> (encode, decode) for the kernel boundary:
    bool/int8 leaves become int32 inside the kernel. Closes over static
    metadata only, so the closures are safe to cache across traces."""

    def encode(vals):
        return tuple(v.astype(jnp.int32) if v.dtype in _ENC_DTYPES else v
                     for v in vals)

    def decode(vals):
        return jax.tree_util.tree_unflatten(
            treedef, [v.astype(dt) for v, dt in zip(vals, dtypes)])

    return encode, decode


class IALSState(NamedTuple):
    ls_state: object
    aip_state: jax.Array


def _check_stateless(stateless, fixed_marginal, fixed_marginal_vec):
    if stateless and fixed_marginal is None and fixed_marginal_vec is None:
        raise ValueError(
            "stateless=True only makes sense for the F-IALS (fixed "
            "marginal) variants: a trained/untrained AIP needs its "
            "recurrent state advanced every tick")


def make_ials(local_env: LocalEnv, aip_params, aip_cfg: influence.AIPConfig,
              *, fixed_marginal: Optional[float] = None,
              fixed_marginal_vec=None, stateless: bool = False) -> Env:
    """-> Env with the GS signature (state, action, key)->(state,obs,r,info).

    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) per-head
    probabilities) switch the simulator into F-IALS mode: the AIP is ignored
    and u_t ~ Bernoulli(const), as in Appendix E.

    ``stateless=True`` (F-IALS only): skip the AIP forward pass entirely
    instead of advancing a recurrent state the sampler then ignores. The
    state *leaf* is kept — frozen at its init value — so the stateless
    F-IALS state pytree stays shape-compatible with every other variant
    (checkpoints, donated PPO rollout buffers, and `jax.lax.scan` carries
    are interchangeable across simulators). The tradeoff of that parity
    choice: the frozen leaf is NOT a warmed-up AIP state, so you cannot
    hand a stateless F-IALS rollout state to a trained-AIP simulator and
    expect the GRU to resume mid-history — swap simulators only at reset
    boundaries. Trajectories are bit-identical to the stateful F-IALS
    (the marginal sampler never reads the state); only the dead AIP
    compute disappears.
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")

    def reset(key):
        k1, k2 = jax.random.split(key)
        ls = local_env.reset(k1)
        return IALSState(ls_state=ls,
                         aip_state=influence.init_state(aip_cfg))

    def step(state: IALSState, action, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(state.ls_state, action)
        if stateless:
            new_aip = state.aip_state
        else:
            logits, new_aip = influence.step(aip_params, aip_cfg,
                                             state.aip_state, d_t)
        if fixed_marginal_vec is not None:
            probs = jnp.asarray(fixed_marginal_vec, jnp.float32)
        elif fixed_marginal is not None:
            probs = jnp.full((spec.n_influence,), fixed_marginal)
        else:
            probs = fast_sigmoid(logits)
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(state.ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_ials(local_env: BatchedLocalEnv, aip_params,
                      aip_cfg: influence.AIPConfig, *,
                      fixed_marginal: Optional[float] = None,
                      fixed_marginal_vec=None,
                      stateless: bool = False,
                      use_horizon_kernel: Optional[bool] = None
                      ) -> BatchedEnv:
    """The fused rollout engine: a natively batched IALS.

    One tick for the whole (B,) env batch = one bulk uint32 bits draw, one
    fused AIP step (``influence.step_sample`` -> ``kernels.ops.aip_step``
    for the GRU backbone), one vectorized LS transition. The F-IALS
    switches (``fixed_marginal`` / ``fixed_marginal_vec`` / ``stateless``)
    behave as in ``make_ials``.

    Whole-horizon layer: ``noise_fn``/``step_det`` split the tick into its
    random draws and its deterministic remainder, and ``rollout`` advances
    all T ticks in one call — for a GRU backbone on TPU with an LS that
    exposes ``rollout_tick``, that is ONE ``kernels.ops.ials_rollout``
    Pallas dispatch with the AIP hidden state and every LS leaf resident
    in VMEM across the horizon; everywhere else, a bulk-noise scan of the
    fused per-tick step. All paths are bitwise-equal to scanning ``step``
    with the same keys (``env_rollout``'s contract).
    ``use_horizon_kernel`` overrides the backend auto-detection (None):
    True forces the ``ops.ials_rollout`` route off-TPU too (the parity
    tests cover the kernel glue that way), False pins the scan.
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")
    M = spec.n_influence
    if fixed_marginal_vec is not None:
        marg = jnp.asarray(fixed_marginal_vec, jnp.float32)
    elif fixed_marginal is not None:
        marg = jnp.full((M,), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key, n_envs: int):
        return IALSState(ls_state=local_env.reset(key, n_envs),
                         aip_state=influence.init_state(aip_cfg, (n_envs,)))

    def _batch(state: IALSState) -> int:
        return jax.tree_util.tree_leaves(state.ls_state)[0].shape[0]

    def noise_fn(key, n_envs: int):
        k_u, k_env = jax.random.split(key)
        bits = jax.random.bits(k_u, (n_envs, M), jnp.uint32)
        env = (local_env.noise_fn(k_env, n_envs)
               if local_env.noise_fn is not None else k_env)
        return {"bits": bits, "env": env}

    def _ls_step(ls_state, actions, u, env_noise):
        if local_env.step_det is not None:
            return local_env.step_det(ls_state, actions, u, env_noise)
        return local_env.step(ls_state, actions, u, env_noise)

    def step_det(state: IALSState, actions, noise):
        d_t = local_env.dset_fn(state.ls_state, actions)       # (B, Dd)
        B = d_t.shape[0]
        bits = noise["bits"]
        if marg is None:
            logits, new_aip, u = influence.step_sample(
                aip_params, aip_cfg, state.aip_state, d_t, bits)
            probs = fast_sigmoid(logits)
        else:
            if stateless:
                new_aip = state.aip_state
            else:
                _, new_aip = influence.step(aip_params, aip_cfg,
                                            state.aip_state, d_t)
            probs = jnp.broadcast_to(marg, (B, M))
            u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
        ls2, obs, r, info = _ls_step(state.ls_state, actions, u,
                                     noise["env"])
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def step(state: IALSState, actions, key):
        return step_det(state, actions, noise_fn(key, _batch(state)))

    # --- whole-horizon path -------------------------------------------
    _kernel_fns = {}      # structural key -> stable (tick, dset) closures
    #                       (stable identity keeps the kernel's jit cache
    #                       warm across rollout calls)

    def _kernel_closures(ls_def, ls_dtypes, nz_def, nz_dtypes):
        key_ = (ls_def, ls_dtypes, nz_def, nz_dtypes)
        if key_ not in _kernel_fns:
            ls_enc, ls_dec = _codec(ls_def, ls_dtypes)
            _, nz_dec = _codec(nz_def, nz_dtypes)

            def k_dset(vals, a):
                return local_env.dset_fn(ls_dec(vals), a)

            def k_tick(vals, a, u, nzv):
                st2, r = local_env.rollout_tick(ls_dec(vals), a, u,
                                                nz_dec(nzv))
                return ls_enc(jax.tree_util.tree_leaves(st2)), r

            _kernel_fns[key_] = (k_tick, k_dset)
        return _kernel_fns[key_]

    def rollout(state: IALSState, actions, keys):
        """(state, actions (T, B), keys (T,)) -> (state, rewards (T, B)):
        the whole horizon in one call, bitwise-equal to scanning
        ``step``."""
        B = _batch(state)
        noise = horizon_noise(noise_fn, keys, B)
        use_kernel = (marg is None and aip_cfg.kind == "gru"
                      and local_env.rollout_tick is not None
                      and (use_horizon_kernel if use_horizon_kernel
                           is not None
                           else jax.default_backend() == "tpu"))
        if use_kernel:
            from repro.kernels import ops  # deferred: keeps kernels
            #                                optional for the scan path
            ls_leaves, ls_def = jax.tree_util.tree_flatten(state.ls_state)
            nz_leaves, nz_def = jax.tree_util.tree_flatten(noise["env"])
            ls_dtypes = tuple(l.dtype for l in ls_leaves)
            nz_dtypes = tuple(l.dtype for l in nz_leaves)
            k_tick, k_dset = _kernel_closures(ls_def, ls_dtypes, nz_def,
                                              nz_dtypes)
            ls_enc, ls_dec = _codec(ls_def, ls_dtypes)
            nz_enc, _ = _codec(nz_def, nz_dtypes)
            g = aip_params["gru"]
            hd = aip_params["head"]
            final, h_T, rews = ops.ials_rollout(
                ls_enc(ls_leaves), state.aip_state, g["wx"], g["wh"],
                g["b"], hd["w"], hd["b"], actions, noise["bits"],
                nz_enc(nz_leaves), tick_fn=k_tick, dset_fn=k_dset)
            return (IALSState(ls_state=ls_dec(final), aip_state=h_T),
                    rews)

        def tick(carry, xs):
            a, n = xs
            s, _, r, _ = step_det(carry, a, n)
            return s, r

        return jax.lax.scan(tick, state, (actions, noise), unroll=8)

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe,
                      rollout=rollout, noise_fn=noise_fn,
                      step_det=step_det)
