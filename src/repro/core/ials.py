"""IALS — Influence-Augmented Local Simulator (paper Fig. 1 right, Alg. 2).

Composes a Local Simulator with an AIP into something that *looks like a
global simulator* to the RL loop:

    step: 1. d_t   = dset_fn(x_t, a_t)
          2. p     = sigmoid(Î_θ(d_t | aip_state))     (or a fixed marginal)
          3. u_t   ~ Bernoulli(p)                       (per head, Eq. 12)
          4. x_t+1 ~ LS(x_t, a_t, u_t)

AIP variants from the paper's experiment grid:
  - trained AIP  -> IALS
  - freshly-initialised AIP -> untrained-IALS (§5.1)
  - fixed marginal P(u)=const -> F-IALS (App. E)

The whole step is pure JAX, so IALS rollouts vmap over thousands of
environments and shard over the ``data``/``pod`` mesh axes — each pod
simulates its own batch; this is the framework's scaling story for the
paper's "make data generation fast" contribution.

Two constructions:
  - ``make_ials``: the scalar ``Env`` protocol (one simulator; batch by
    vmapping it) — kept for composability and the loop baselines.
  - ``make_batched_ials``: the fused rollout engine — a ``BatchedEnv``
    whose step is ONE fused AIP invocation (GRU cell + head + sigmoid +
    Bernoulli threshold-compare, ``kernels/aip_step.py`` on TPU) plus ONE
    vectorized LS transition for the whole env batch, with all per-tick
    randomness drawn in bulk from a single key. This is what makes the
    IALS actually faster than the GS (ISSUE 2 / paper Fig. 3/5 middle).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.envs.api import BatchedEnv, BatchedLocalEnv, Env, LocalEnv
from repro.nn.act import fast_sigmoid, uniform_from_bits


class IALSState(NamedTuple):
    ls_state: object
    aip_state: jax.Array


def make_ials(local_env: LocalEnv, aip_params, aip_cfg: influence.AIPConfig,
              *, fixed_marginal: Optional[float] = None,
              fixed_marginal_vec=None) -> Env:
    """-> Env with the GS signature (state, action, key)->(state,obs,r,info).

    ``fixed_marginal`` (scalar) or ``fixed_marginal_vec`` ((M,) per-head
    probabilities) switch the simulator into F-IALS mode: the AIP is ignored
    and u_t ~ Bernoulli(const), as in Appendix E.
    """
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")

    def reset(key):
        k1, k2 = jax.random.split(key)
        ls = local_env.reset(k1)
        return IALSState(ls_state=ls,
                         aip_state=influence.init_state(aip_cfg))

    def step(state: IALSState, action, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(state.ls_state, action)
        logits, new_aip = influence.step(aip_params, aip_cfg,
                                         state.aip_state, d_t)
        if fixed_marginal_vec is not None:
            probs = jnp.asarray(fixed_marginal_vec, jnp.float32)
        elif fixed_marginal is not None:
            probs = jnp.full((spec.n_influence,), fixed_marginal)
        else:
            probs = fast_sigmoid(logits)
        u = jax.random.bernoulli(k_u, probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(state.ls_state, action, u, k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def make_batched_ials(local_env: BatchedLocalEnv, aip_params,
                      aip_cfg: influence.AIPConfig, *,
                      fixed_marginal: Optional[float] = None,
                      fixed_marginal_vec=None) -> BatchedEnv:
    """The fused-step rollout engine: a natively batched IALS.

    One tick for the whole (B,) env batch = one bulk uint32 bits draw, one
    fused AIP step (``influence.step_sample`` -> ``kernels.ops.aip_step``
    for the GRU backbone), one vectorized LS transition. The F-IALS
    switches (``fixed_marginal`` / ``fixed_marginal_vec``) behave as in
    ``make_ials``.
    """
    spec = dataclasses.replace(local_env.spec,
                               name=local_env.spec.name + "+ials")
    M = spec.n_influence
    if fixed_marginal_vec is not None:
        marg = jnp.asarray(fixed_marginal_vec, jnp.float32)
    elif fixed_marginal is not None:
        marg = jnp.full((M,), fixed_marginal, jnp.float32)
    else:
        marg = None

    def reset(key, n_envs: int):
        return IALSState(ls_state=local_env.reset(key, n_envs),
                         aip_state=influence.init_state(aip_cfg, (n_envs,)))

    def step(state: IALSState, actions, key):
        k_u, k_env = jax.random.split(key)
        d_t = local_env.dset_fn(state.ls_state, actions)       # (B, Dd)
        B = d_t.shape[0]
        bits = jax.random.bits(k_u, (B, M), jnp.uint32)
        if marg is None:
            logits, new_aip, u = influence.step_sample(
                aip_params, aip_cfg, state.aip_state, d_t, bits)
            probs = fast_sigmoid(logits)
        else:
            _, new_aip = influence.step(aip_params, aip_cfg,
                                        state.aip_state, d_t)
            probs = jnp.broadcast_to(marg, (B, M))
            u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
        ls2, obs, r, info = local_env.step(state.ls_state, actions, u,
                                           k_env)
        info = dict(info)
        info["u"] = u
        info["u_probs"] = probs
        return IALSState(ls_state=ls2, aip_state=new_aip), obs, r, info

    def observe(state: IALSState):
        return local_env.observe(state.ls_state)

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe)
