"""Algorithm 1: collect a (d_t, u_t) dataset from the Global Simulator.

Rollouts under an exploratory policy π₀ (uniform random by default —
satisfying the support condition of §4.2), vmapped over episodes so the whole
collection is one jitted program. Returns stacked sequences so the AIP can be
trained with (optionally truncated) BPTT.

Multi-agent GS (``env.spec.n_agents = A > 1``): the same single rollout
yields every agent's (d_t, u_t) pairs at once — leaves come back as
(N, T, A, ...); ``per_agent`` transposes them to the (A, N, T, ...) layout
that ``influence.train_aip_batched`` consumes.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.envs.api import Env


def collect_dataset(env: Env, key, *, n_episodes: int, ep_len: int,
                    policy: Optional[Callable] = None,
                    dset_key: str = "dset") -> Dict[str, jax.Array]:
    """-> {"d": (N, T, Dd), "u": (N, T, M), "reward": (N, T)}.

    ``policy(key, obs) -> action`` defaults to uniform random (π₀).
    ``dset_key`` chooses "dset" (the d-separating set) or "dset_full"
    (d-set + confounders — the App. B ablation input).

    On a multi-agent GS each leaf gains an agent axis after T:
    d (N, T, A, Dd), u (N, T, A, M), reward (N, T, A).
    """
    n_actions = env.spec.n_actions
    a_shape = (env.spec.n_agents,) if env.spec.n_agents > 1 else ()

    def pi0(k, obs):
        return jax.random.randint(k, a_shape, 0, n_actions)

    pol = policy or pi0

    def episode(key):
        k0, key = jax.random.split(key)
        state = env.reset(k0)
        obs = env.observe(state)

        def step(carry, k):
            state, obs = carry
            ka, ks = jax.random.split(k)
            a = pol(ka, obs)
            state, obs2, r, info = env.step(state, a, ks)
            out = {"d": info[dset_key], "u": info["u"], "reward": r}
            return (state, obs2), out

        keys = jax.random.split(key, ep_len)
        _, traj = lax.scan(step, (state, obs), keys)
        return traj

    keys = jax.random.split(key, n_episodes)
    traj = jax.jit(jax.vmap(episode))(keys)
    return traj


def per_agent(data: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """(N, T, A, ...) multi-agent collection -> (A, N, T, ...) per-agent
    datasets (the layout ``train_aip_batched`` maps over)."""
    return {k: jnp.moveaxis(v, 2, 0) for k, v in data.items()}


def empirical_marginal(us: jax.Array, *, per_agent: bool = False
                       ) -> jax.Array:
    """P̂(u) per head from collected data — the F-IALS baseline (App. E).

    (N, T, M) -> (M,). With ``per_agent=True`` expects the ``per_agent``
    layout (A, N, T, M) and returns (A, M); the flag is explicit because a
    raw multi-agent collection (N, T, A, M) is also 4-D and would silently
    average the wrong axes."""
    if per_agent:
        if us.ndim != 4:
            raise ValueError(f"per_agent expects (A, N, T, M), got "
                             f"{us.shape}")
        return us.mean(axis=(1, 2))
    return us.reshape(-1, us.shape[-1]).mean(0)
