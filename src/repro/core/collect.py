"""Algorithm 1: collect a (d_t, u_t) dataset from the Global Simulator.

Rollouts under an exploratory policy π₀ (uniform random by default —
satisfying the support condition of §4.2), vmapped over episodes so the whole
collection is one jitted program. Returns stacked sequences so the AIP can be
trained with (optionally truncated) BPTT.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.envs.api import Env


def collect_dataset(env: Env, key, *, n_episodes: int, ep_len: int,
                    policy: Optional[Callable] = None,
                    dset_key: str = "dset") -> Dict[str, jax.Array]:
    """-> {"d": (N, T, Dd), "u": (N, T, M), "reward": (N, T)}.

    ``policy(key, obs) -> action`` defaults to uniform random (π₀).
    ``dset_key`` chooses "dset" (the d-separating set) or "dset_full"
    (d-set + confounders — the App. B ablation input).
    """
    n_actions = env.spec.n_actions

    def pi0(k, obs):
        return jax.random.randint(k, (), 0, n_actions)

    pol = policy or pi0

    def episode(key):
        k0, key = jax.random.split(key)
        state = env.reset(k0)
        obs = env.observe(state)

        def step(carry, k):
            state, obs = carry
            ka, ks = jax.random.split(k)
            a = pol(ka, obs)
            state, obs2, r, info = env.step(state, a, ks)
            out = {"d": info[dset_key], "u": info["u"], "reward": r}
            return (state, obs2), out

        keys = jax.random.split(key, ep_len)
        _, traj = lax.scan(step, (state, obs), keys)
        return traj

    keys = jax.random.split(key, n_episodes)
    traj = jax.jit(jax.vmap(episode))(keys)
    return traj


def empirical_marginal(us: jax.Array) -> jax.Array:
    """P̂(u) per head from collected data — the F-IALS baseline (App. E)."""
    return us.reshape(-1, us.shape[-1]).mean(0)
