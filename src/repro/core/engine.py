"""The unified IALS rollout engine: one implementation, every variant.

``make_unified_ials`` builds the fused, natively batched IALS
(`BatchedEnv`) for ANY point of the paper's simulator grid:

    backbone      x  agent multiplicity  x  AIP variant
    {gru, fnn}       {single A=1, multi}    {trained, untrained, F-IALS}

Single- and multi-agent are not separate engines any more (they were, in
PRs 2-3): the agent axis is just another batch dimension of one fused
tick, and — following the batched-simulation playbook of Shacklett et
al. 2021 — just another *grid dimension* of one whole-horizon rollout
kernel. Single-agent is the A=1 squeeze, mirroring how the env layer
squeezes its 1-agent multi envs.

One tick for the whole (B,) env batch (times A agents) = one bulk uint32
bits draw, one fused AIP step (``core.influence``'s multi-agent steps —
``kernels/aip_step.py`` on TPU; per backbone, whichever of the stacked
/ vmapped formulations measures faster off-TPU), one vectorized LS
transition over all B·A lanes. State leaves are (B, ...) when A=1 and
(B, A, ...) otherwise; PPO consumes either shape as extra batch
dimensions.

Whole-horizon layer (``noise_fn`` / ``step_det`` / ``rollout`` /
``policy_rollout`` — see ``envs/api.py`` and docs/ARCHITECTURE.md):
``rollout`` advances all T ticks in one call, and ``policy_rollout``
goes one level further — the PPO actor joins the loop (policy forward,
Gumbel-argmax actions, episode resets traced in alongside the AIP+LS
tick), so an entire acting horizon is one ``kernels.ops.policy_rollout``
dispatch; the slot is set only when the kernel route is active (TPU, or
``use_horizon_kernel=True``), since off-TPU PPO's own hoisted scan is
the bit-identical default. When the AIP is real (not a fixed marginal) and the
LS exposes ``rollout_tick``, that is ONE kernel-route dispatch —
``kernels.ops.ials_rollout_multi`` (GRU) or ``kernels.ops.fnn_rollout``
(FNN) — with the AIP recurrent state and every LS leaf VMEM-resident
across the horizon on TPU, and the identical-math stacked oracle scan
elsewhere; lanes are reordered agent-major ((A·B,) with lane ``a*B+b``)
at the boundary so each kernel lane block indexes its own agent's
weights, and bool/int8 leaves round-trip through int32 via
``envs.api.kernel_codec``. Otherwise ``rollout`` is a bulk-noise scan of
the fused per-tick step. Every path is bitwise-equal to scanning
``step`` with the same keys (``env_rollout``'s contract; enforced by
tests/test_rollout_engine.py for all backbone x multiplicity combos).

``make_batched_ials`` / ``make_batched_multi_ials`` are thin wrappers
kept as the historical entry points.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.envs.api import (BatchedEnv, BatchedLocalEnv, horizon_noise,
                            kernel_codec)
from repro.nn.act import fast_sigmoid, uniform_from_bits


class IALSState(NamedTuple):
    ls_state: object      # LS state; (B, ...) leaves, (B, A, ...) if multi
    aip_state: jax.Array  # (B, [A,] H) GRU hidden / (B, [A,] stack, d_in)


def _check_stateless(stateless, fixed_marginal, fixed_marginal_vec):
    if stateless and fixed_marginal is None and fixed_marginal_vec is None:
        raise ValueError(
            "stateless=True only makes sense for the F-IALS (fixed "
            "marginal) variants: a trained/untrained AIP needs its "
            "recurrent state advanced every tick")


def make_unified_ials(local_env: BatchedLocalEnv, aip_params,
                      aip_cfg: influence.AIPConfig, *,
                      n_agents: int = 1,
                      fixed_marginal: Optional[float] = None,
                      fixed_marginal_vec=None,
                      stateless: bool = False,
                      use_horizon_kernel: Optional[bool] = None,
                      mesh=None) -> BatchedEnv:
    """The unified fused rollout engine — a natively batched IALS for any
    backbone x multiplicity combination.

    ``local_env`` is a natively batched LS; with ``n_agents = A > 1`` its
    (B·A,)-lane batch axis carries every agent of every env copy
    (``aip_params`` leaves are (A, ...) stacked — one AIP per agent) and
    the engine exposes the multi-agent ``BatchedEnv`` signature PPO
    consumes: actions (B, A), obs (B, A, obs_dim). With ``n_agents=1``
    the agent axis is squeezed off every leaf and ``aip_params`` is a
    plain single-AIP pytree.

    ``fixed_marginal`` (scalar) / ``fixed_marginal_vec`` ((M,) shared or
    (A, M) per-agent) switch every simulator into F-IALS mode (App. E);
    ``stateless=True`` (F-IALS only) freezes the ignored AIP state at its
    init value — the leaf is kept for state-shape parity (checkpoints,
    donated PPO buffers, and scan carries stay interchangeable across
    variants), at the cost that the frozen leaf is not a warmed-up
    recurrent state: swap simulators only at reset boundaries.

    ``use_horizon_kernel`` overrides the ``rollout`` backend
    auto-detection (None = the kernel route on TPU, the bulk-noise scan
    elsewhere): True forces the ``kernels.ops`` route off-TPU too (on CPU
    that is the stacked oracle scan — the parity tests cover the kernel
    glue that way), False pins the scan.

    ``mesh`` (a ``jax.sharding.Mesh``) turns on SPMD partitioning: state
    entering and leaving ``step_det`` / ``rollout`` / ``policy_rollout``
    is pinned to the IALS rules of ``distributed/sharding.py`` (env lanes
    over the data axes, the agent axis over "model" when it divides) via
    ``with_sharding_constraint``, and GSPMD propagates through the
    horizon. ``reset`` stays unconstrained on purpose: constraining its
    output back-propagates the sharding into the threefry RNG lowering
    and changes the drawn bits — shard fresh states eagerly with
    ``sharding.shard_ials_state`` instead. ``mesh=None`` (or a size-1
    mesh) adds no constraint ops — the default program is bitwise
    unchanged — and data-parallel lane sharding introduces no cross-lane
    reductions, so the sharded rollout is bitwise-equal to the
    single-device one (tests/test_sharding.py).
    """
    _check_stateless(stateless, fixed_marginal, fixed_marginal_vec)
    if mesh is not None:
        from repro.distributed import sharding as _shd
        if _shd.mesh_size(mesh) == 1:
            mesh = None

    def _constrain(state: "IALSState") -> "IALSState":
        if mesh is None:
            return state
        from repro.distributed import sharding as _shd
        return _shd.constrain_ials_state(state, mesh, n_agents)

    A = n_agents
    multi = A > 1
    M = local_env.spec.n_influence
    spec = dataclasses.replace(
        local_env.spec,
        name=local_env.spec.name + ("+multi-ials" if multi else "+ials"),
        n_agents=A)
    ash = (A,) if multi else ()
    if fixed_marginal_vec is not None:
        marg = jnp.broadcast_to(
            jnp.asarray(fixed_marginal_vec, jnp.float32), ash + (M,))
    elif fixed_marginal is not None:
        marg = jnp.full(ash + (M,), fixed_marginal, jnp.float32)
    else:
        marg = None

    tmap = jax.tree_util.tree_map

    # (B, A, ...) <-> (B*A, ...) batch-major — the LS's native lane order
    def _flat(tree, B):
        if not multi:
            return tree
        return tmap(lambda l: l.reshape((B * A,) + l.shape[2:]), tree)

    def _unflat(tree, B):
        if not multi:
            return tree
        return tmap(lambda l: l.reshape((B, A) + l.shape[1:]), tree)

    def reset(key, n_envs: int):
        # NOT constrained: a sharding constraint here back-propagates into
        # the threefry lowering of the LS's random init draws and changes
        # the drawn bits (jax_threefry_partitionable=False), breaking the
        # sharded-vs-single-device bitwise contract. Eager placement is
        # ``sharding.shard_ials_state``'s job; the in-horizon constraints
        # (step_det / rollout / policy_rollout) are the bitwise-safe ones.
        return IALSState(
            ls_state=_unflat(local_env.reset(key, n_envs * A), n_envs),
            aip_state=influence.init_state(aip_cfg, (n_envs,) + ash))

    def _batch(state: IALSState) -> int:
        return jax.tree_util.tree_leaves(state.ls_state)[0].shape[0]

    def noise_fn(key, n_envs: int):
        k_u, k_env = jax.random.split(key)
        bits = jax.random.bits(k_u, (n_envs,) + ash + (M,), jnp.uint32)
        env = (local_env.noise_fn(k_env, n_envs * A)
               if local_env.noise_fn is not None else k_env)
        return {"bits": bits, "env": env}

    def _ls_step(ls_flat, a_flat, u_flat, env_noise):
        if local_env.step_det is not None:
            return local_env.step_det(ls_flat, a_flat, u_flat, env_noise)
        return local_env.step(ls_flat, a_flat, u_flat, env_noise)

    def step_det(state: IALSState, actions, noise):
        B = actions.shape[0]
        ls_flat = _flat(state.ls_state, B)
        a_flat = actions.reshape((B * A,)) if multi else actions
        d_t = local_env.dset_fn(ls_flat, a_flat)       # (B·A, Dd)
        if multi:
            d_t = d_t.reshape(B, A, -1)
        bits = noise["bits"]
        if marg is None:
            sample = (influence.step_sample_multi if multi
                      else influence.step_sample)
            logits, new_aip, u = sample(aip_params, aip_cfg,
                                        state.aip_state, d_t, bits)
            probs = fast_sigmoid(logits)
        else:
            if stateless:
                new_aip = state.aip_state
            else:
                fwd = influence.step_multi if multi else influence.step
                _, new_aip = fwd(aip_params, aip_cfg, state.aip_state,
                                 d_t)
            probs = jnp.broadcast_to(marg, (B,) + ash + (M,))
            u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
        u_flat = u.reshape(B * A, M) if multi else u
        ls2, obs, r, info = _ls_step(ls_flat, a_flat, u_flat,
                                     noise["env"])
        info = dict(_unflat(info, B))
        info["u"] = u
        info["u_probs"] = probs
        if multi:
            obs, r = obs.reshape(B, A, -1), r.reshape(B, A)
        return _constrain(IALSState(ls_state=_unflat(ls2, B),
                                    aip_state=new_aip)), obs, r, info

    def step(state: IALSState, actions, key):
        return step_det(state, actions, noise_fn(key, actions.shape[0]))

    # --- whole-horizon path -------------------------------------------
    # agent-major lane layout at the kernel boundary: lane a*B + b, so
    # each kernel lane block belongs to one agent and indexes that
    # agent's stacked weights (no-ops when A == 1)
    def _lane_fold(x):                    # (B, A, ...) -> (A·B, ...)
        if not multi:
            return x
        return x.swapaxes(0, 1).reshape((-1,) + x.shape[2:])

    def _lane_unfold(x, B):               # (A·B, ...) -> (B, A, ...)
        if not multi:
            return x
        return x.reshape((A, B) + x.shape[1:]).swapaxes(0, 1)

    def _stream_fold(x):                  # (T, B, A, ...) -> (T, A·B, ...)
        if not multi:
            return x
        return x.swapaxes(1, 2).reshape((x.shape[0], -1) + x.shape[3:])

    def _stream_unfold(x, B):             # (T, A·B, ...) -> (T, B, A, ...)
        if not multi:
            return x
        return x.reshape((x.shape[0], A, B) + x.shape[2:]).swapaxes(1, 2)

    def _noise_fold(x, B):   # (T, B·A, ...) batch-major -> (T, A·B, ...)
        if not multi:
            return x
        return _stream_fold(x.reshape((x.shape[0], B, A) + x.shape[2:]))

    _kernel_fns = {}      # structural key -> stable (tick, dset, obs)
    #                       closures (stable identity keeps the kernel's
    #                       jit cache warm across rollout calls)

    def _kernel_closures(ls_def, ls_dtypes, nz_def, nz_dtypes):
        key_ = (ls_def, ls_dtypes, nz_def, nz_dtypes)
        if key_ not in _kernel_fns:
            ls_enc, ls_dec = kernel_codec(ls_def, ls_dtypes)
            _, nz_dec = kernel_codec(nz_def, nz_dtypes)

            def k_dset(vals, a):
                return local_env.dset_fn(ls_dec(vals), a)

            def k_tick(vals, a, u, nzv):
                st2, r = local_env.rollout_tick(ls_dec(vals), a, u,
                                                nz_dec(nzv))
                return ls_enc(jax.tree_util.tree_leaves(st2)), r

            def k_obs(vals):
                return local_env.obs_fn(ls_dec(vals))

            _kernel_fns[key_] = (k_tick, k_dset, k_obs)
        return _kernel_fns[key_]

    def _stacked(tree):
        """aip_params with a leading (A,) axis on every leaf (the A=1
        squeeze stacks on the fly)."""
        return tree if multi else tmap(lambda l: l[None], tree)

    def rollout(state: IALSState, actions, keys):
        """(state, actions (T, B[, A]), keys (T,)) -> (state, rewards
        (T, B[, A])): the whole horizon in one call, bitwise-equal to
        scanning ``step``."""
        state = _constrain(state)
        B = _batch(state)
        noise = horizon_noise(noise_fn, keys, B)
        use_kernel = (marg is None
                      and local_env.rollout_tick is not None
                      and local_env.noise_fn is not None
                      and (use_horizon_kernel if use_horizon_kernel
                           is not None
                           else jax.default_backend() == "tpu"))
        if use_kernel:
            from repro.kernels import ops  # deferred: keeps kernels
            #                                optional for the scan path
            ls_leaves, ls_def = jax.tree_util.tree_flatten(
                tmap(_lane_fold, state.ls_state))
            nz_leaves, nz_def = jax.tree_util.tree_flatten(
                tmap(lambda l: _noise_fold(l, B), noise["env"]))
            ls_dtypes = tuple(l.dtype for l in ls_leaves)
            nz_dtypes = tuple(l.dtype for l in nz_leaves)
            k_tick, k_dset, _ = _kernel_closures(ls_def, ls_dtypes,
                                                 nz_def, nz_dtypes)
            ls_enc, ls_dec = kernel_codec(ls_def, ls_dtypes)
            nz_enc, _ = kernel_codec(nz_def, nz_dtypes)
            acts = _stream_fold(actions)               # (T, A·B)
            bits = _stream_fold(noise["bits"])         # (T, A·B, M)
            p = _stacked(aip_params)
            if aip_cfg.kind == "gru":
                g, hd = p["gru"], p["head"]
                final, sT, rews = ops.ials_rollout_multi(
                    ls_enc(ls_leaves), _lane_fold(state.aip_state),
                    g["wx"], g["wh"], g["b"], hd["w"], hd["b"], acts,
                    bits, nz_enc(nz_leaves), n_agents=A, tick_fn=k_tick,
                    dset_fn=k_dset)
                aip_T = _lane_unfold(sT, B)
            else:
                buf0 = _lane_fold(state.aip_state)     # (L, stack, d_in)
                L = buf0.shape[0]
                buf0 = buf0.reshape(L, -1)
                final, sT, rews = ops.fnn_rollout(
                    ls_enc(ls_leaves), buf0, p["l1"]["w"], p["l1"]["b"],
                    p["l2"]["w"], p["l2"]["b"], p["head"]["w"],
                    p["head"]["b"], acts, bits, nz_enc(nz_leaves),
                    n_agents=A, tick_fn=k_tick, dset_fn=k_dset)
                aip_T = _lane_unfold(
                    sT.reshape(L, aip_cfg.stack, aip_cfg.d_in), B)
            ls_T = tmap(lambda l: _lane_unfold(l, B), ls_dec(final))
            return (_constrain(IALSState(ls_state=ls_T, aip_state=aip_T)),
                    _stream_unfold(rews, B))

        def tick(carry, xs):
            a, n = xs
            s, _, r, _ = step_det(carry, a, n)
            return s, r

        return jax.lax.scan(tick, state, (actions, noise), unroll=8)

    # --- actor-in-the-loop path (the training-loop contract) ----------
    # set on the env ONLY when the kernel route is active (TPU, or
    # forced via use_horizon_kernel=True): PPO hands the whole acting
    # loop over; off-TPU by default PPO's own hoisted bulk-noise scan is
    # the bit-identical program, so there is nothing to dispatch to
    kernel_route = (marg is None
                    and local_env.rollout_tick is not None
                    and local_env.noise_fn is not None
                    and local_env.obs_fn is not None
                    and (use_horizon_kernel if use_horizon_kernel
                         is not None
                         else jax.default_backend() == "tpu"))

    def policy_rollout(state: IALSState, frames, t_in_ep, pol_params,
                       gumbel, noise, reset_states, *, episode_len: int,
                       fast_gates: bool):
        """``BatchedEnv.policy_rollout`` (see envs/api.py): T PPO acting
        ticks — policy forward, Gumbel-argmax actions, AIP sample, LS
        tick, reward, periodic resets — as ONE ``kernels.ops`` dispatch
        (the Pallas kernel on TPU, the identical-math oracle scan
        elsewhere). All randomness arrives pre-drawn: ``gumbel``
        (T, B, [A,] n_actions), ``noise`` = ``horizon_noise`` of this
        engine's ``noise_fn``, ``reset_states`` = T-stacked ``reset``
        results. The episode-reset schedule is closed-form from
        ``t_in_ep`` (invariant: 0 <= t_in_ep < episode_len, which PPO's
        reset logic maintains); resets restore the streamed LS leaves
        and re-zero the AIP state (its init value)."""
        from repro.kernels import ops  # deferred: keeps kernels optional
        state = _constrain(state)
        B = _batch(state)
        T = gumbel.shape[0]
        ls_leaves, ls_def = jax.tree_util.tree_flatten(
            tmap(_lane_fold, state.ls_state))
        nz_leaves, nz_def = jax.tree_util.tree_flatten(
            tmap(lambda l: _noise_fold(l, B), noise["env"]))
        ls_dtypes = tuple(l.dtype for l in ls_leaves)
        nz_dtypes = tuple(l.dtype for l in nz_leaves)
        k_tick, k_dset, k_obs = _kernel_closures(ls_def, ls_dtypes,
                                                 nz_def, nz_dtypes)
        ls_enc, ls_dec = kernel_codec(ls_def, ls_dtypes)
        nz_enc, _ = kernel_codec(nz_def, nz_dtypes)
        rls_leaves, _ = jax.tree_util.tree_flatten(
            tmap(_stream_fold, reset_states.ls_state))

        # the deterministic reset schedule: tick i is done iff the
        # episode counter hits episode_len there — exactly the scan
        # path's t >= episode_len given the 0 <= t_in_ep invariant
        ticks = (t_in_ep[None, :] + 1
                 + jnp.arange(T, dtype=jnp.int32)[:, None])
        done_env = (ticks % episode_len) == 0            # (T, B)
        t_out = (t_in_ep + T) % episode_len
        done_lanes = done_env.astype(jnp.int32)
        if multi:                       # lane a*B + b <-> env b
            done_lanes = jnp.tile(done_lanes, (1, A))

        frames_l = _lane_fold(frames)                    # (L, k, d)
        stack, d_obs = frames_l.shape[-2], frames_l.shape[-1]
        p = _stacked(aip_params)
        if aip_cfg.kind == "gru":
            aw = (p["gru"]["wx"], p["gru"]["wh"], p["gru"]["b"],
                  p["head"]["w"], p["head"]["b"])
            s0 = _lane_fold(state.aip_state)
        else:
            aw = (p["l1"]["w"], p["l1"]["b"], p["l2"]["w"],
                  p["l2"]["b"], p["head"]["w"], p["head"]["b"])
            buf = _lane_fold(state.aip_state)
            s0 = buf.reshape(buf.shape[0], -1)
        from repro.rl.ppo import flat_policy_weights  # deferred: no cycle
        pw = flat_policy_weights(pol_params)
        fin_ls, sT, fT, x, a, logits, v, r = ops.policy_rollout(
            ls_enc(ls_leaves), s0,
            frames_l.reshape(frames_l.shape[0], -1), aw, pw,
            _stream_fold(gumbel), _stream_fold(noise["bits"]),
            done_lanes, nz_enc(nz_leaves), ls_enc(rls_leaves),
            kind=aip_cfg.kind, n_agents=A, fast_gates=fast_gates,
            tick_fn=k_tick, dset_fn=k_dset, obs_fn=k_obs)
        ls_T = tmap(lambda l: _lane_unfold(l, B), ls_dec(fin_ls))
        if aip_cfg.kind == "gru":
            aip_T = _lane_unfold(sT, B)
        else:
            aip_T = _lane_unfold(
                sT.reshape(-1, aip_cfg.stack, aip_cfg.d_in), B)
        frames_T = _lane_unfold(fT.reshape(-1, stack, d_obs), B)
        r_u = _stream_unfold(r, B)
        ash_n = 1 if multi else 0
        done_b = jnp.broadcast_to(
            done_env.reshape(done_env.shape + (1,) * ash_n),
            r_u.shape).astype(jnp.float32)
        out = {"x": _stream_unfold(x, B), "a": _stream_unfold(a, B),
               "logits": _stream_unfold(logits, B),
               "v": _stream_unfold(v, B), "r": r_u, "done": done_b}
        return (_constrain(IALSState(ls_state=ls_T, aip_state=aip_T)),
                frames_T, t_out, out)

    def observe(state: IALSState):
        B = _batch(state)
        obs = local_env.observe(_flat(state.ls_state, B))
        return obs.reshape(B, A, -1) if multi else obs

    return BatchedEnv(spec=spec, reset=reset, step=step, observe=observe,
                      rollout=rollout, noise_fn=noise_fn,
                      step_det=step_det,
                      policy_rollout=(policy_rollout if kernel_route
                                      else None))


def make_batched_ials(local_env: BatchedLocalEnv, aip_params,
                      aip_cfg: influence.AIPConfig, *,
                      fixed_marginal: Optional[float] = None,
                      fixed_marginal_vec=None,
                      stateless: bool = False,
                      use_horizon_kernel: Optional[bool] = None,
                      mesh=None) -> BatchedEnv:
    """Single-agent fused rollout engine — ``make_unified_ials`` at its
    A=1 squeeze (kept as the historical entry point)."""
    return make_unified_ials(local_env, aip_params, aip_cfg, n_agents=1,
                             fixed_marginal=fixed_marginal,
                             fixed_marginal_vec=fixed_marginal_vec,
                             stateless=stateless,
                             use_horizon_kernel=use_horizon_kernel,
                             mesh=mesh)


def make_batched_multi_ials(local_env: BatchedLocalEnv, aip_params,
                            aip_cfg: influence.AIPConfig, n_agents: int,
                            *, fixed_marginal: Optional[float] = None,
                            fixed_marginal_vec=None,
                            stateless: bool = False,
                            use_horizon_kernel: Optional[bool] = None,
                            mesh=None) -> BatchedEnv:
    """Fused Distributed IALS (one IALS + AIP per agent region) —
    ``make_unified_ials`` with the agent axis on (kept as the historical
    entry point). ``aip_params`` leaves are (A, ...) stacked."""
    return make_unified_ials(local_env, aip_params, aip_cfg,
                             n_agents=n_agents,
                             fixed_marginal=fixed_marginal,
                             fixed_marginal_vec=fixed_marginal_vec,
                             stateless=stateless,
                             use_horizon_kernel=use_horizon_kernel,
                             mesh=mesh)
