"""The paper's primary contribution: the IBA pipeline and the IALS
simulators.

``influence`` (the AIP and its training loop), ``collect`` (Algorithm 1
dataset collection from the GS), ``engine`` (the unified fused rollout
engine — ONE implementation serving {gru, fnn} backbones x {single,
multi} agents, whole horizons kernel-backed), ``ials`` (the
scalar-protocol IALS constructions + the engine's historical entry
points), ``multi_ials`` (compatibility re-exports for the Distributed
IALS names).
"""
