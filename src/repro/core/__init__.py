"""The paper's primary contribution: the IBA pipeline and the IALS
simulators.

``influence`` (the AIP and its training loop), ``collect`` (Algorithm 1
dataset collection from the GS), ``ials`` (the single-agent IALS and the
fused batched rollout engine), ``multi_ials`` (Distributed IALS — one
IALS + AIP per agent region, batched into one program).
"""
