"""Pallas TPU kernels for the rollout hot path, each with a pure-jnp
oracle in ``ref.py`` and backend dispatch in ``ops.py`` (compiled on TPU,
oracle/interpret elsewhere — see docs/ARCHITECTURE.md §2).
"""
