"""Fused GRU sequence Pallas TPU kernel — the AIP's hot loop.

The paper's IALS inner loop alternates tiny env steps with a GRU cell
(Algorithm 2 line 7); on GPU this is a cuDNN RNN, on TPU we fuse the whole
cell — both matmuls (x@Wx on the MXU, h@Wh on the MXU) plus all three gate
nonlinearities — into one kernel invocation per timestep, with the hidden
state resident in VMEM scratch across the T-step grid ("arbitrary"
semantics), so h never round-trips to HBM during a rollout.

Weights are laid out (D, 3H)/(H, 3H) gate-major [r|z|n], matching
``repro/nn/rnn.py``; ``ref.gru_sequence_ref`` is the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.nn.act import fast_sigmoid, fast_tanh


def _gru_kernel(x_ref, wx_ref, wh_ref, b_ref, h0_ref, hs_ref, h_scr, *,
                H: int, T: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[:, 0, :].astype(jnp.float32)            # (B, D)
    h = h_scr[...]                                     # (B, H)
    gx = jax.lax.dot_general(x, wx_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ()))) + \
        b_ref[...].astype(jnp.float32)
    gh = jax.lax.dot_general(h, wh_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ())))
    r = fast_sigmoid(gx[:, :H] + gh[:, :H])
    z = fast_sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
    n = fast_tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
    h_new = (1.0 - z) * n + z * h
    h_scr[...] = h_new
    hs_ref[:, 0, :] = h_new.astype(hs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gru_sequence(x, wx, wh, b, h0, *, interpret: bool | None = None):
    """x: (B, T, D); wx: (D, 3H); wh: (H, 3H); b: (3H,); h0: (B, H)
    -> (hs (B, T, H), h_T).

    ``interpret=None`` auto-detects the backend: compiled on TPU,
    interpret mode everywhere else."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, D = x.shape
    H = wh.shape[0]
    kernel = functools.partial(_gru_kernel, H=H, T=T)
    hs = pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((B, 1, D), lambda t: (0, t, 0)),
            pl.BlockSpec((D, 3 * H), lambda t: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda t: (0, 0)),
            pl.BlockSpec((3 * H,), lambda t: (0,)),
            pl.BlockSpec((B, H), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1, H), lambda t: (0, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, H), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, wx, wh, b, h0)
    return hs, hs[:, -1, :]
