"""Fused AIP Pallas TPU kernels: one tick (``aip_step``) and one whole
horizon (the ``aip_rollout`` family).

The IALS inner loop (Algorithm 2 lines 5-8) is: query the AIP on d_t, turn
the logits into per-head Bernoulli probabilities, and draw u_t. Dispatched
op-by-op that is a backbone forward pass, a head matmul, a sigmoid, a
uniform draw and a compare — five round-trips through HBM for a state that
fits in one VMEM tile. ``aip_step`` fuses the whole thing for the GRU
backbone: both GRU matmuls on the MXU, the gate nonlinearities, the head
projection, the head sigmoid, and the Bernoulli threshold-compare against
caller-supplied counter-based random bits, with every intermediate
resident in VMEM.

The rollout kernels go one level up (the Large-Batch-Simulation move,
Shacklett et al. 2021): ONE generalized grid, ``(A·B-blocks, T)`` — lane
blocks on the parallel outer axis, the horizon on an inner "arbitrary"
axis like ``gru.py`` — with the AIP recurrent state AND the local
simulator's state leaves resident in VMEM scratch across all T grid
steps. Lanes are laid out *agent-major* (lane ``a*B + b``), so every lane
block belongs to exactly one agent and the per-agent weights are just
another blocked input indexed by ``block_index // (B / block_b)``; the
agent axis is a grid dimension, not a Python-level engine variant. The
caller supplies the LS transition (``tick_fn``) and d-set extraction
(``dset_fn``) as pure jnp functions that get traced straight into the
kernel body, so one ``pallas_call`` advances the entire coupled AIP+LS
system for the whole horizon: actions, random bits, and LS noise stream
in block-by-tick; only per-tick rewards and the final states ever leave
VMEM.

Two backbones share that one kernel body (``_rollout_kernel``), each as a
cell traced into it:
  - ``aip_rollout_multi`` — GRU cell + head (``_gru_cell``), recurrent
    state = the (lanes, H) hidden vector; ``aip_rollout`` is its A=1
    squeeze (kept as the historical single-agent entry point).
  - ``fnn_rollout`` — the finite-memory FNN of Theorem 1: frame-stack
    shift + two relu GEMMs + head (``_fnn_cell``), recurrent state = the
    (lanes, stack·d_in) flattened d-set buffer.

Randomness is *passed in* as uint32 bits (one `jax.random.bits` call per
tick, generated in bulk by the rollout engine) so the kernels themselves
are pure functions — the same bits give the same u_t on every backend,
which is what the parity tests pin down against the ``ref.py`` oracles.

GRU weights are laid out (D, 3H)/(H, 3H) gate-major [r|z|n] like
``repro/nn/rnn.py``, stacked with a leading (A,) agent axis for the multi
kernels; activations are the shared rational gates from ``repro.nn.act``
(identical in the oracles), so kernel-vs-oracle agreement is exact up to
matmul association order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def _gru_cell(w, h, d, bits, *, H: int):
    """One fused GRU-backbone AIP tick on VMEM-resident values.

    w = (wx (D, 3H), wh (H, 3H), b (3H,), hw (H, M), hb (M,)) values;
    h: (B, H) f32 recurrent state; d: (B, D) f32; bits: (B, M) u32
    -> (h2, logits, u) all f32.
    """
    wx, wh, b, hw, hb = (v.astype(jnp.float32) for v in w)
    gx = jax.lax.dot_general(d, wx, (((1,), (0,)), ((), ()))) + b
    gh = jax.lax.dot_general(h, wh, (((1,), (0,)), ((), ())))
    r = fast_sigmoid(gx[:, :H] + gh[:, :H])
    z = fast_sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
    n = fast_tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
    h2 = (1.0 - z) * n + z * h
    logits = jax.lax.dot_general(h2, hw, (((1,), (0,)), ((), ()))) + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def _fnn_cell(w, buf, d, bits):
    """One fused FNN-backbone AIP tick (the Theorem-1 k-step predictor).

    w = (w1 (S, K), b1 (K,), w2 (K, K), b2 (K,), hw (K, M), hb (M,));
    buf: (B, S) f32 — the frame-stack buffer, S = stack * d_in, flattened
    row-major so the shift is a plain slice; d: (B, d_in) f32; bits:
    (B, M) u32 -> (buf2, logits, u). ``buf2`` already contains d (the
    newest frame last), matching ``influence.step``'s returned buffer.
    """
    w1, b1, w2, b2, hw, hb = (v.astype(jnp.float32) for v in w)
    buf2 = jnp.concatenate([buf[:, d.shape[1]:], d], axis=1)
    h = jax.nn.relu(
        jax.lax.dot_general(buf2, w1, (((1,), (0,)), ((), ()))) + b1)
    h = jax.nn.relu(
        jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ()))) + b2)
    logits = jax.lax.dot_general(h, hw, (((1,), (0,)), ((), ()))) + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return buf2, logits, u


def _aip_step_kernel(d_ref, h_ref, wx_ref, wh_ref, b_ref, hw_ref, hb_ref,
                     bits_ref, h2_ref, logits_ref, u_ref, *, H: int):
    d = d_ref[...].astype(jnp.float32)                 # (B, D)
    h = h_ref[...].astype(jnp.float32)                 # (B, H)
    w = (wx_ref[...], wh_ref[...], b_ref[...], hw_ref[...], hb_ref[...])
    h2, logits, u = _gru_cell(w, h, d, bits_ref[...], H=H)
    h2_ref[...] = h2.astype(h2_ref.dtype)
    logits_ref[...] = logits.astype(logits_ref.dtype)
    u_ref[...] = u.astype(u_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def aip_step(d, h, wx, wh, b, hw, hb, bits, *, interpret: bool | None = None):
    """d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,);
    hw: (H, M); hb: (M,); bits: (B, M) uint32
    -> (h_new (B, H), logits (B, M) f32, u (B, M) f32 in {0, 1}).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = d.shape
    H = wh.shape[0]
    M = hw.shape[1]
    kernel = functools.partial(_aip_step_kernel, H=H)
    h2, logits, u = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, D), lambda: (0, 0)),
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((D, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((3 * H,), lambda: (0,)),
            pl.BlockSpec((H, M), lambda: (0, 0)),
            pl.BlockSpec((M,), lambda: (0,)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(d, h, wx, wh, b, hw, hb, bits)
    return h2, logits, u


# ---------------------------------------------------------------------------
# The whole-horizon rollout family: one kernel body, two cells, any A
# ---------------------------------------------------------------------------

def _rollout_kernel(*refs, n_ls: int, n_noise: int, n_w: int, T: int,
                    cell_fn, tick_fn, dset_fn):
    """Grid (A·B-blocks, T): lane blocks parallel-outer, horizon inner.

    Ref layout (positional): LS state leaves | AIP state s0 | n_w stacked
    weights (leading per-agent block axis) | actions, bits | noise leaves
    || final LS leaves, sT, rewards || scratch: AIP state, LS leaves.
    The AIP recurrent state and every LS leaf live in VMEM scratch for the
    whole T axis of a lane block; ``cell_fn`` (the backbone),
    ``tick_fn``, and ``dset_fn`` are traced straight into this body."""
    i = n_ls
    ls0 = refs[:n_ls]
    s0_ref = refs[i]
    w_refs = refs[i + 1:i + 1 + n_w]
    i += 1 + n_w
    a_ref, bits_ref = refs[i], refs[i + 1]
    i += 2
    noise_refs = refs[i:i + n_noise]
    i += n_noise
    ls_out = refs[i:i + n_ls]
    sT_ref, rew_ref = refs[i + n_ls], refs[i + n_ls + 1]
    i += n_ls + 2
    s_scr = refs[i]
    ls_scr = refs[i + 1:i + 1 + n_ls]

    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)
        for dst, src in zip(ls_scr, ls0):
            dst[...] = src[...]

    ls_vals = tuple(s[...] for s in ls_scr)
    a = a_ref[0]                                       # (Bblk,)
    d = dset_fn(ls_vals, a).astype(jnp.float32)        # (Bblk, Dd)
    w = tuple(r[0] for r in w_refs)                    # this block's agent
    s2, _, u = cell_fn(w, s_scr[...], d, bits_ref[0])
    new_ls, rew = tick_fn(ls_vals, a, u,
                          tuple(nr[0] for nr in noise_refs))
    s_scr[...] = s2
    for dst, val in zip(ls_scr, new_ls):
        dst[...] = val.astype(dst.dtype)
    rew_ref[0] = rew.astype(rew_ref.dtype)

    @pl.when(t == T - 1)
    def _finish():
        sT_ref[...] = s_scr[...].astype(sT_ref.dtype)
        for dst, src in zip(ls_out, ls_scr):
            dst[...] = src[...]


def _launch_rollout(cell_fn, ls, s0, weights, actions, bits, noise, *,
                    n_agents: int, tick_fn, dset_fn,
                    block_b: int | None, interpret: bool):
    """Shared ``pallas_call`` builder for the rollout family.

    ``ls``: tuple of (L, ...) LS leaves, L = A·B lanes agent-major;
    ``s0``: (L, K) AIP recurrent state; ``weights``: tuple of (A, ...)
    stacked per-agent weight leaves; ``actions``: (T, L); ``bits``:
    (T, L, M); ``noise``: tuple of (T, L, ...) leaves.
    -> (final ls leaves, s_T (L, K), rewards (T, L) f32)."""
    L = s0.shape[0]
    A = n_agents
    if L % A:
        raise ValueError(f"lane count {L} not divisible by n_agents={A}")
    B = L // A
    T = actions.shape[0]
    if block_b is None:
        block_b = B
    if B % block_b:
        raise ValueError(f"block_b={block_b} must divide per-agent "
                         f"batch {B}")
    nB = B // block_b

    def w_spec(leaf):          # (A, ...) stacked weight -> this agent's
        s = leaf.shape[1:]
        return pl.BlockSpec((1,) + s,
                            lambda bi, t, _n=len(s): (bi // nB,)
                            + (0,) * _n)

    def state_spec(leaf):      # (L, ...) leaf -> per-block, t-invariant
        s = leaf.shape[1:]
        return pl.BlockSpec((block_b,) + s,
                            lambda bi, t, _n=len(s): (bi,) + (0,) * _n)

    def stream_spec(leaf):     # (T, L, ...) leaf -> one tick per grid step
        s = leaf.shape[2:]
        return pl.BlockSpec((1, block_b) + s,
                            lambda bi, t, _n=len(s): (t, bi) + (0,) * _n)

    kernel = functools.partial(_rollout_kernel, n_ls=len(ls),
                               n_noise=len(noise), n_w=len(weights), T=T,
                               cell_fn=cell_fn, tick_fn=tick_fn,
                               dset_fn=dset_fn)
    out = pl.pallas_call(
        kernel,
        grid=(A * nB, T),
        in_specs=[state_spec(l) for l in ls] + [state_spec(s0)] + [
            w_spec(w) for w in weights] + [
            stream_spec(actions), stream_spec(bits),
        ] + [stream_spec(n) for n in noise],
        out_specs=[state_spec(l) for l in ls] + [
            state_spec(s0), stream_spec(jnp.empty((T, L), jnp.float32))],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in ls] + [
            jax.ShapeDtypeStruct(s0.shape, s0.dtype),
            jax.ShapeDtypeStruct((T, L), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_b, s0.shape[1]), jnp.float32)] + [
            pltpu.VMEM((block_b,) + l.shape[1:], l.dtype) for l in ls],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*ls, s0, *weights, actions, bits, *noise)
    return tuple(out[:len(ls)]), out[len(ls)], out[len(ls) + 1]


@functools.partial(jax.jit, static_argnames=("n_agents", "tick_fn",
                                             "dset_fn", "block_b",
                                             "interpret"))
def aip_rollout_multi(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                      n_agents: int, tick_fn, dset_fn,
                      block_b: int | None = None,
                      interpret: bool | None = None):
    """Whole-horizon fused IALS rollout, GRU backbone, A per-agent AIPs —
    ONE kernel dispatch for T ticks of every lane.

    ``ls``: tuple of LS state leaves, each (L, ...) with L = A·B lanes in
    *agent-major* order (lane ``a*B + b``) and a kernel-safe dtype
    (int32/float32 — the engine encodes bools); ``h0``: (L, H) AIP state;
    stacked weights ``wx`` (A, D, 3H), ``wh`` (A, H, 3H), ``b`` (A, 3H),
    ``hw`` (A, H, M), ``hb`` (A, M); ``actions``: (T, L) int32; ``bits``:
    (T, L, M) uint32; ``noise``: tuple of (T, L, ...) LS noise leaves.
    ``tick_fn(ls_leaves, a, u, noise_leaves) -> (ls_leaves, r)`` and
    ``dset_fn(ls_leaves, a) -> (lanes, Dd)`` must be pure jnp — they are
    traced into the kernel body and run on VMEM-resident values.

    -> (final ls leaves, h_T (L, H), rewards (T, L) f32), bitwise-equal
    to scanning the per-tick fused step (``ref.ials_rollout_multi_ref``).

    ``block_b`` lane-blocks the *per-agent* batch axis B across the
    parallel grid dimension (must divide B; default: one block per
    agent). ``interpret=None`` auto-detects: compiled on TPU, interpret
    elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    H = wh.shape[1]
    cell = functools.partial(_gru_cell, H=H)
    return _launch_rollout(cell, tuple(ls), h0, (wx, wh, b, hw, hb),
                           actions, bits, tuple(noise), n_agents=n_agents,
                           tick_fn=tick_fn, dset_fn=dset_fn,
                           block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_agents", "tick_fn",
                                             "dset_fn", "block_b",
                                             "interpret"))
def fnn_rollout(ls, buf0, w1, b1, w2, b2, hw, hb, actions, bits, noise, *,
                n_agents: int, tick_fn, dset_fn,
                block_b: int | None = None,
                interpret: bool | None = None):
    """Whole-horizon fused IALS rollout, FNN backbone (Theorem-1 k-step
    predictor), A per-agent AIPs — the frame-stack shift, both relu
    GEMMs, the head, and the Bernoulli draw all trace into the kernel.

    Layout as in ``aip_rollout_multi`` except the AIP recurrent state:
    ``buf0`` is the (L, stack·d_in) *flattened* frame-stack buffer
    (row-major over (stack, d_in), newest frame last, so the shift is a
    plain slice-and-concat — identical values to ``influence.step``'s
    (stack, d_in) buffer). Stacked weights ``w1`` (A, stack·d_in, K),
    ``b1`` (A, K), ``w2`` (A, K, K), ``b2`` (A, K), ``hw`` (A, K, M),
    ``hb`` (A, M).

    -> (final ls leaves, buf_T (L, stack·d_in), rewards (T, L) f32),
    bitwise-equal to scanning the fused per-tick step
    (``ref.fnn_rollout_ref``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _launch_rollout(_fnn_cell, tuple(ls), buf0,
                           (w1, b1, w2, b2, hw, hb), actions, bits,
                           tuple(noise), n_agents=n_agents,
                           tick_fn=tick_fn, dset_fn=dset_fn,
                           block_b=block_b, interpret=interpret)


def aip_rollout(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                tick_fn, dset_fn, block_b: int | None = None,
                interpret: bool | None = None):
    """Single-agent whole-horizon GRU rollout — the A=1 squeeze of
    ``aip_rollout_multi`` (shared-weight lane blocks; kept as the
    historical entry point). Unstacked weights as in ``aip_step``;
    otherwise see ``aip_rollout_multi``.
    """
    return aip_rollout_multi(
        tuple(ls), h0, wx[None], wh[None], b[None], hw[None], hb[None],
        actions, bits, tuple(noise), n_agents=1, tick_fn=tick_fn,
        dset_fn=dset_fn, block_b=block_b, interpret=interpret)

