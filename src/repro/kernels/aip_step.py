"""Fused AIP step Pallas TPU kernel — one invocation per simulator tick.

The IALS inner loop (Algorithm 2 lines 5-8) is: query the AIP on d_t, turn
the logits into per-head Bernoulli probabilities, and draw u_t. Dispatched
op-by-op that is a GRU cell, a head matmul, a sigmoid, a uniform draw and a
compare — five round-trips through HBM for a (B, H) state that fits in one
VMEM tile. This kernel fuses the whole thing: both GRU matmuls on the MXU,
the gate nonlinearities, the head projection, the head sigmoid, and the
Bernoulli threshold-compare against caller-supplied counter-based random
bits, with every intermediate resident in VMEM.

Randomness is *passed in* as uint32 bits (one `jax.random.bits` call per
tick, generated in bulk by the rollout engine) so the kernel itself is a
pure function — the same bits give the same u_t on every backend, which is
what the parity tests pin down against ``ref.aip_step_ref``.

Weights are laid out (D, 3H)/(H, 3H) gate-major [r|z|n] like
``repro/nn/rnn.py``; activations are the shared rational gates from
``repro.nn.act`` (identical in the oracle), so kernel-vs-oracle agreement
is exact up to matmul association order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def _aip_step_kernel(d_ref, h_ref, wx_ref, wh_ref, b_ref, hw_ref, hb_ref,
                     bits_ref, h2_ref, logits_ref, u_ref, *, H: int):
    d = d_ref[...].astype(jnp.float32)                 # (B, D)
    h = h_ref[...].astype(jnp.float32)                 # (B, H)
    gx = jax.lax.dot_general(d, wx_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ()))) + \
        b_ref[...].astype(jnp.float32)
    gh = jax.lax.dot_general(h, wh_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ())))
    r = fast_sigmoid(gx[:, :H] + gh[:, :H])
    z = fast_sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
    n = fast_tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
    h2 = (1.0 - z) * n + z * h
    logits = jax.lax.dot_general(h2, hw_ref[...].astype(jnp.float32),
                                 (((1,), (0,)), ((), ()))) + \
        hb_ref[...].astype(jnp.float32)
    probs = fast_sigmoid(logits)
    u01 = uniform_from_bits(bits_ref[...])
    h2_ref[...] = h2.astype(h2_ref.dtype)
    logits_ref[...] = logits.astype(logits_ref.dtype)
    u_ref[...] = (u01 < probs).astype(u_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def aip_step(d, h, wx, wh, b, hw, hb, bits, *, interpret: bool | None = None):
    """d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,);
    hw: (H, M); hb: (M,); bits: (B, M) uint32
    -> (h_new (B, H), logits (B, M) f32, u (B, M) f32 in {0, 1}).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = d.shape
    H = wh.shape[0]
    M = hw.shape[1]
    kernel = functools.partial(_aip_step_kernel, H=H)
    h2, logits, u = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, D), lambda: (0, 0)),
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((D, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((3 * H,), lambda: (0,)),
            pl.BlockSpec((H, M), lambda: (0, 0)),
            pl.BlockSpec((M,), lambda: (0,)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(d, h, wx, wh, b, hw, hb, bits)
    return h2, logits, u
