"""Fused AIP Pallas TPU kernels: one tick (``aip_step``) and one whole
horizon (``aip_rollout``).

The IALS inner loop (Algorithm 2 lines 5-8) is: query the AIP on d_t, turn
the logits into per-head Bernoulli probabilities, and draw u_t. Dispatched
op-by-op that is a GRU cell, a head matmul, a sigmoid, a uniform draw and a
compare — five round-trips through HBM for a (B, H) state that fits in one
VMEM tile. ``aip_step`` fuses the whole thing: both GRU matmuls on the MXU,
the gate nonlinearities, the head projection, the head sigmoid, and the
Bernoulli threshold-compare against caller-supplied counter-based random
bits, with every intermediate resident in VMEM.

``aip_rollout`` goes one level up (the Large-Batch-Simulation move,
Shacklett et al. 2021): a lane-blocked ``(B-blocks, T)`` grid — batch
blocks on the parallel outer axis, the horizon on an inner "arbitrary"
axis like ``gru.py`` — with the AIP hidden state AND the local simulator's
state leaves resident in VMEM scratch across all T grid steps. The caller
supplies the LS transition (``tick_fn``) and d-set extraction (``dset_fn``)
as pure jnp functions that get traced straight into the kernel body, so
one ``pallas_call`` advances the entire coupled AIP+LS system for the
whole horizon: actions, random bits, and LS noise stream in block-by-tick;
only per-tick rewards and the final states ever leave VMEM.

Randomness is *passed in* as uint32 bits (one `jax.random.bits` call per
tick, generated in bulk by the rollout engine) so the kernels themselves
are pure functions — the same bits give the same u_t on every backend,
which is what the parity tests pin down against the ``ref.py`` oracles.

Weights are laid out (D, 3H)/(H, 3H) gate-major [r|z|n] like
``repro/nn/rnn.py``; activations are the shared rational gates from
``repro.nn.act`` (identical in the oracle), so kernel-vs-oracle agreement
is exact up to matmul association order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def _aip_cell(d, h, wx_ref, wh_ref, b_ref, hw_ref, hb_ref, bits, *, H: int):
    """Shared tick math on VMEM-resident values: GRU cell + head + sigmoid
    + threshold-compare. d: (B, D) f32, h: (B, H) f32, bits: (B, M) u32
    -> (h2, logits, u) all f32."""
    gx = jax.lax.dot_general(d, wx_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ()))) + \
        b_ref[...].astype(jnp.float32)
    gh = jax.lax.dot_general(h, wh_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ())))
    r = fast_sigmoid(gx[:, :H] + gh[:, :H])
    z = fast_sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
    n = fast_tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
    h2 = (1.0 - z) * n + z * h
    logits = jax.lax.dot_general(h2, hw_ref[...].astype(jnp.float32),
                                 (((1,), (0,)), ((), ()))) + \
        hb_ref[...].astype(jnp.float32)
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def _aip_step_kernel(d_ref, h_ref, wx_ref, wh_ref, b_ref, hw_ref, hb_ref,
                     bits_ref, h2_ref, logits_ref, u_ref, *, H: int):
    d = d_ref[...].astype(jnp.float32)                 # (B, D)
    h = h_ref[...].astype(jnp.float32)                 # (B, H)
    h2, logits, u = _aip_cell(d, h, wx_ref, wh_ref, b_ref, hw_ref, hb_ref,
                              bits_ref[...], H=H)
    h2_ref[...] = h2.astype(h2_ref.dtype)
    logits_ref[...] = logits.astype(logits_ref.dtype)
    u_ref[...] = u.astype(u_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def aip_step(d, h, wx, wh, b, hw, hb, bits, *, interpret: bool | None = None):
    """d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,);
    hw: (H, M); hb: (M,); bits: (B, M) uint32
    -> (h_new (B, H), logits (B, M) f32, u (B, M) f32 in {0, 1}).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = d.shape
    H = wh.shape[0]
    M = hw.shape[1]
    kernel = functools.partial(_aip_step_kernel, H=H)
    h2, logits, u = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, D), lambda: (0, 0)),
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((D, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((3 * H,), lambda: (0,)),
            pl.BlockSpec((H, M), lambda: (0, 0)),
            pl.BlockSpec((M,), lambda: (0,)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(d, h, wx, wh, b, hw, hb, bits)
    return h2, logits, u


def _aip_rollout_kernel(*refs, n_ls: int, n_noise: int, H: int, T: int,
                        tick_fn, dset_fn):
    """Grid (B-blocks, T), batch blocks parallel-outer, horizon inner.

    Ref layout (positional): LS state leaves | h0, wx, wh, b, hw, hb,
    actions, bits | noise leaves || final LS leaves, hT, rewards ||
    scratch: h, LS leaves. The AIP hidden state and every LS leaf live in
    VMEM scratch for the whole T axis of a batch block; ``tick_fn`` and
    ``dset_fn`` are traced straight into this body."""
    i = n_ls
    ls0 = refs[:n_ls]
    (h0_ref, wx_ref, wh_ref, b_ref, hw_ref, hb_ref, a_ref,
     bits_ref) = refs[i:i + 8]
    i += 8
    noise_refs = refs[i:i + n_noise]
    i += n_noise
    ls_out = refs[i:i + n_ls]
    hT_ref, rew_ref = refs[i + n_ls], refs[i + n_ls + 1]
    i += n_ls + 2
    h_scr = refs[i]
    ls_scr = refs[i + 1:i + 1 + n_ls]

    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)
        for dst, src in zip(ls_scr, ls0):
            dst[...] = src[...]

    ls_vals = tuple(s[...] for s in ls_scr)
    a = a_ref[0]                                       # (Bblk,)
    d = dset_fn(ls_vals, a).astype(jnp.float32)        # (Bblk, Dd)
    h2, _, u = _aip_cell(d, h_scr[...], wx_ref, wh_ref, b_ref, hw_ref,
                         hb_ref, bits_ref[0], H=H)
    new_ls, rew = tick_fn(ls_vals, a, u,
                          tuple(nr[0] for nr in noise_refs))
    h_scr[...] = h2
    for dst, val in zip(ls_scr, new_ls):
        dst[...] = val.astype(dst.dtype)
    rew_ref[0] = rew.astype(rew_ref.dtype)

    @pl.when(t == T - 1)
    def _finish():
        hT_ref[...] = h_scr[...].astype(hT_ref.dtype)
        for dst, src in zip(ls_out, ls_scr):
            dst[...] = src[...]


@functools.partial(jax.jit, static_argnames=("tick_fn", "dset_fn",
                                             "block_b", "interpret"))
def aip_rollout(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                tick_fn, dset_fn, block_b: int | None = None,
                interpret: bool | None = None):
    """Whole-horizon fused IALS rollout — ONE kernel dispatch for T ticks.

    ``ls``: tuple of LS state leaves, each (B, ...) with a kernel-safe
    dtype (int32/float32 — the engine encodes bools); ``h0``: (B, H) AIP
    state; weights as in ``aip_step``; ``actions``: (T, B) int32;
    ``bits``: (T, B, M) uint32; ``noise``: tuple of (T, B, ...) LS noise
    leaves. ``tick_fn(ls_leaves, a, u, noise_leaves) -> (ls_leaves, r)``
    and ``dset_fn(ls_leaves, a) -> (B, Dd)`` must be pure jnp — they are
    traced into the kernel body and run on VMEM-resident values.

    -> (final ls leaves, h_T (B, H), rewards (T, B) f32), bitwise-equal to
    scanning the per-tick fused step (``ref.ials_rollout_ref`` oracle).

    ``block_b`` lane-blocks the batch axis across the parallel grid
    dimension (must divide B; default: one block). ``interpret=None``
    auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ls = tuple(ls)
    noise = tuple(noise)
    B, H = h0.shape
    T = actions.shape[0]
    M = hw.shape[1]
    D3 = wx.shape
    if block_b is None:
        block_b = B
    if B % block_b:
        raise ValueError(f"block_b={block_b} must divide B={B}")
    nB = B // block_b

    def bcast(shape):          # weight blocks: whole array, every step
        return pl.BlockSpec(shape, lambda bi, t: (0,) * len(shape))

    def state_spec(leaf):      # (B, ...) leaf -> per-block, t-invariant
        s = leaf.shape[1:]
        return pl.BlockSpec((block_b,) + s,
                            lambda bi, t, _n=len(s): (bi,) + (0,) * _n)

    def stream_spec(leaf):     # (T, B, ...) leaf -> one tick per grid step
        s = leaf.shape[2:]
        return pl.BlockSpec((1, block_b) + s,
                            lambda bi, t, _n=len(s): (t, bi) + (0,) * _n)

    kernel = functools.partial(_aip_rollout_kernel, n_ls=len(ls),
                               n_noise=len(noise), H=H, T=T,
                               tick_fn=tick_fn, dset_fn=dset_fn)
    out = pl.pallas_call(
        kernel,
        grid=(nB, T),
        in_specs=[state_spec(l) for l in ls] + [
            state_spec(h0),
            bcast(D3), bcast(wh.shape), bcast(b.shape),
            bcast(hw.shape), bcast(hb.shape),
            stream_spec(actions), stream_spec(bits),
        ] + [stream_spec(n) for n in noise],
        out_specs=[state_spec(l) for l in ls] + [
            state_spec(h0), stream_spec(jnp.empty((T, B), jnp.float32))],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in ls] + [
            jax.ShapeDtypeStruct((B, H), h0.dtype),
            jax.ShapeDtypeStruct((T, B), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_b, H), jnp.float32)] + [
            pltpu.VMEM((block_b,) + l.shape[1:], l.dtype) for l in ls],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*ls, h0, wx, wh, b, hw, hb, actions, bits, *noise)
    return tuple(out[:len(ls)]), out[len(ls)], out[len(ls) + 1]
