"""Fused AIP Pallas TPU kernels: one tick (``aip_step``) and one whole
horizon (the ``aip_rollout`` family).

The IALS inner loop (Algorithm 2 lines 5-8) is: query the AIP on d_t, turn
the logits into per-head Bernoulli probabilities, and draw u_t. Dispatched
op-by-op that is a backbone forward pass, a head matmul, a sigmoid, a
uniform draw and a compare — five round-trips through HBM for a state that
fits in one VMEM tile. ``aip_step`` fuses the whole thing for the GRU
backbone: both GRU matmuls on the MXU, the gate nonlinearities, the head
projection, the head sigmoid, and the Bernoulli threshold-compare against
caller-supplied counter-based random bits, with every intermediate
resident in VMEM.

The rollout kernels go one level up (the Large-Batch-Simulation move,
Shacklett et al. 2021): ONE generalized grid, ``(A·B-blocks, T)`` — lane
blocks on the parallel outer axis, the horizon on an inner "arbitrary"
axis like ``gru.py`` — with the AIP recurrent state AND the local
simulator's state leaves resident in VMEM scratch across all T grid
steps. Lanes are laid out *agent-major* (lane ``a*B + b``), so every lane
block belongs to exactly one agent and the per-agent weights are just
another blocked input indexed by ``block_index // (B / block_b)``; the
agent axis is a grid dimension, not a Python-level engine variant. The
caller supplies the LS transition (``tick_fn``) and d-set extraction
(``dset_fn``) as pure jnp functions that get traced straight into the
kernel body, so one ``pallas_call`` advances the entire coupled AIP+LS
system for the whole horizon: actions, random bits, and LS noise stream
in block-by-tick; only per-tick rewards and the final states ever leave
VMEM.

Two backbones share that one kernel body (``_rollout_kernel``), each as a
cell traced into it:
  - ``aip_rollout_multi`` — GRU cell + head (``_gru_cell``), recurrent
    state = the (lanes, H) hidden vector; ``aip_rollout`` is its A=1
    squeeze (kept as the historical single-agent entry point).
  - ``fnn_rollout`` — the finite-memory FNN of Theorem 1: frame-stack
    shift + two relu GEMMs + head (``_fnn_cell``), recurrent state = the
    (lanes, stack·d_in) flattened d-set buffer.

``policy_rollout`` goes one level further still: the PPO *actor* joins
the loop. Its kernel body (``_policy_rollout_kernel``) traces the policy
network (``_policy_cell`` — the exact ``rl/ppo.py::policy_forward``
math, frame stack in VMEM scratch like ``fnn_rollout``'s d-set buffer),
Gumbel-argmax action sampling on pre-drawn noise (bitwise-equal to
``jax.random.categorical``'s own Gumbel-max derivation), either backbone
cell, the LS transition, the observation function, and the periodic
episode-reset merge into one grid — an entire PPO rollout (act + AIP +
LS + reward) is ONE dispatch on TPU.

Randomness is *passed in* as uint32 bits (one `jax.random.bits` call per
tick, generated in bulk by the rollout engine) so the kernels themselves
are pure functions — the same bits give the same u_t on every backend,
which is what the parity tests pin down against the ``ref.py`` oracles.

GRU weights are laid out (D, 3H)/(H, 3H) gate-major [r|z|n] like
``repro/nn/rnn.py``, stacked with a leading (A,) agent axis for the multi
kernels; activations are the shared rational gates from ``repro.nn.act``
(identical in the oracles), so kernel-vs-oracle agreement is exact up to
matmul association order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def _gru_cell(w, h, d, bits, *, H: int):
    """One fused GRU-backbone AIP tick on VMEM-resident values.

    w = (wx (D, 3H), wh (H, 3H), b (3H,), hw (H, M), hb (M,)) values;
    h: (B, H) f32 recurrent state; d: (B, D) f32; bits: (B, M) u32
    -> (h2, logits, u) all f32.
    """
    wx, wh, b, hw, hb = (v.astype(jnp.float32) for v in w)
    gx = jax.lax.dot_general(d, wx, (((1,), (0,)), ((), ()))) + b
    gh = jax.lax.dot_general(h, wh, (((1,), (0,)), ((), ())))
    r = fast_sigmoid(gx[:, :H] + gh[:, :H])
    z = fast_sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
    n = fast_tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
    h2 = (1.0 - z) * n + z * h
    logits = jax.lax.dot_general(h2, hw, (((1,), (0,)), ((), ()))) + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def _fnn_cell(w, buf, d, bits):
    """One fused FNN-backbone AIP tick (the Theorem-1 k-step predictor).

    w = (w1 (S, K), b1 (K,), w2 (K, K), b2 (K,), hw (K, M), hb (M,));
    buf: (B, S) f32 — the frame-stack buffer, S = stack * d_in, flattened
    row-major so the shift is a plain slice; d: (B, d_in) f32; bits:
    (B, M) u32 -> (buf2, logits, u). ``buf2`` already contains d (the
    newest frame last), matching ``influence.step``'s returned buffer.
    """
    w1, b1, w2, b2, hw, hb = (v.astype(jnp.float32) for v in w)
    buf2 = jnp.concatenate([buf[:, d.shape[1]:], d], axis=1)
    h = jax.nn.relu(
        jax.lax.dot_general(buf2, w1, (((1,), (0,)), ((), ()))) + b1)
    h = jax.nn.relu(
        jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ()))) + b2)
    logits = jax.lax.dot_general(h, hw, (((1,), (0,)), ((), ()))) + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return buf2, logits, u


def _policy_cell(w, x, *, fast_gates: bool):
    """The PPO actor-critic forward on VMEM-resident values — the exact
    math of ``rl/ppo.py::policy_forward`` (dense = x @ w + b, hidden tanh
    layers through the shared gates; exact ``jnp.tanh`` when the policy
    was configured that way).

    w = (w1 (S, Hp), b1, w2 (Hp, Hp), b2, piw (Hp, n_act), pib,
    vw (Hp, 1), vb) values; x: (B, S) f32 frame-stacked obs
    -> (logits (B, n_act) f32, value (B,) f32).
    """
    w1, b1, w2, b2, piw, pib, vw, vb = (v.astype(jnp.float32) for v in w)
    act = fast_tanh if fast_gates else jnp.tanh
    h = act(jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ()))) + b1)
    h = act(jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ()))) + b2)
    # both heads as ONE (Hp, n_act+1) GEMM: an (Hp, 1) matvec on its own
    # is a fusion-order wildcard (1-ulp drift between program shapes) AND
    # a dispatch-bound micro-GEMM; fusing pins the reduction order shared
    # with the oracle and feeds the MXU one op instead of two
    hw = jnp.concatenate([piw, vw], axis=1)
    hb = jnp.concatenate([pib, vb], axis=0)
    out = jax.lax.dot_general(h, hw, (((1,), (0,)), ((), ()))) + hb
    return out[:, :-1], out[:, -1]


def _aip_step_kernel(d_ref, h_ref, wx_ref, wh_ref, b_ref, hw_ref, hb_ref,
                     bits_ref, h2_ref, logits_ref, u_ref, *, H: int):
    d = d_ref[...].astype(jnp.float32)                 # (B, D)
    h = h_ref[...].astype(jnp.float32)                 # (B, H)
    w = (wx_ref[...], wh_ref[...], b_ref[...], hw_ref[...], hb_ref[...])
    h2, logits, u = _gru_cell(w, h, d, bits_ref[...], H=H)
    h2_ref[...] = h2.astype(h2_ref.dtype)
    logits_ref[...] = logits.astype(logits_ref.dtype)
    u_ref[...] = u.astype(u_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def aip_step(d, h, wx, wh, b, hw, hb, bits, *, interpret: bool | None = None):
    """d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,);
    hw: (H, M); hb: (M,); bits: (B, M) uint32
    -> (h_new (B, H), logits (B, M) f32, u (B, M) f32 in {0, 1}).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = d.shape
    H = wh.shape[0]
    M = hw.shape[1]
    kernel = functools.partial(_aip_step_kernel, H=H)
    h2, logits, u = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, D), lambda: (0, 0)),
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((D, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((3 * H,), lambda: (0,)),
            pl.BlockSpec((H, M), lambda: (0, 0)),
            pl.BlockSpec((M,), lambda: (0,)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
            pl.BlockSpec((B, M), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(d, h, wx, wh, b, hw, hb, bits)
    return h2, logits, u


def _serve_forward_kernel(f_ref, m_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                          piw_ref, pib_ref, vw_ref, vb_ref, lg_ref, v_ref,
                          *, fast_gates: bool):
    x = f_ref[...].astype(jnp.float32)                 # (bs, D)
    w = (w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
         piw_ref[...], pib_ref[...], vw_ref[...], vb_ref[...])
    logits, v = _policy_cell(w, x, fast_gates=fast_gates)
    m = m_ref[...] != 0                                # (bs,)
    lg_ref[...] = jnp.where(m[:, None], logits, 0.0).astype(lg_ref.dtype)
    v_ref[...] = jnp.where(m, v, 0.0).astype(v_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("fast_gates", "block_s", "interpret"))
def serve_forward(frames, mask, pol_w, *, fast_gates: bool,
                  block_s: int | None = None,
                  interpret: bool | None = None):
    """Masked fixed-slot policy forward — the serving tier's inference
    dispatch (``ref.serve_forward_ref`` is the ground truth).

    frames: (S, D) f32 packed request slot (D = frame_stack * obs_dim);
    mask: (S,) int32/bool lane-validity mask; pol_w: the flat
    ``rl/ppo.py::flat_policy_weights`` tuple -> (logits (S, n_actions)
    f32, v (S,) f32), pad lanes exactly zero.

    One grid pass over slot blocks, the whole policy net (two gated
    GEMMs + the fused two-head GEMM of ``_policy_cell``) VMEM-resident
    per block; the mask is applied INSIDE the kernel — the boundary of
    the ragged-batch contract (``envs/api.py``) — so a pad lane's
    contents can never reach a consumer. The slot shape is static per
    server, so every dispatch reuses one compiled program.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, D = frames.shape
    n_act = pol_w[4].shape[1]
    bs = min(block_s or 256, S)
    while S % bs:
        bs //= 2
    mask = mask.astype(jnp.int32)
    kernel = functools.partial(_serve_forward_kernel,
                               fast_gates=fast_gates)
    w1, b1, w2, b2, piw, pib, vw, vb = pol_w
    Hp = w1.shape[1]
    logits, v = pl.pallas_call(
        kernel,
        grid=(S // bs,),
        in_specs=[
            pl.BlockSpec((bs, D), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((D, Hp), lambda i: (0, 0)),
            pl.BlockSpec((Hp,), lambda i: (0,)),
            pl.BlockSpec((Hp, Hp), lambda i: (0, 0)),
            pl.BlockSpec((Hp,), lambda i: (0,)),
            pl.BlockSpec((Hp, n_act), lambda i: (0, 0)),
            pl.BlockSpec((n_act,), lambda i: (0,)),
            pl.BlockSpec((Hp, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bs, n_act), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, n_act), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(frames, mask, w1, b1, w2, b2, piw, pib, vw, vb)
    return logits, v


def _serve_forward_multi_kernel(f_ref, m_ref, p_ref, w1_ref, b1_ref,
                                w2_ref, b2_ref, piw_ref, pib_ref, vw_ref,
                                vb_ref, lg_ref, v_ref, *, fast_gates: bool,
                                n_policies: int):
    x = f_ref[...].astype(jnp.float32)                 # (bs, D)
    pidx = p_ref[...]                                  # (bs,)
    stacked = (w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...],
               piw_ref[...], pib_ref[...], vw_ref[...], vb_ref[...])
    lg = jnp.zeros((x.shape[0], piw_ref.shape[-1]), jnp.float32)
    v = jnp.zeros((x.shape[0],), jnp.float32)
    # static unroll over the (small) policy axis: each checkpoint's cell
    # runs the exact single-policy ``_policy_cell`` at the exact block
    # shape, lanes then select their own row — the bitwise
    # one-policy-vs-N parity depends on this (no per-lane weight gather)
    for n in range(n_policies):
        lg_n, v_n = _policy_cell(tuple(w[n] for w in stacked), x,
                                 fast_gates=fast_gates)
        sel = pidx == n
        lg = jnp.where(sel[:, None], lg_n, lg)
        v = jnp.where(sel, v_n, v)
    m = m_ref[...] != 0                                # (bs,)
    lg_ref[...] = jnp.where(m[:, None], lg, 0.0).astype(lg_ref.dtype)
    v_ref[...] = jnp.where(m, v, 0.0).astype(v_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("fast_gates", "block_s", "interpret"))
def serve_forward_multi(frames, mask, pidx, pol_ws, *, fast_gates: bool,
                        block_s: int | None = None,
                        interpret: bool | None = None):
    """Cross-policy masked fixed-slot policy forward — ``serve_forward``
    with a leading policy axis on the weights
    (``ref.serve_forward_multi_ref`` is the ground truth).

    frames: (S, D) f32 packed slot; mask: (S,) lane-validity; pidx: (S,)
    int32 per-lane policy index; pol_ws: the stacked
    ``rl/ppo.py::stack_policy_weights`` tuple ((N, ...) arrays) ->
    (logits (S, n_actions) f32, v (S,) f32), pad lanes and unroutable
    ``pidx`` lanes exactly zero.

    Same grid/blocking as ``serve_forward``; the policy axis is a static
    unroll inside the kernel body (every checkpoint's weights are a
    handful of small matrices, VMEM-resident per block), so one compiled
    program serves N checkpoints in one dispatch.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, D = frames.shape
    N = pol_ws[0].shape[0]
    n_act = pol_ws[4].shape[2]
    bs = min(block_s or 256, S)
    while S % bs:
        bs //= 2
    mask = mask.astype(jnp.int32)
    pidx = pidx.astype(jnp.int32)
    kernel = functools.partial(_serve_forward_multi_kernel,
                               fast_gates=fast_gates, n_policies=N)
    w1, b1, w2, b2, piw, pib, vw, vb = pol_ws
    Hp = w1.shape[2]
    logits, v = pl.pallas_call(
        kernel,
        grid=(S // bs,),
        in_specs=[
            pl.BlockSpec((bs, D), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((N, D, Hp), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, Hp), lambda i: (0, 0)),
            pl.BlockSpec((N, Hp, Hp), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, Hp), lambda i: (0, 0)),
            pl.BlockSpec((N, Hp, n_act), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, n_act), lambda i: (0, 0)),
            pl.BlockSpec((N, Hp, 1), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bs, n_act), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, n_act), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(),
        interpret=interpret,
    )(frames, mask, pidx, w1, b1, w2, b2, piw, pib, vw, vb)
    return logits, v


# ---------------------------------------------------------------------------
# The whole-horizon rollout family: one kernel body, two cells, any A
# ---------------------------------------------------------------------------

def _rollout_kernel(*refs, n_ls: int, n_noise: int, n_w: int, T: int,
                    cell_fn, tick_fn, dset_fn):
    """Grid (A·B-blocks, T): lane blocks parallel-outer, horizon inner.

    Ref layout (positional): LS state leaves | AIP state s0 | n_w stacked
    weights (leading per-agent block axis) | actions, bits | noise leaves
    || final LS leaves, sT, rewards || scratch: AIP state, LS leaves.
    The AIP recurrent state and every LS leaf live in VMEM scratch for the
    whole T axis of a lane block; ``cell_fn`` (the backbone),
    ``tick_fn``, and ``dset_fn`` are traced straight into this body."""
    i = n_ls
    ls0 = refs[:n_ls]
    s0_ref = refs[i]
    w_refs = refs[i + 1:i + 1 + n_w]
    i += 1 + n_w
    a_ref, bits_ref = refs[i], refs[i + 1]
    i += 2
    noise_refs = refs[i:i + n_noise]
    i += n_noise
    ls_out = refs[i:i + n_ls]
    sT_ref, rew_ref = refs[i + n_ls], refs[i + n_ls + 1]
    i += n_ls + 2
    s_scr = refs[i]
    ls_scr = refs[i + 1:i + 1 + n_ls]

    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)
        for dst, src in zip(ls_scr, ls0):
            dst[...] = src[...]

    ls_vals = tuple(s[...] for s in ls_scr)
    a = a_ref[0]                                       # (Bblk,)
    d = dset_fn(ls_vals, a).astype(jnp.float32)        # (Bblk, Dd)
    w = tuple(r[0] for r in w_refs)                    # this block's agent
    s2, _, u = cell_fn(w, s_scr[...], d, bits_ref[0])
    new_ls, rew = tick_fn(ls_vals, a, u,
                          tuple(nr[0] for nr in noise_refs))
    s_scr[...] = s2
    for dst, val in zip(ls_scr, new_ls):
        dst[...] = val.astype(dst.dtype)
    rew_ref[0] = rew.astype(rew_ref.dtype)

    @pl.when(t == T - 1)
    def _finish():
        sT_ref[...] = s_scr[...].astype(sT_ref.dtype)
        for dst, src in zip(ls_out, ls_scr):
            dst[...] = src[...]


def _launch_rollout(cell_fn, ls, s0, weights, actions, bits, noise, *,
                    n_agents: int, tick_fn, dset_fn,
                    block_b: int | None, interpret: bool):
    """Shared ``pallas_call`` builder for the rollout family.

    ``ls``: tuple of (L, ...) LS leaves, L = A·B lanes agent-major;
    ``s0``: (L, K) AIP recurrent state; ``weights``: tuple of (A, ...)
    stacked per-agent weight leaves; ``actions``: (T, L); ``bits``:
    (T, L, M); ``noise``: tuple of (T, L, ...) leaves.
    -> (final ls leaves, s_T (L, K), rewards (T, L) f32)."""
    L = s0.shape[0]
    A = n_agents
    if L % A:
        raise ValueError(f"lane count {L} not divisible by n_agents={A}")
    B = L // A
    T = actions.shape[0]
    if block_b is None:
        block_b = B
    if B % block_b:
        raise ValueError(f"block_b={block_b} must divide per-agent "
                         f"batch {B}")
    nB = B // block_b

    def w_spec(leaf):          # (A, ...) stacked weight -> this agent's
        s = leaf.shape[1:]
        return pl.BlockSpec((1,) + s,
                            lambda bi, t, _n=len(s): (bi // nB,)
                            + (0,) * _n)

    def state_spec(leaf):      # (L, ...) leaf -> per-block, t-invariant
        s = leaf.shape[1:]
        return pl.BlockSpec((block_b,) + s,
                            lambda bi, t, _n=len(s): (bi,) + (0,) * _n)

    def stream_spec(leaf):     # (T, L, ...) leaf -> one tick per grid step
        s = leaf.shape[2:]
        return pl.BlockSpec((1, block_b) + s,
                            lambda bi, t, _n=len(s): (t, bi) + (0,) * _n)

    kernel = functools.partial(_rollout_kernel, n_ls=len(ls),
                               n_noise=len(noise), n_w=len(weights), T=T,
                               cell_fn=cell_fn, tick_fn=tick_fn,
                               dset_fn=dset_fn)
    out = pl.pallas_call(
        kernel,
        grid=(A * nB, T),
        in_specs=[state_spec(l) for l in ls] + [state_spec(s0)] + [
            w_spec(w) for w in weights] + [
            stream_spec(actions), stream_spec(bits),
        ] + [stream_spec(n) for n in noise],
        out_specs=[state_spec(l) for l in ls] + [
            state_spec(s0), stream_spec(jnp.empty((T, L), jnp.float32))],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in ls] + [
            jax.ShapeDtypeStruct(s0.shape, s0.dtype),
            jax.ShapeDtypeStruct((T, L), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_b, s0.shape[1]), jnp.float32)] + [
            pltpu.VMEM((block_b,) + l.shape[1:], l.dtype) for l in ls],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*ls, s0, *weights, actions, bits, *noise)
    return tuple(out[:len(ls)]), out[len(ls)], out[len(ls) + 1]


@functools.partial(jax.jit, static_argnames=("n_agents", "tick_fn",
                                             "dset_fn", "block_b",
                                             "interpret"))
def aip_rollout_multi(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                      n_agents: int, tick_fn, dset_fn,
                      block_b: int | None = None,
                      interpret: bool | None = None):
    """Whole-horizon fused IALS rollout, GRU backbone, A per-agent AIPs —
    ONE kernel dispatch for T ticks of every lane.

    ``ls``: tuple of LS state leaves, each (L, ...) with L = A·B lanes in
    *agent-major* order (lane ``a*B + b``) and a kernel-safe dtype
    (int32/float32 — the engine encodes bools); ``h0``: (L, H) AIP state;
    stacked weights ``wx`` (A, D, 3H), ``wh`` (A, H, 3H), ``b`` (A, 3H),
    ``hw`` (A, H, M), ``hb`` (A, M); ``actions``: (T, L) int32; ``bits``:
    (T, L, M) uint32; ``noise``: tuple of (T, L, ...) LS noise leaves.
    ``tick_fn(ls_leaves, a, u, noise_leaves) -> (ls_leaves, r)`` and
    ``dset_fn(ls_leaves, a) -> (lanes, Dd)`` must be pure jnp — they are
    traced into the kernel body and run on VMEM-resident values.

    -> (final ls leaves, h_T (L, H), rewards (T, L) f32), bitwise-equal
    to scanning the per-tick fused step (``ref.ials_rollout_multi_ref``).

    ``block_b`` lane-blocks the *per-agent* batch axis B across the
    parallel grid dimension (must divide B; default: one block per
    agent). ``interpret=None`` auto-detects: compiled on TPU, interpret
    elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    H = wh.shape[1]
    cell = functools.partial(_gru_cell, H=H)
    return _launch_rollout(cell, tuple(ls), h0, (wx, wh, b, hw, hb),
                           actions, bits, tuple(noise), n_agents=n_agents,
                           tick_fn=tick_fn, dset_fn=dset_fn,
                           block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_agents", "tick_fn",
                                             "dset_fn", "block_b",
                                             "interpret"))
def fnn_rollout(ls, buf0, w1, b1, w2, b2, hw, hb, actions, bits, noise, *,
                n_agents: int, tick_fn, dset_fn,
                block_b: int | None = None,
                interpret: bool | None = None):
    """Whole-horizon fused IALS rollout, FNN backbone (Theorem-1 k-step
    predictor), A per-agent AIPs — the frame-stack shift, both relu
    GEMMs, the head, and the Bernoulli draw all trace into the kernel.

    Layout as in ``aip_rollout_multi`` except the AIP recurrent state:
    ``buf0`` is the (L, stack·d_in) *flattened* frame-stack buffer
    (row-major over (stack, d_in), newest frame last, so the shift is a
    plain slice-and-concat — identical values to ``influence.step``'s
    (stack, d_in) buffer). Stacked weights ``w1`` (A, stack·d_in, K),
    ``b1`` (A, K), ``w2`` (A, K, K), ``b2`` (A, K), ``hw`` (A, K, M),
    ``hb`` (A, M).

    -> (final ls leaves, buf_T (L, stack·d_in), rewards (T, L) f32),
    bitwise-equal to scanning the fused per-tick step
    (``ref.fnn_rollout_ref``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _launch_rollout(_fnn_cell, tuple(ls), buf0,
                           (w1, b1, w2, b2, hw, hb), actions, bits,
                           tuple(noise), n_agents=n_agents,
                           tick_fn=tick_fn, dset_fn=dset_fn,
                           block_b=block_b, interpret=interpret)


def aip_rollout(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                tick_fn, dset_fn, block_b: int | None = None,
                interpret: bool | None = None):
    """Single-agent whole-horizon GRU rollout — the A=1 squeeze of
    ``aip_rollout_multi`` (shared-weight lane blocks; kept as the
    historical entry point). Unstacked weights as in ``aip_step``;
    otherwise see ``aip_rollout_multi``.
    """
    return aip_rollout_multi(
        tuple(ls), h0, wx[None], wh[None], b[None], hw[None], hb[None],
        actions, bits, tuple(noise), n_agents=1, tick_fn=tick_fn,
        dset_fn=dset_fn, block_b=block_b, interpret=interpret)


# ---------------------------------------------------------------------------
# Actor-in-the-loop rollout: the policy traced into the same grid
# ---------------------------------------------------------------------------

def _policy_rollout_kernel(*refs, n_ls: int, n_noise: int, n_w: int,
                           T: int, cell_fn, pol_fn, tick_fn, dset_fn,
                           obs_fn):
    """Grid (A·B-blocks, T): one PPO acting tick per grid step.

    Ref layout (positional): LS leaves | AIP state s0 | policy frame
    stack f0 | n_w stacked AIP weights (per-agent block axis) | 8 shared
    policy weights | gumbel, bits, done streams | noise leaves | reset
    LS leaves || final LS leaves, sT, framesT, x, a, logits, v, rewards
    || scratch: AIP state, frames, LS leaves. Per tick: policy forward
    on the VMEM frame stack -> Gumbel-argmax action -> AIP cell +
    Bernoulli draw -> LS transition -> observation refills the frame
    stack -> the streamed ``done`` schedule merges in the streamed reset
    state (AIP state back to zeros, frames re-seeded from the reset
    observation). Only the PPO batch streams and final states leave
    VMEM."""
    i = n_ls
    ls0 = refs[:n_ls]
    s0_ref, f0_ref = refs[i], refs[i + 1]
    i += 2
    w_refs = refs[i:i + n_w]
    i += n_w
    pw_refs = refs[i:i + 8]
    i += 8
    gum_ref, bits_ref, done_ref = refs[i], refs[i + 1], refs[i + 2]
    i += 3
    noise_refs = refs[i:i + n_noise]
    i += n_noise
    reset_refs = refs[i:i + n_ls]
    i += n_ls
    ls_out = refs[i:i + n_ls]
    i += n_ls
    sT_ref, fT_ref = refs[i], refs[i + 1]
    i += 2
    x_ref, a_ref, lg_ref, v_ref, rew_ref = refs[i:i + 5]
    i += 5
    s_scr, f_scr = refs[i], refs[i + 1]
    ls_scr = refs[i + 2:i + 2 + n_ls]

    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)
        f_scr[...] = f0_ref[...].astype(jnp.float32)
        for dst, src in zip(ls_scr, ls0):
            dst[...] = src[...]

    x = f_scr[...]                                     # (Bblk, S)
    logits, value = pol_fn(tuple(r[...] for r in pw_refs), x)
    a = jnp.argmax(logits + gum_ref[0], axis=-1).astype(jnp.int32)

    ls_vals = tuple(s[...] for s in ls_scr)
    d = dset_fn(ls_vals, a).astype(jnp.float32)        # (Bblk, Dd)
    w = tuple(r[0] for r in w_refs)                    # this block's agent
    s2, _, u = cell_fn(w, s_scr[...], d, bits_ref[0])
    new_ls, rew = tick_fn(ls_vals, a, u,
                          tuple(nr[0] for nr in noise_refs))
    obs = obs_fn(new_ls).astype(jnp.float32)           # (Bblk, d_obs)
    d_obs = obs.shape[-1]
    frames2 = jnp.concatenate([x[:, d_obs:], obs], axis=1)

    dn = done_ref[0] != 0                              # (Bblk,)
    ls_m = tuple(
        jnp.where(dn.reshape((-1,) + (1,) * (n.ndim - 1)), r[0], n)
        for n, r in zip(new_ls, reset_refs))
    s_m = jnp.where(dn[:, None], jnp.zeros_like(s2), s2)
    obs0 = obs_fn(ls_m).astype(jnp.float32)
    frames_reset = jnp.concatenate(
        [jnp.zeros_like(x[:, d_obs:]), obs0], axis=1)
    f_m = jnp.where(dn[:, None], frames_reset, frames2)

    s_scr[...] = s_m
    f_scr[...] = f_m
    for dst, val in zip(ls_scr, ls_m):
        dst[...] = val.astype(dst.dtype)
    x_ref[0] = x.astype(x_ref.dtype)
    a_ref[0] = a
    lg_ref[0] = logits.astype(lg_ref.dtype)
    v_ref[0] = value.astype(v_ref.dtype)
    rew_ref[0] = rew.astype(rew_ref.dtype)

    @pl.when(t == T - 1)
    def _finish():
        sT_ref[...] = s_scr[...].astype(sT_ref.dtype)
        fT_ref[...] = f_scr[...].astype(fT_ref.dtype)
        for dst, src in zip(ls_out, ls_scr):
            dst[...] = src[...]


def _launch_policy_rollout(cell_fn, pol_fn, ls, s0, frames0, weights,
                           pol_w, gumbel, bits, done, noise, reset_ls, *,
                           n_agents: int, tick_fn, dset_fn, obs_fn,
                           block_b: int | None, interpret: bool):
    """``pallas_call`` builder for the actor-in-the-loop rollout.

    Layout as in ``_launch_rollout`` plus: ``frames0`` (L, stack·obs_dim)
    f32 policy frame stack; ``pol_w`` tuple of 8 SHARED policy weights
    (full blocks — parameter-shared PPO has no agent axis); ``gumbel``
    (T, L, n_actions) f32; ``done`` (T, L) int32 reset schedule;
    ``reset_ls`` tuple of (T, L, ...) streamed reset-state leaves (same
    dtypes as ``ls``). -> (final ls leaves, s_T, frames_T, x (T, L, S),
    a (T, L) int32, logits (T, L, n_actions), v (T, L), r (T, L))."""
    L = s0.shape[0]
    A = n_agents
    if L % A:
        raise ValueError(f"lane count {L} not divisible by n_agents={A}")
    B = L // A
    T = gumbel.shape[0]
    if block_b is None:
        block_b = B
    if B % block_b:
        raise ValueError(f"block_b={block_b} must divide per-agent "
                         f"batch {B}")
    nB = B // block_b
    S = frames0.shape[1]
    n_act = gumbel.shape[-1]

    def w_spec(leaf):          # (A, ...) stacked weight -> this agent's
        s = leaf.shape[1:]
        return pl.BlockSpec((1,) + s,
                            lambda bi, t, _n=len(s): (bi // nB,)
                            + (0,) * _n)

    def full_spec(leaf):       # shared weight -> whole array, invariant
        return pl.BlockSpec(leaf.shape,
                            lambda bi, t, _n=leaf.ndim: (0,) * _n)

    def state_spec(leaf):      # (L, ...) leaf -> per-block, t-invariant
        s = leaf.shape[1:]
        return pl.BlockSpec((block_b,) + s,
                            lambda bi, t, _n=len(s): (bi,) + (0,) * _n)

    def stream_spec(leaf):     # (T, L, ...) leaf -> one tick per grid step
        s = leaf.shape[2:]
        return pl.BlockSpec((1, block_b) + s,
                            lambda bi, t, _n=len(s): (t, bi) + (0,) * _n)

    stream_outs = [
        jax.ShapeDtypeStruct((T, L, S), jnp.float32),       # x
        jax.ShapeDtypeStruct((T, L), jnp.int32),            # a
        jax.ShapeDtypeStruct((T, L, n_act), jnp.float32),   # logits
        jax.ShapeDtypeStruct((T, L), jnp.float32),          # v
        jax.ShapeDtypeStruct((T, L), jnp.float32),          # rewards
    ]
    kernel = functools.partial(_policy_rollout_kernel, n_ls=len(ls),
                               n_noise=len(noise), n_w=len(weights), T=T,
                               cell_fn=cell_fn, pol_fn=pol_fn,
                               tick_fn=tick_fn, dset_fn=dset_fn,
                               obs_fn=obs_fn)
    out = pl.pallas_call(
        kernel,
        grid=(A * nB, T),
        in_specs=[state_spec(l) for l in ls]
        + [state_spec(s0), state_spec(frames0)]
        + [w_spec(w) for w in weights]
        + [full_spec(w) for w in pol_w]
        + [stream_spec(gumbel), stream_spec(bits), stream_spec(done)]
        + [stream_spec(n) for n in noise]
        + [stream_spec(r) for r in reset_ls],
        out_specs=[state_spec(l) for l in ls]
        + [state_spec(s0), state_spec(frames0)]
        + [stream_spec(o) for o in stream_outs],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in ls]
        + [jax.ShapeDtypeStruct(s0.shape, s0.dtype),
           jax.ShapeDtypeStruct(frames0.shape, frames0.dtype)]
        + stream_outs,
        scratch_shapes=[pltpu.VMEM((block_b, s0.shape[1]), jnp.float32),
                        pltpu.VMEM((block_b, S), jnp.float32)]
        + [pltpu.VMEM((block_b,) + l.shape[1:], l.dtype) for l in ls],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*ls, s0, frames0, *weights, *pol_w, gumbel, bits, done, *noise,
      *reset_ls)
    nl = len(ls)
    return (tuple(out[:nl]), out[nl], out[nl + 1], out[nl + 2],
            out[nl + 3], out[nl + 4], out[nl + 5], out[nl + 6])


@functools.partial(jax.jit, static_argnames=("kind", "n_agents",
                                             "fast_gates", "tick_fn",
                                             "dset_fn", "obs_fn",
                                             "block_b", "interpret"))
def policy_rollout(ls, s0, frames0, aip_w, pol_w, gumbel, bits, done,
                   noise, reset_ls, *, kind: str, n_agents: int,
                   fast_gates: bool, tick_fn, dset_fn, obs_fn,
                   block_b: int | None = None,
                   interpret: bool | None = None):
    """Whole-horizon actor-in-the-loop IALS rollout — an ENTIRE PPO
    acting horizon (policy forward + Gumbel-argmax action + AIP sample +
    LS transition + reward + periodic episode resets) in ONE kernel
    dispatch, with the policy frame stack, AIP recurrent state, and every
    LS leaf VMEM-resident across all T grid steps.

    ``kind`` picks the AIP backbone cell ("gru": ``aip_w`` = stacked
    (wx, wh, b, hw, hb); "fnn": (w1, b1, w2, b2, hw, hb)); ``pol_w`` is
    the shared policy tuple (w1, b1, w2, b2, piw, pib, vw, vb) evaluated
    with the rational gates when ``fast_gates`` (exact tanh otherwise);
    randomness is all pre-drawn (``gumbel`` for actions, ``bits`` for
    the AIP Bernoulli draw, ``noise`` for the LS, ``reset_ls`` +
    ``done`` for the episode-reset schedule), so the kernel is a pure
    function. ``obs_fn(ls_leaves) -> (lanes, obs_dim)`` must be pure,
    constant-free jnp (the ``BatchedLocalEnv.obs_fn`` contract) — it is
    traced into the body to refill the frame stack each tick.

    Layout and the remaining arguments as in ``aip_rollout_multi`` /
    ``_launch_policy_rollout``; bitwise-equal to
    ``ref.policy_rollout_ref`` given the same streams.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if kind == "gru":
        cell = functools.partial(_gru_cell, H=aip_w[1].shape[1])
    else:
        cell = _fnn_cell
    pol = functools.partial(_policy_cell, fast_gates=fast_gates)
    return _launch_policy_rollout(
        cell, pol, tuple(ls), s0, frames0, tuple(aip_w), tuple(pol_w),
        gumbel, bits, done, tuple(noise), tuple(reset_ls),
        n_agents=n_agents, tick_fn=tick_fn, dset_fn=dset_fn,
        obs_fn=obs_fn, block_b=block_b, interpret=interpret)

