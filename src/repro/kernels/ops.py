"""Public jit'd wrappers around the Pallas kernels.

On a real TPU runtime set ``interpret=False`` (the default flips on TPU
backends automatically); in this CPU container interpret mode executes the
kernel bodies in Python for correctness validation against ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import aip_step as _aip
from . import flash_attention as _fa
from . import gru as _gru
from . import ref as _ref
from . import rmsnorm as _rms


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_mha(q, k, v, *, causal=True, scale=None, bq=128, bk=128):
    """q: (B, T, H, D); k, v: (B, S, KH, D) with GQA support.

    Flattens (B, H) into the kernel batch; GQA KV heads are repeated into
    query-head groups OUTSIDE the kernel (zero-copy broadcast reshape).
    """
    B, T, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KH, G, S, D)).reshape(B * H, S, D)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KH, G, S, v.shape[-1])).reshape(B * H, S,
                                                              v.shape[-1])
    o = _fa.flash_attention(qf, kf, vf, causal=causal, scale=scale,
                            bq=bq, bk=bk, interpret=_default_interpret())
    return o.reshape(B, H, T, -1).transpose(0, 2, 1, 3)


def gru_sequence(params, xs, h0=None):
    """Drop-in for repro.nn.rnn.gru_sequence backed by the fused kernel."""
    B, T, D = xs.shape
    H = params["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, H), xs.dtype)
    return _gru.gru_sequence(xs, params["wx"], params["wh"], params["b"],
                             h0, interpret=_default_interpret())


def aip_step(d, h, wx, wh, b, hw, hb, bits):
    """Fused IALS AIP tick: GRU cell + head + sigmoid + Bernoulli draw.

    On TPU this is one compiled Pallas invocation with the state resident
    in VMEM. Elsewhere it dispatches the pure-jnp oracle directly — the
    same math as the kernel (shared ``repro.nn.act`` gates and
    threshold-compare), but without interpret-mode's per-grid-point
    emulation overhead, because this op sits on the rollout hot path.
    """
    if jax.default_backend() == "tpu":
        return _aip.aip_step(d, h, wx, wh, b, hw, hb, bits,
                             interpret=False)
    return _ref.aip_step_ref(d, h, wx, wh, b, hw, hb, bits)


def aip_step_multi(d, h, wx, wh, b, hw, hb, bits):
    """A per-agent fused AIP ticks with stacked (A, ...) weights.

    d: (B, A, D); h: (B, A, H); bits: (B, A, M) uint32 -> (h_new, logits,
    u), all leading (B, A). On TPU: an agent-axis vmap of the compiled
    ``aip_step`` kernel (one batched invocation). Elsewhere: the
    vmapped-per-agent oracle — numerically equal to the stacked
    ``ref.aip_step_multi_ref`` einsum but measurably faster under XLA CPU
    (see the ``--ab`` bench's stacked-vs-vmapped tick rows), and the
    exact computation the whole-horizon rollout oracle scans, so the
    per-tick and forced-ops routes stay bitwise-equal.
    """
    if jax.default_backend() == "tpu":
        return jax.vmap(
            lambda dd, hh, a1, a2, a3, a4, a5, bt: _aip.aip_step(
                dd, hh, a1, a2, a3, a4, a5, bt, interpret=False),
            in_axes=(1, 1, 0, 0, 0, 0, 0, 1), out_axes=(1, 1, 1))(
                d, h, wx, wh, b, hw, hb, bits)
    return _ref.aip_step_multi_vmapped_ref(d, h, wx, wh, b, hw, hb, bits)


def ials_rollout_multi(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                       n_agents, tick_fn, dset_fn, block_b=None,
                       interpret=None):
    """Whole-horizon fused IALS rollout, GRU backbone, A per-agent AIPs:
    T coupled AIP+LS ticks for every A·B agent-major lane in ONE kernel
    dispatch (``aip_rollout_multi``'s (A·B-blocks, T) grid, per-agent
    weights indexed by the agent coordinate of each lane block) on TPU;
    the identical-math ``ref.ials_rollout_multi_ref`` scan elsewhere.
    Both paths run the caller's ``tick_fn``/``dset_fn`` on the same
    values in the same order, so they agree bitwise given the same bits
    and noise.

    ``interpret=None`` is the production dispatch above; passing a bool
    forces the Pallas kernel itself (interpret mode off-TPU — the parity
    tests exercise the real grid/scratch machinery that way).
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.aip_rollout_multi(
                tuple(ls), h0, wx, wh, b, hw, hb, actions, bits,
                tuple(noise), n_agents=n_agents, tick_fn=tick_fn,
                dset_fn=dset_fn, block_b=block_b, interpret=False)
        return _ref.ials_rollout_multi_ref(
            tuple(ls), h0, wx, wh, b, hw, hb, actions, bits, tuple(noise),
            n_agents=n_agents, tick_fn=tick_fn, dset_fn=dset_fn)
    return _aip.aip_rollout_multi(
        tuple(ls), h0, wx, wh, b, hw, hb, actions, bits, tuple(noise),
        n_agents=n_agents, tick_fn=tick_fn, dset_fn=dset_fn,
        block_b=block_b, interpret=interpret)


def fnn_rollout(ls, buf0, w1, b1, w2, b2, hw, hb, actions, bits, noise, *,
                n_agents, tick_fn, dset_fn, block_b=None, interpret=None):
    """Whole-horizon fused IALS rollout, FNN backbone (the Theorem-1
    k-step predictor): frame-stack shift + two relu GEMMs + head + draw
    traced into ``fnn_rollout``'s kernel body on TPU, the identical-math
    ``ref.fnn_rollout_ref`` scan elsewhere. Layout and ``interpret``
    semantics as in ``ials_rollout_multi``; ``buf0`` is the
    (L, stack·d_in) flattened frame buffer.
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.fnn_rollout(
                tuple(ls), buf0, w1, b1, w2, b2, hw, hb, actions, bits,
                tuple(noise), n_agents=n_agents, tick_fn=tick_fn,
                dset_fn=dset_fn, block_b=block_b, interpret=False)
        return _ref.fnn_rollout_ref(
            tuple(ls), buf0, w1, b1, w2, b2, hw, hb, actions, bits,
            tuple(noise), n_agents=n_agents, tick_fn=tick_fn,
            dset_fn=dset_fn)
    return _aip.fnn_rollout(
        tuple(ls), buf0, w1, b1, w2, b2, hw, hb, actions, bits,
        tuple(noise), n_agents=n_agents, tick_fn=tick_fn, dset_fn=dset_fn,
        block_b=block_b, interpret=interpret)


def ials_rollout(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                 tick_fn, dset_fn, block_b=None, interpret=None):
    """Whole-horizon fused IALS rollout: T coupled AIP+LS ticks in ONE
    kernel dispatch, AIP hidden state and LS leaves VMEM-resident across
    the horizon (``aip_rollout``'s (B-blocks, T) grid) on TPU; the
    identical-math ``ref.ials_rollout_ref`` scan elsewhere. Both paths run
    the caller's ``tick_fn``/``dset_fn`` on the same values in the same
    order, so they agree bitwise given the same bits and noise.

    ``interpret=None`` is the production dispatch above; passing a bool
    forces the Pallas kernel itself (interpret mode off-TPU — the parity
    tests exercise the real grid/scratch machinery that way).
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.aip_rollout(tuple(ls), h0, wx, wh, b, hw, hb,
                                    actions, bits, tuple(noise),
                                    tick_fn=tick_fn, dset_fn=dset_fn,
                                    block_b=block_b, interpret=False)
        return _ref.ials_rollout_ref(tuple(ls), h0, wx, wh, b, hw, hb,
                                     actions, bits, tuple(noise),
                                     tick_fn=tick_fn, dset_fn=dset_fn)
    return _aip.aip_rollout(tuple(ls), h0, wx, wh, b, hw, hb, actions,
                            bits, tuple(noise), tick_fn=tick_fn,
                            dset_fn=dset_fn, block_b=block_b,
                            interpret=interpret)


def policy_rollout(ls, s0, frames0, aip_w, pol_w, gumbel, bits, done,
                   noise, reset_ls, *, kind, n_agents, fast_gates,
                   tick_fn, dset_fn, obs_fn, block_b=None,
                   interpret=None):
    """Whole-horizon actor-in-the-loop IALS rollout: an entire PPO acting
    horizon — policy forward on the VMEM-resident frame stack,
    Gumbel-argmax action sampling on pre-drawn noise, the AIP backbone
    cell (``kind`` in {"gru", "fnn"}) with its Bernoulli draw, the LS
    transition + reward, and the periodic episode-reset merge — in ONE
    kernel dispatch (``aip_step.policy_rollout``'s (A·B-blocks, T) grid)
    on TPU; the identical-math ``ref.policy_rollout_ref`` scan elsewhere.
    Both paths run the caller's ``tick_fn``/``dset_fn``/``obs_fn`` on the
    same values in the same order, so they agree bitwise given the same
    streams — and both are bitwise with PPO's own hoisted scan, which is
    what lets the engine hand its acting loop over wholesale.

    ``interpret=None`` is the production dispatch above; passing a bool
    forces the Pallas kernel itself (interpret mode off-TPU — the parity
    tests exercise the real grid/scratch machinery that way).
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.policy_rollout(
                tuple(ls), s0, frames0, tuple(aip_w), tuple(pol_w),
                gumbel, bits, done, tuple(noise), tuple(reset_ls),
                kind=kind, n_agents=n_agents, fast_gates=fast_gates,
                tick_fn=tick_fn, dset_fn=dset_fn, obs_fn=obs_fn,
                block_b=block_b, interpret=False)
        return _ref.policy_rollout_ref(
            tuple(ls), s0, frames0, tuple(aip_w), tuple(pol_w), gumbel,
            bits, done, tuple(noise), tuple(reset_ls), kind=kind,
            n_agents=n_agents, fast_gates=fast_gates, tick_fn=tick_fn,
            dset_fn=dset_fn, obs_fn=obs_fn)
    return _aip.policy_rollout(
        tuple(ls), s0, frames0, tuple(aip_w), tuple(pol_w), gumbel, bits,
        done, tuple(noise), tuple(reset_ls), kind=kind,
        n_agents=n_agents, fast_gates=fast_gates, tick_fn=tick_fn,
        dset_fn=dset_fn, obs_fn=obs_fn, block_b=block_b,
        interpret=interpret)


def serve_forward(frames, mask, pol_w, *, fast_gates, block_s=None,
                  interpret=None):
    """Masked fixed-slot policy forward — the serving tier's one inference
    dispatch (``serving/server.py::PolicyServer`` drives it): the packed
    request slot ``frames`` (S, D) f32 and lane-validity ``mask`` (S,)
    through the PPO actor-critic net (``pol_w`` = the flat
    ``rl/ppo.py::flat_policy_weights`` tuple) -> (logits (S, n_actions),
    v (S,)), pad lanes exactly zeroed INSIDE the dispatch — the kernel
    boundary of the ragged-batch contract (``envs/api.py``): pad-lane
    contents can never perturb a real lane, and at the fixed slot shape
    real-lane outputs are bitwise independent of lane position and pad
    pattern. On TPU this is the compiled Pallas kernel
    (``aip_step.serve_forward``); elsewhere the identical-math oracle
    (``ref.serve_forward_ref``) — both compute the two policy heads as
    one fused GEMM, so logits are bitwise across routes and ``v`` is the
    documented 1-ulp leaf vs the PPO scan forward (ARCHITECTURE §4).

    ``interpret=None`` is the production dispatch above; passing a bool
    forces the Pallas kernel itself (interpret mode off-TPU — the parity
    tests exercise the real grid/block machinery that way).
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.serve_forward(frames, mask, tuple(pol_w),
                                      fast_gates=fast_gates,
                                      block_s=block_s, interpret=False)
        return _ref.serve_forward_ref(tuple(pol_w), frames, mask,
                                      fast_gates=fast_gates)
    return _aip.serve_forward(frames, mask, tuple(pol_w),
                              fast_gates=fast_gates, block_s=block_s,
                              interpret=interpret)


def serve_forward_multi(frames, mask, pidx, pol_ws, *, fast_gates,
                        block_s=None, interpret=None):
    """Cross-policy masked fixed-slot policy forward — the multi-tenant
    serving dispatch (one server, many checkpoints): the packed request
    slot ``frames`` (S, D) f32, lane-validity ``mask`` (S,), and
    per-lane policy indices ``pidx`` (S,) int32 through N stacked
    actor-critic checkpoints (``pol_ws`` = the
    ``rl/ppo.py::stack_policy_weights`` tuple, (N, ...) leading policy
    axis) -> (logits (S, n_actions), v (S,)), pad lanes and unroutable
    ``pidx`` lanes exactly zeroed INSIDE the dispatch. Every lane's
    output is bitwise-identical to the single-policy ``serve_forward``
    of its own checkpoint at the same slot shape — each checkpoint's
    forward runs the exact single-policy cell over the full slot and
    lanes select their row, so cross-policy batching cannot skew a
    tenant's actions (pinned by the N-policies-vs-N-servers parity
    tests). On TPU this is the compiled Pallas kernel
    (``aip_step.serve_forward_multi``); elsewhere the identical-math
    oracle (``ref.serve_forward_multi_ref``).

    ``interpret=None`` is the production dispatch above; passing a bool
    forces the Pallas kernel itself (interpret mode off-TPU — the parity
    tests exercise the real grid/block machinery that way).
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return _aip.serve_forward_multi(frames, mask, pidx,
                                            tuple(pol_ws),
                                            fast_gates=fast_gates,
                                            block_s=block_s,
                                            interpret=False)
        return _ref.serve_forward_multi_ref(tuple(pol_ws), frames, mask,
                                            pidx, fast_gates=fast_gates)
    return _aip.serve_forward_multi(frames, mask, pidx, tuple(pol_ws),
                                    fast_gates=fast_gates,
                                    block_s=block_s, interpret=interpret)


def rmsnorm(x, g, *, eps: float = 1e-6):
    shp = x.shape
    out = _rms.rmsnorm(x.reshape(-1, shp[-1]), g, eps=eps,
                       interpret=_default_interpret())
    return out.reshape(shp)
