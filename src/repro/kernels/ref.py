"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, Dv). Naive softmax."""
    D = q.shape[-1]
    scale = (D ** -0.5) if scale is None else scale
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        T, S = q.shape[1], k.shape[1]
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _gru_cell_ref(wx, wh, b, h, xt):
    H = wh.shape[0]
    gx = xt @ wx + b
    gh = h @ wh
    r = fast_sigmoid(gx[..., :H] + gh[..., :H])
    z = fast_sigmoid(gx[..., H:2 * H] + gh[..., H:2 * H])
    n = fast_tanh(gx[..., 2 * H:] + r * gh[..., 2 * H:])
    return (1.0 - z) * n + z * h


def gru_sequence_ref(x, wx, wh, b, h0):
    """x: (B, T, D); wx: (D, 3H); wh: (H, 3H); b: (3H,); h0: (B, H)."""

    def cell(h, xt):
        h2 = _gru_cell_ref(wx, wh, b, h, xt)
        return h2, h2

    hT, hs = jax.lax.scan(cell, h0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1), hT


def aip_step_ref(d, h, wx, wh, b, hw, hb, bits):
    """Fused AIP step oracle: GRU cell + head + sigmoid + Bernoulli draw.

    d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,); hw: (H, M);
    hb: (M,); bits: (B, M) uint32 counter-based random bits.
    -> (h_new (B, H), logits (B, M), u (B, M) f32 in {0, 1}).
    """
    h2 = _gru_cell_ref(wx, wh, b, h.astype(jnp.float32),
                       d.astype(jnp.float32))
    logits = h2 @ hw + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def gru_step_multi_ref(d, h, wx, wh, b, hw, hb):
    """A per-agent GRU-backbone AIP cells as ONE stacked contraction —
    the agent axis is a batch dimension of every einsum, not a vmap.

    d: (B, A, D); h: (B, A, H); stacked weights wx (A, D, 3H),
    wh (A, H, 3H), b (A, 3H), hw (A, H, M), hb (A, M)
    -> (h_new (B, A, H), logits (B, A, M)). The per-agent math is
    identical to ``_gru_cell_ref`` (the stacked-vs-vmapped parity test
    pins that down)."""
    H = wh.shape[1]
    gx = jnp.einsum('bad,adk->bak', d, wx) + b
    gh = jnp.einsum('bah,ahk->bak', h, wh)
    r = fast_sigmoid(gx[..., :H] + gh[..., :H])
    z = fast_sigmoid(gx[..., H:2 * H] + gh[..., H:2 * H])
    n = fast_tanh(gx[..., 2 * H:] + r * gh[..., 2 * H:])
    h2 = (1.0 - z) * n + z * h
    logits = jnp.einsum('bah,ahm->bam', h2, hw) + hb
    return h2, logits


def aip_step_multi_ref(d, h, wx, wh, b, hw, hb, bits):
    """``aip_step_ref`` for A per-agent AIPs with stacked weights: the
    fused tick (cell + head + sigmoid + Bernoulli threshold-compare) in
    (B, A, ...) layout — the *stacked* formulation, documenting exactly
    the math each ``aip_rollout_multi`` lane block runs against its
    agent's weight slice. bits: (B, A, M) uint32.
    -> (h_new, logits, u) all leading (B, A)."""
    h2, logits = gru_step_multi_ref(d.astype(jnp.float32),
                                    h.astype(jnp.float32),
                                    wx, wh, b, hw, hb)
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def aip_step_multi_vmapped_ref(d, h, wx, wh, b, hw, hb, bits):
    """The *vmapped-per-agent* formulation of the same fused multi tick:
    an agent-axis vmap of ``aip_step_ref``. Numerically this equals the
    stacked ``aip_step_multi_ref`` (the parity test pins the two
    together), but on CPU XLA schedules it measurably faster than the
    stacked einsum (same-phase A/B: ~1.25x on the warehouse engine), so
    this is what the per-tick engine path and the rollout oracle scan
    actually run off-TPU — while the whole-horizon kernel keeps the
    stacked layout its grid structurally needs."""
    return jax.vmap(
        lambda dd, hh, a1, a2, a3, a4, a5, bt: aip_step_ref(
            dd, hh, a1, a2, a3, a4, a5, bt),
        in_axes=(1, 1, 0, 0, 0, 0, 0, 1), out_axes=(1, 1, 1))(
            d, h, wx, wh, b, hw, hb, bits)


def fnn_step_multi_ref(buf, d, w1, b1, w2, b2, hw, hb):
    """A per-agent FNN-backbone (Theorem-1 k-step) AIP cells as stacked
    contractions over a *flattened* frame buffer.

    buf: (B, A, S) with S = stack·d_in (row-major over (stack, d_in),
    newest frame last — the flat shift is value-identical to
    ``influence.step``'s (stack, d_in) concat); d: (B, A, d_in); stacked
    weights w1 (A, S, K), b1 (A, K), w2 (A, K, K), b2 (A, K),
    hw (A, K, M), hb (A, M) -> (buf_new, logits). The einsum contraction
    pattern matches ``influence._fnn_step_multi`` exactly."""
    buf2 = jnp.concatenate([buf[..., d.shape[-1]:], d], axis=-1)
    h = jax.nn.relu(jnp.einsum('baf,afk->bak', buf2, w1) + b1)
    h = jax.nn.relu(jnp.einsum('bak,akj->baj', h, w2) + b2)
    logits = jnp.einsum('baj,ajm->bam', h, hw) + hb
    return buf2, logits


def _lanes_to_ba(x, n_agents: int):
    """(L, ...) agent-major lanes -> (B, A, ...). (Deliberately NOT named
    like the engine's fold helpers, which map the opposite direction.)"""
    B = x.shape[0] // n_agents
    return x.reshape((n_agents, B) + x.shape[1:]).swapaxes(0, 1)


def _ba_to_lanes(x):
    """(B, A, ...) -> (L, ...) agent-major lanes."""
    return x.swapaxes(0, 1).reshape((-1,) + x.shape[2:])


def ials_rollout_ref(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                     tick_fn, dset_fn):
    """Whole-horizon fused IALS rollout oracle (GRU, shared weights): a
    scan of exactly the per-tick math ``aip_rollout`` runs per grid step
    (same ``tick_fn`` / ``dset_fn`` closures, same ``aip_step_ref``
    cell), so kernel and oracle agree bit-for-bit given the same bits.

    ls: tuple of (B, ...) LS state leaves; actions (T, B); bits (T, B, M)
    uint32; noise: tuple of (T, B, ...) leaves.
    -> (final ls leaves, h_T, rewards (T, B) f32).
    """

    def tick(carry, xs):
        ls, h = carry
        a, bt, nz = xs
        d = dset_fn(ls, a).astype(jnp.float32)
        h2, _, u = aip_step_ref(d, h, wx, wh, b, hw, hb, bt)
        ls2, r = tick_fn(ls, a, u, nz)
        return (tuple(ls2), h2), r.astype(jnp.float32)

    (ls_T, h_T), rews = jax.lax.scan(
        tick, (tuple(ls), h0), (actions, bits, tuple(noise)), unroll=8)
    return ls_T, h_T, rews


def ials_rollout_multi_ref(ls, h0, wx, wh, b, hw, hb, actions, bits,
                           noise, *, n_agents: int, tick_fn, dset_fn):
    """Stacked-weight whole-horizon rollout oracle (GRU): the
    ``aip_rollout_multi`` ground truth. Lane layout as in the kernel —
    (L, ...) leaves, L = A·B agent-major; stacked (A, ...) weights. The
    AIP cell runs in (B, A, ...) layout through
    ``aip_step_multi_vmapped_ref`` (the exact per-agent computation the
    unified engine's per-tick path uses off-TPU, so the forced-ops route
    stays bitwise with the scan), the LS tick on the flat lanes. A=1
    squeezes to ``ials_rollout_ref``.
    -> (final ls leaves, h_T (L, H), rewards (T, L) f32)."""
    A = n_agents
    if A == 1:
        return ials_rollout_ref(ls, h0, wx[0], wh[0], b[0], hw[0], hb[0],
                                actions, bits, noise, tick_fn=tick_fn,
                                dset_fn=dset_fn)

    def tick(carry, xs):
        ls, h = carry                       # h: (B, A, H)
        a, bt, nz = xs
        d = _lanes_to_ba(dset_fn(ls, a).astype(jnp.float32), A)
        h2, _, u = aip_step_multi_vmapped_ref(d, h, wx, wh, b, hw, hb,
                                              _lanes_to_ba(bt, A))
        ls2, r = tick_fn(ls, a, _ba_to_lanes(u), nz)
        return (tuple(ls2), h2), r.astype(jnp.float32)

    (ls_T, h_T), rews = jax.lax.scan(
        tick, (tuple(ls), _lanes_to_ba(h0, A)),
        (actions, bits, tuple(noise)), unroll=8)
    return ls_T, _ba_to_lanes(h_T), rews


def fnn_rollout_ref(ls, buf0, w1, b1, w2, b2, hw, hb, actions, bits,
                    noise, *, n_agents: int, tick_fn, dset_fn):
    """Stacked-weight whole-horizon rollout oracle (FNN backbone): the
    ``fnn_rollout`` ground truth. ``buf0``: (L, stack·d_in) flattened
    frame buffers; stacked (A, ...) weights; lane layout as in
    ``ials_rollout_multi_ref``. A=1 runs the plain 2D matmul path
    (identical association to ``influence.step``'s dense calls).
    -> (final ls leaves, buf_T (L, stack·d_in), rewards (T, L) f32)."""
    A = n_agents
    if A == 1:

        def tick(carry, xs):
            ls, buf = carry
            a, bt, nz = xs
            d = dset_fn(ls, a).astype(jnp.float32)
            buf2 = jnp.concatenate([buf[:, d.shape[1]:], d], axis=1)
            h = jax.nn.relu(buf2 @ w1[0] + b1[0])
            h = jax.nn.relu(h @ w2[0] + b2[0])
            logits = h @ hw[0] + hb[0]
            u = (uniform_from_bits(bt) < fast_sigmoid(logits)
                 ).astype(jnp.float32)
            ls2, r = tick_fn(ls, a, u, nz)
            return (tuple(ls2), buf2), r.astype(jnp.float32)

        (ls_T, buf_T), rews = jax.lax.scan(
            tick, (tuple(ls), buf0), (actions, bits, tuple(noise)),
            unroll=8)
        return ls_T, buf_T, rews

    def tick(carry, xs):
        ls, buf = carry                     # buf: (B, A, S)
        a, bt, nz = xs
        d = _lanes_to_ba(dset_fn(ls, a).astype(jnp.float32), A)
        buf2, logits = fnn_step_multi_ref(buf, d, w1, b1, w2, b2, hw, hb)
        u = (uniform_from_bits(_lanes_to_ba(bt, A)) < fast_sigmoid(logits)
             ).astype(jnp.float32)
        ls2, r = tick_fn(ls, a, _ba_to_lanes(u), nz)
        return (tuple(ls2), buf2), r.astype(jnp.float32)

    (ls_T, buf_T), rews = jax.lax.scan(
        tick, (tuple(ls), _lanes_to_ba(buf0, A)),
        (actions, bits, tuple(noise)), unroll=8)
    return ls_T, _ba_to_lanes(buf_T), rews


def _policy_fwd_ref(pol_w, x, fast_gates: bool):
    """The PPO actor-critic forward on a flat weight tuple — the exact
    math of ``rl/ppo.py::policy_forward`` (dense = x @ w + b, hidden
    layers through the shared gates), so the actor-in-the-loop rollout
    stays bitwise with the PPO scan path. pol_w = (w1, b1, w2, b2, piw,
    pib, vw, vb); x: (..., S) -> (logits (..., n_actions), value (...))."""
    w1, b1, w2, b2, piw, pib, vw, vb = pol_w
    act = fast_tanh if fast_gates else jnp.tanh
    h = act(x @ w1 + b1)
    h = act(h @ w2 + b2)
    # both heads as one GEMM, matching the kernel's ``_policy_cell``
    # exactly (a lone (Hp, 1) matvec drifts by 1 ulp across program
    # shapes); vs the PPO scan path this makes ``v`` the one documented
    # allclose-not-bitwise leaf of the fused-actor routes
    out = h @ jnp.concatenate([piw, vw], axis=1) \
        + jnp.concatenate([pib, vb], axis=0)
    return out[..., :-1], out[..., -1]


def serve_forward_ref(pol_w, frames, mask, *, fast_gates: bool):
    """Masked fixed-slot policy forward — the ``serve_forward`` kernel's
    ground truth and the off-TPU serving dispatch. ``frames``: (S, d_in)
    f32 packed slot (real lanes wherever ``mask`` is nonzero, pad lanes
    elsewhere); ``mask``: (S,) int32/bool lane-validity mask ->
    (logits (S, n_actions), v (S,)) with pad lanes exactly zeroed.

    Every lane runs the exact ``_policy_fwd_ref`` math (both heads fused
    into one GEMM — the serving slot shape is fixed, so lane outputs are
    bitwise independent of pad contents and lane position; see the
    ragged-batch contract in ``envs/api.py``), and the mask is applied at
    this boundary so pad lanes can never leak into a consumer."""
    logits, v = _policy_fwd_ref(pol_w, frames, fast_gates)
    m = mask != 0
    return (jnp.where(m[:, None], logits, 0.0),
            jnp.where(m, v, 0.0))


def serve_forward_multi_ref(pol_ws, frames, mask, pidx, *,
                            fast_gates: bool):
    """Cross-policy masked slot forward — the ``serve_forward_multi``
    kernel's ground truth and the off-TPU dispatch. ``pol_ws`` is the
    stacked ``rl/ppo.py::stack_policy_weights`` tuple ((N, ...) leading
    policy axis); ``pidx``: (S,) int32 per-lane policy index; frames and
    mask as in ``serve_forward_ref`` -> (logits (S, n_actions), v (S,)),
    pad lanes exactly zeroed, and any lane whose ``pidx`` is outside
    [0, N) zeroed too (an unroutable lane must not silently run some
    checkpoint).

    Every policy's forward runs over the FULL slot at the same (S, d_in)
    program shape as the single-policy ``serve_forward_ref``, and lanes
    select their own policy's row afterwards — N slot-shaped GEMMs
    instead of a per-lane weight gather. That is deliberate: the gather
    would change the contraction the MXU sees and break the bitwise
    N-policies-vs-N-separate-servers parity this route pins; the N-fold
    slot FLOPs are the price, paid at shapes where per-dispatch overhead,
    not GEMM FLOPs, dominates (N = a handful of region families)."""
    S = frames.shape[0]
    n_pol = pol_ws[0].shape[0]
    logits = jnp.zeros((S, pol_ws[4].shape[-1]), jnp.float32)
    v = jnp.zeros((S,), jnp.float32)
    for n in range(n_pol):
        lg_n, v_n = _policy_fwd_ref(tuple(w[n] for w in pol_ws), frames,
                                    fast_gates)
        sel = pidx == n
        logits = jnp.where(sel[:, None], lg_n, logits)
        v = jnp.where(sel, v_n, v)
    m = mask != 0
    return (jnp.where(m[:, None], logits, 0.0),
            jnp.where(m, v, 0.0))


def policy_rollout_ref(ls, s0, frames0, aip_w, pol_w, gumbel, bits, done,
                       noise, reset_ls, *, kind: str, n_agents: int,
                       fast_gates: bool, tick_fn, dset_fn, obs_fn):
    """Whole-horizon actor-in-the-loop rollout oracle: the
    ``policy_rollout`` kernel's ground truth, and bit-for-bit the PPO
    hoisted-scan tick (frame-stack shift, policy forward, Gumbel-argmax
    action, AIP sample, LS tick, periodic reset merge) in lane layout.

    ls / reset_ls: tuples of (L, ...) / (T, L, ...) kernel-encoded LS
    leaves, L = A·B agent-major; s0: (L, K) AIP recurrent state (GRU
    hidden / flattened FNN frame buffer); frames0: (L, stack·obs_dim)
    flattened policy frame stack; aip_w: stacked (A, ...) backbone
    weights ((wx, wh, b, hw, hb) for ``kind="gru"``, (w1, b1, w2, b2,
    hw, hb) for ``"fnn"``); pol_w: the SHARED (parameter-shared PPO)
    policy weight tuple of ``_policy_fwd_ref``; gumbel: (T, L,
    n_actions) f32 pre-drawn action noise; bits: (T, L, M) uint32;
    done: (T, L) int32 episode-reset schedule; noise: tuple of (T, L,
    ...) LS noise leaves; the AIP state resets to zeros (its init value)
    on done, matching the engine's ``reset``.

    The AIP cell runs in (B, A, ...) layout through the same
    formulations the per-tick engine dispatches off-TPU (vmapped GRU /
    stacked-einsum FNN), and the policy forward runs in (B, A, S) — the
    PPO scan's own shapes — so the forced-ops route stays bitwise with
    the scan. -> (final ls leaves, s_T (L, K), frames_T (L, S), x (T, L,
    S), a (T, L) int32, logits (T, L, n_actions), v (T, L), r (T, L))."""
    A = n_agents
    to_ba = (lambda x: _lanes_to_ba(x, A)) if A > 1 else (lambda x: x)
    to_l = _ba_to_lanes if A > 1 else (lambda x: x)

    def aip_cell(s, d, bt):
        if kind == "gru":
            wx, wh, b, hw, hb = aip_w
            if A == 1:
                return aip_step_ref(d, s, wx[0], wh[0], b[0], hw[0],
                                    hb[0], bt)
            return aip_step_multi_vmapped_ref(d, s, wx, wh, b, hw, hb,
                                              bt)
        w1, b1, w2, b2, hw, hb = aip_w
        if A == 1:
            buf2 = jnp.concatenate([s[:, d.shape[-1]:], d], axis=1)
            h = jax.nn.relu(buf2 @ w1[0] + b1[0])
            h = jax.nn.relu(h @ w2[0] + b2[0])
            logits = h @ hw[0] + hb[0]
        else:
            buf2, logits = fnn_step_multi_ref(s, d, w1, b1, w2, b2, hw,
                                              hb)
        u = (uniform_from_bits(bt) < fast_sigmoid(logits)
             ).astype(jnp.float32)
        return buf2, logits, u

    def tick(carry, xs):
        lsc, s, frames = carry              # frames: (B, [A,] S) f32
        g, bt, dn, nz, rls = xs
        x = frames
        logits, value = _policy_fwd_ref(pol_w, x, fast_gates)
        a_ba = jnp.argmax(logits + to_ba(g), axis=-1)
        a = to_l(a_ba)
        d = to_ba(dset_fn(lsc, a).astype(jnp.float32))
        s2, _, u_ba = aip_cell(s, d, to_ba(bt))
        ls2, r = tick_fn(lsc, a, to_l(u_ba), nz)
        obs = obs_fn(ls2)
        d_obs = obs.shape[-1]
        obs_ba = to_ba(obs)
        frames2 = jnp.concatenate([x[..., d_obs:], obs_ba], axis=-1)
        dn_b = to_ba(dn) != 0               # (B, [A])
        ls_m = tuple(
            jnp.where((dn != 0).reshape((-1,) + (1,) * (n.ndim - 1)),
                      rl, n) for n, rl in zip(ls2, rls))
        s_m = jnp.where(dn_b[..., None], jnp.zeros_like(s2), s2)
        obs0_ba = to_ba(obs_fn(ls_m))
        frames_reset = jnp.concatenate(
            [jnp.zeros_like(x[..., d_obs:]), obs0_ba], axis=-1)
        frames_m = jnp.where(dn_b[..., None], frames_reset, frames2)
        out = (to_l(x), a, to_l(logits), to_l(value),
               r.astype(jnp.float32))
        return (tuple(ls_m), s_m, frames_m), out

    init = (tuple(ls), to_ba(s0), to_ba(frames0))
    (ls_T, s_T, f_T), (xs, acts, lgs, vs, rs) = jax.lax.scan(
        tick, init, (gumbel, bits, done, tuple(noise), tuple(reset_ls)),
        unroll=8)
    return ls_T, to_l(s_T), to_l(f_T), xs, acts, lgs, vs, rs


def rmsnorm_ref(x, g, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * g.astype(jnp.float32)
            ).astype(x.dtype)
