"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, Dv). Naive softmax."""
    D = q.shape[-1]
    scale = (D ** -0.5) if scale is None else scale
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        T, S = q.shape[1], k.shape[1]
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _gru_cell_ref(wx, wh, b, h, xt):
    H = wh.shape[0]
    gx = xt @ wx + b
    gh = h @ wh
    r = fast_sigmoid(gx[..., :H] + gh[..., :H])
    z = fast_sigmoid(gx[..., H:2 * H] + gh[..., H:2 * H])
    n = fast_tanh(gx[..., 2 * H:] + r * gh[..., 2 * H:])
    return (1.0 - z) * n + z * h


def gru_sequence_ref(x, wx, wh, b, h0):
    """x: (B, T, D); wx: (D, 3H); wh: (H, 3H); b: (3H,); h0: (B, H)."""

    def cell(h, xt):
        h2 = _gru_cell_ref(wx, wh, b, h, xt)
        return h2, h2

    hT, hs = jax.lax.scan(cell, h0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1), hT


def aip_step_ref(d, h, wx, wh, b, hw, hb, bits):
    """Fused AIP step oracle: GRU cell + head + sigmoid + Bernoulli draw.

    d: (B, D); h: (B, H); wx: (D, 3H); wh: (H, 3H); b: (3H,); hw: (H, M);
    hb: (M,); bits: (B, M) uint32 counter-based random bits.
    -> (h_new (B, H), logits (B, M), u (B, M) f32 in {0, 1}).
    """
    h2 = _gru_cell_ref(wx, wh, b, h.astype(jnp.float32),
                       d.astype(jnp.float32))
    logits = h2 @ hw + hb
    probs = fast_sigmoid(logits)
    u = (uniform_from_bits(bits) < probs).astype(jnp.float32)
    return h2, logits, u


def ials_rollout_ref(ls, h0, wx, wh, b, hw, hb, actions, bits, noise, *,
                     tick_fn, dset_fn):
    """Whole-horizon fused IALS rollout oracle: a scan of exactly the
    per-tick math ``aip_rollout`` runs per grid step (same ``tick_fn`` /
    ``dset_fn`` closures, same ``aip_step_ref`` cell), so kernel and
    oracle agree bit-for-bit given the same bits.

    ls: tuple of (B, ...) LS state leaves; actions (T, B); bits (T, B, M)
    uint32; noise: tuple of (T, B, ...) leaves.
    -> (final ls leaves, h_T, rewards (T, B) f32).
    """

    def tick(carry, xs):
        ls, h = carry
        a, bt, nz = xs
        d = dset_fn(ls, a).astype(jnp.float32)
        h2, _, u = aip_step_ref(d, h, wx, wh, b, hw, hb, bt)
        ls2, r = tick_fn(ls, a, u, nz)
        return (tuple(ls2), h2), r.astype(jnp.float32)

    (ls_T, h_T), rews = jax.lax.scan(
        tick, (tuple(ls), h0), (actions, bits, tuple(noise)))
    return ls_T, h_T, rews


def rmsnorm_ref(x, g, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * g.astype(jnp.float32)
            ).astype(x.dtype)
