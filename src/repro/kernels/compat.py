"""Version shims for the Pallas TPU API."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """pltpu compiler params across the TPUCompilerParams -> CompilerParams
    rename; raises a clear error if this jax exposes neither."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported jax version")
    return cls(**kwargs)
