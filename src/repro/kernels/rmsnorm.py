"""Fused RMSNorm Pallas TPU kernel.

One pass per row block: square-mean reduce, rsqrt, scale — all in VMEM.
RMSNorm is memory-bound; fusion keeps it at exactly one HBM read + one HBM
write per element (XLA sometimes splits the reduce and the scale into two
passes around a convert). Rows are tiled (br, d) with d whole per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm(x, g, *, eps: float = 1e-6, br: int = 256,
            interpret: bool = True):
    """x: (N, d); g: (d,) -> (N, d)."""
    N, d = x.shape
    br = min(br, N)
    while N % br:
        br //= 2
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, g)
