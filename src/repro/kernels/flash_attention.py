"""Flash-attention forward Pallas TPU kernel.

TPU adaptation of the Dao flash algorithm: the (q-block, kv-block) loop is
the Pallas *grid* — (batch*heads, T/bq, S/bk) with the kv axis innermost and
"arbitrary" semantics — while online-softmax state (m, l, acc) lives in VMEM
scratch that persists across the kv-grid steps. Block shapes default to the
MXU-native 128x128; both matmuls (q@k^T and p@v) hit the MXU per tile, and
the softmax rescale is fused in-register. No (T, S) score matrix ever exists.

Validated against ``ref.flash_attention_ref`` in interpret mode (this
container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk) MXU

    if causal:
        q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    v = v_ref[0].astype(jnp.float32)                  # (bk, Dv)
    acc_new = acc_prev * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))   # MXU

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (BH, T, D); k, v: (BH, S, D[v]). Heads pre-flattened into batch
    (GQA callers repeat or group KV before the kernel)."""
    BH, T, D = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    scale = (D ** -0.5) if scale is None else scale
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0
    nq, nk = T // bq, S // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
