"""Loop-corrected HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
by calibration in tests/test_hlo_analysis.py) — fatal for scan-over-layers
models where >95% of compute lives inside the layer loop. This module parses
the optimized HLO text, builds the computation call graph, extracts while
trip counts from loop-condition constants, and propagates multipliers to:

- collective bytes by kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), summing *operand* sizes per the spec,
  with all-reduce counted 2x (ring reduce+broadcast);
- FLOPs (dot: 2*prod(result)*prod(contracting); elementwise arithmetic:
  result elems — matters for xLSTM's outer-product updates);
- HBM bytes (operands+results of top-level ops, fusion bodies opaque).

All sizes are per-device (post-SPMD-partitioning shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1,
}

_ARRAY_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*(?:\(.*\))?\s*(?:->.*)?{\s*$")
_REF_RE = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                     r"(?:{([^}]*)}|(%?[\w.\-]+))")
_OPERAND_RE = re.compile(r"(%?[\w.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "negate", "abs", "log", "rsqrt", "sqrt", "select",
    "compare", "and", "or", "xor", "exponential-minus-one", "log-plus-one",
    "floor", "ceil", "sign", "atan2", "remainder", "logistic", "cbrt",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes_elems(type_str: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclass
class HloOp:
    name: str
    type_str: str
    kind: str
    rest: str          # args + attrs raw text
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[HloOp] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None or line.strip() == "}":
            m = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
            if m:
                name = m.group(2).lstrip("%")
                cur = Computation(name=name, is_entry=bool(m.group(1)))
                comps[name] = cur
            elif line.strip() == "}":
                cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            mm = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
            if mm:
                name = mm.group(2).lstrip("%")
                cur = Computation(name=name, is_entry=bool(mm.group(1)))
                comps[name] = cur
            continue
        name, type_str, kind, rest = m.groups()
        name = name.lstrip("%")
        # operands: %refs inside the first paren group (before attrs)
        depth, i = 1, 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_str, attr_str = rest[:i - 1], rest[i:]
        operands = [o.lstrip("%") for o in
                    re.findall(r"%[\w.\-]+", arg_str)]
        op = HloOp(name=name, type_str=type_str, kind=kind,
                   rest=rest, operands=operands)
        cur.ops.append(op)
        cur.types[name] = type_str
    return comps


def _called(op: HloOp) -> List[str]:
    out = []
    for m in _REF_RE.finditer(op.rest):
        grp = m.group(1) or m.group(2)
        for name in re.findall(r"%?([\w.\-]+)", grp):
            out.append(name)
    return out


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.kind == "constant" and op.type_str.startswith("s32"):
            m = re.match(r"(\d+)\)", op.rest.strip())
            if m:
                best = max(best, int(m.group(1)))
    return best


def computation_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation, from ENTRY."""
    mult: Dict[str, float] = defaultdict(float)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {}
    fused_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                fused_bodies.update(_called(op))

    seen_stack = []

    def visit(name: str, m: float):
        if name not in comps or name in seen_stack or m <= 0:
            return
        mult[name] += m
        seen_stack.append(name)
        for op in comps[name].ops:
            if op.kind == "while":
                refs = _REF_RE.finditer(op.rest)
                body = cond = None
                for r in refs:
                    grp = (r.group(1) or r.group(2)).lstrip("%")
                    if "body=" in r.group(0):
                        body = grp
                    elif "condition=" in r.group(0):
                        cond = grp
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
            else:
                for callee in _called(op):
                    visit(callee, m)
        seen_stack.pop()

    visit(entry.name, 1.0)
    return dict(mult), fused_bodies, entry.name


def _dot_flops(op: HloOp, comp: Computation) -> float:
    res_b, res_e = _type_bytes_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rest)
    if not m or not op.operands:
        return 2.0 * res_e
    lhs_type = comp.types.get(op.operands[0], "")
    arrs = _ARRAY_RE.findall(lhs_type)
    if not arrs:
        return 2.0 * res_e
    dims = [int(d) for d in arrs[0][1].split(",") if d]
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * res_e * k


_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def analyze(hlo_text: str) -> Dict[str, float]:
    comps = parse_hlo(hlo_text)
    mult, fused_bodies, entry = computation_multipliers(comps)

    coll_bytes = defaultdict(float)
    coll_counts = defaultdict(float)
    flops = 0.0
    flops_dot = 0.0
    flops_elem = 0.0
    custom_calls = 0.0
    hbm_bytes = 0.0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fused_bodies
        for op in comp.ops:
            res_b, res_e = _type_bytes_elems(op.type_str)
            # ---- flops (count inside fusions too) ----
            if op.kind in ("dot", "convolution"):
                f = m * _dot_flops(op, comp)
                flops += f
                flops_dot += f
            elif op.kind in ELEMENTWISE:
                flops += m * res_e
                flops_elem += m * res_e
            elif op.kind in ("reduce", "reduce-window"):
                ob = sum(_type_bytes_elems(comp.types.get(o, ""))[1]
                         for o in op.operands[:1])
                flops += m * ob
                flops_elem += m * ob
            elif op.kind == "custom-call":
                # opaque to this model (e.g. a Pallas kernel body): count
                # it so a cell with hidden compute is visible as such
                custom_calls += m
            # ---- collectives ----
            if op.kind in COLLECTIVES:
                ob = sum(_type_bytes_elems(comp.types.get(o, ""))[0]
                         for o in op.operands)
                factor = 2.0 if op.kind == "all-reduce" else 1.0
                coll_bytes[op.kind] += m * ob * factor
                coll_counts[op.kind] += m
            # ---- HBM traffic: top-level ops only, fusions opaque ----
            if not in_fusion and op.kind not in _SKIP_MEM:
                ob = sum(_type_bytes_elems(comp.types.get(o, ""))[0]
                         for o in op.operands)
                hbm_bytes += m * (ob + res_b)

    return {
        "flops": flops,
        "flops_dot": flops_dot,
        "flops_elementwise": flops_elem,
        "custom_call_count": custom_calls,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": dict(coll_bytes),
        "collective_counts": dict(coll_counts),
        "collective_bytes_total": float(sum(coll_bytes.values())),
        "n_computations": len(comps),
    }


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e-class chip constants from the brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def roofline(analysis: Dict, n_chips: int,
             model_flops: float | None = None) -> Dict[str, float]:
    """All byte/flop numbers in ``analysis`` are per-device already."""
    t_compute = analysis["flops"] / PEAK_FLOPS
    t_memory = analysis["hbm_bytes"] / HBM_BW
    t_coll = analysis["collective_bytes_total"] / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dominant,
        "step_time_lower_bound_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops:
        out["model_flops_total"] = model_flops
        out["useful_flops_ratio"] = \
            model_flops / max(analysis["flops"] * n_chips, 1.0)
        out["mfu_upper_bound"] = (model_flops / n_chips / PEAK_FLOPS) / \
            max(out["step_time_lower_bound_s"], 1e-12)
    return out
