"""Deterministic fault injection for the disaggregated trainer.

Fleet RL is only as trustworthy as its behavior under churn, and churn
is miserable to reproduce from real preemptions — so this module makes
faults *first-class, scheduled events*. A ``FaultPlan`` is a literal
list of what goes wrong and when, keyed on the trainer's deterministic
tick counter, which means a faulted run is exactly replayable: the
fault-injection tests pin the trainer's behavior (restart streams,
staleness drops, torn-save recovery) bitwise, not statistically.

Three fault families, matching the three seams in
``distributed/actor_learner.py``:

- ``KillWorker(worker_id, at_tick)`` — consulted by the trainer's
  ``before-produce`` seam: the worker's in-memory rollout state is
  discarded and re-initialized from its restart RNG stream (restart
  count increments), modeling a preempted actor process whose
  supervisor restarts it.
- ``DelayBatch(worker_id, at_tick, ticks)`` — the batch produced at
  that tick is held for ``ticks`` scheduler ticks before delivery,
  aging it so it arrives staler than it was produced — the way to drive
  batches past ``max_staleness`` and exercise the drop policy on
  purpose.
- ``torn_save(...)`` — not an event but a harness: reconstructs the
  on-disk layouts a crash mid-``ckpt.save`` can leave behind (tmp-only,
  missing COMMITTED sentinel, truncated array payload) so tests can
  assert the COMMITTED contract holds: ``latest_step`` never surfaces a
  torn checkpoint and ``restore`` falls back to the previous committed
  one.
"""
from __future__ import annotations

import shutil
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Tuple

from repro.checkpoint import ckpt


@dataclass(frozen=True)
class KillWorker:
    """Kill worker ``worker_id`` just before it produces at ``at_tick``
    (its rollout state is lost; the supervisor restarts it immediately)."""
    worker_id: int
    at_tick: int


@dataclass(frozen=True)
class DelayBatch:
    """Hold the batch worker ``worker_id`` produces at ``at_tick`` for
    ``ticks`` additional scheduler ticks before it reaches the learner."""
    worker_id: int
    at_tick: int
    ticks: int


@dataclass(frozen=True)
class FaultPlan:
    events: Tuple = ()

    @staticmethod
    def of(*events) -> "FaultPlan":
        return FaultPlan(events=tuple(events))


class FaultInjector:
    """Stateful view over a ``FaultPlan``: the trainer consults it at
    its deterministic seams; each event fires at most once and every
    applied event is logged (``applied``) so tests can assert the plan
    actually executed, not just that nothing crashed."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending: List = list(plan.events)
        self.applied: List = []

    def _take(self, kind, tick: int, worker_id: int):
        for ev in self._pending:
            if (isinstance(ev, kind) and ev.at_tick == tick
                    and ev.worker_id == worker_id):
                self._pending.remove(ev)
                self.applied.append(ev)
                return ev
        return None

    def should_kill(self, tick: int, worker_id: int) -> bool:
        return self._take(KillWorker, tick, worker_id) is not None

    def delay_ticks(self, tick: int, worker_id: int) -> int:
        ev = self._take(DelayBatch, tick, worker_id)
        return ev.ticks if ev is not None else 0

    @property
    def kills_applied(self) -> int:
        return sum(isinstance(ev, KillWorker) for ev in self.applied)

    @property
    def exhausted(self) -> bool:
        return not self._pending


def torn_save(ckpt_dir, step: int, tree, tear: str = "no-commit",
              metadata=None) -> Path:
    """Simulate a save killed mid-write. Performs a real ``ckpt.save``
    into a scratch directory, then reconstructs the torn layout in
    ``ckpt_dir``:

    - ``"tmp-only"``: the crash hit before the atomic rename —
      ``step_X.tmp`` exists, no final directory.
    - ``"no-commit"``: the final directory exists but the COMMITTED
      sentinel (written last) is missing — e.g. a foreign writer that
      renamed early.
    - ``"truncated"``: COMMITTED missing *and* the array payload is cut
      short — the worst case a hard kill can leave.
    - ``"torn-meta"``: COMMITTED missing *and* ``meta.msgpack`` is cut
      short — the kill landed inside the metadata write itself, so even
      the cheap no-payload readers (``ckpt.read_metadata``) see a
      partial file.

    Returns the torn path. The contract under test: ``ckpt.latest_step``
    must not surface ``step``, ``ckpt.restore`` must fall back to the
    previous committed checkpoint, the explicit-step readers raise
    instead of decoding garbage, and the next successful ``ckpt.save``
    sweeps the debris.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    scratch = ckpt_dir / f".torn_scratch_{step}"
    if scratch.exists():
        shutil.rmtree(scratch)
    ckpt.save(scratch, step, tree, metadata=metadata)
    src = scratch / f"step_{step:09d}"
    (src / "COMMITTED").unlink()
    if tear == "tmp-only":
        dst = ckpt_dir / f"step_{step:09d}.tmp"
    elif tear in ("no-commit", "truncated", "torn-meta"):
        dst = ckpt_dir / f"step_{step:09d}"
    else:
        raise ValueError(f"unknown tear mode: {tear!r}")
    if dst.exists():
        shutil.rmtree(dst)
    shutil.move(str(src), str(dst))
    shutil.rmtree(scratch, ignore_errors=True)
    if tear == "truncated":
        npz = dst / "arrays.npz"
        raw = npz.read_bytes()
        npz.write_bytes(raw[: max(1, len(raw) // 2)])
    elif tear == "torn-meta":
        mp = dst / "meta.msgpack"
        raw = mp.read_bytes()
        mp.write_bytes(raw[: max(1, len(raw) // 2)])
    return dst
