"""Deterministic fault injection for the disaggregated trainer + server.

Fleet RL is only as trustworthy as its behavior under churn, and churn
is miserable to reproduce from real preemptions — so this module makes
faults *first-class, scheduled events*. A ``FaultPlan`` is a literal
list of what goes wrong and when, keyed on a deterministic counter
(the trainer's tick, the server's dispatch/reload index), which means a
faulted run is exactly replayable: the fault-injection tests pin the
behavior under faults (restart streams, staleness drops, torn-save
recovery, rejected reloads) bitwise, not statistically.

Training fault families, matching the seams in
``distributed/actor_learner.py``:

- ``KillWorker(worker_id, at_tick)`` — consulted by the trainer's
  ``before-produce`` seam: the worker's in-memory rollout state is
  discarded and re-initialized from its restart RNG stream (restart
  count increments), modeling a preempted actor process whose
  supervisor restarts it.
- ``DelayBatch(worker_id, at_tick, ticks)`` — the batch produced at
  that tick is held for ``ticks`` scheduler ticks before delivery,
  aging it so it arrives staler than it was produced — the way to drive
  batches past ``max_staleness`` and exercise the drop policy on
  purpose.
- ``torn_save(...)`` — not an event but a harness: reconstructs the
  on-disk layouts a crash mid-``ckpt.save`` can leave behind (tmp-only,
  missing COMMITTED sentinel, truncated array payload) so tests can
  assert the COMMITTED contract holds: ``latest_step`` never surfaces a
  torn checkpoint and ``restore`` falls back to the previous committed
  one.

Serving fault families (PR 10), matching the seams in
``serving/server.py::PolicyServer.serve`` (the overload contract,
docs/ARCHITECTURE.md §8):

- ``SlowDispatch(at_dispatch, extra_s)`` — inflate dispatch
  ``at_dispatch``'s service latency by ``extra_s`` seconds (added to
  the virtual clock, or slept on the wall clock): a GC pause, a
  neighbor stall, a straggling device.
- ``RequestFlood(at_s, duration_s, multiplier)`` — every trace request
  arriving in ``[at_s, at_s + duration_s)`` is duplicated to
  ``multiplier`` copies before replay
  (``serving/request.py::flood_trace``): a deterministic traffic spike
  on top of the open-loop trace.
- ``CorruptCheckpoint(at_reload, mode)`` — the params handed to the
  server's ``at_reload``-th hot-reload attempt are mutated first
  (``corrupt_tree``): the payload a torn/bit-rotted checkpoint would
  deliver, which the reload validation must reject.

``parse_serve_faults`` parses the ``policy_serve --faults`` plan syntax
(``slow:IDX:EXTRA_S``, ``flood:AT_S:DUR_S:MULT``,
``corrupt:IDX[:MODE]``, comma-separated).
"""
from __future__ import annotations

import shutil
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt


@dataclass(frozen=True)
class KillWorker:
    """Kill worker ``worker_id`` just before it produces at ``at_tick``
    (its rollout state is lost; the supervisor restarts it immediately)."""
    worker_id: int
    at_tick: int


@dataclass(frozen=True)
class DelayBatch:
    """Hold the batch worker ``worker_id`` produces at ``at_tick`` for
    ``ticks`` additional scheduler ticks before it reaches the learner."""
    worker_id: int
    at_tick: int
    ticks: int


@dataclass(frozen=True)
class SlowDispatch:
    """Inflate dispatch ``at_dispatch``'s service latency by ``extra_s``
    seconds (virtual clock advance, or a wall-clock sleep) — a GC pause
    or straggler landing on exactly one dispatch, deterministically."""
    at_dispatch: int
    extra_s: float


@dataclass(frozen=True)
class RequestFlood:
    """Duplicate every trace request arriving in ``[at_s, at_s +
    duration_s)`` to ``multiplier`` copies before replay — a
    deterministic traffic spike over a window of the open-loop trace."""
    at_s: float
    duration_s: float
    multiplier: int


@dataclass(frozen=True)
class CorruptCheckpoint:
    """Mutate the params handed to the server's ``at_reload``-th
    hot-reload attempt (``corrupt_tree``), modeling a torn or
    bit-rotted checkpoint payload the reload validation must reject."""
    at_reload: int
    mode: str = "nan"


def corrupt_tree(tree: Any, mode: str = "nan") -> Any:
    """-> ``tree`` with every leaf poisoned: ``"nan"`` fills NaN,
    ``"huge"`` fills +inf (a GEMM of an all-inf weight against a
    mixed-sign input produces ``inf - inf = NaN`` partial sums, so the
    poison survives even saturating activations — a merely-large finite
    fill like 1e30 would be laundered to ±1 by the first ``tanh``).
    Both are caught by the reload canary's finite check; a corruption
    that leaves every activation finite is indistinguishable from a
    valid (if bad) policy by construction, which is why reload
    validation is canary-based, not checksum-based (checksums live one
    layer down, in ``ckpt``'s COMMITTED contract)."""
    fills = {"nan": float("nan"), "huge": float("inf")}
    if mode not in fills:
        raise ValueError(f"unknown corruption mode: {mode!r}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    leaves = [jnp.full_like(leaf, fills[mode]) for leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass(frozen=True)
class FaultPlan:
    events: Tuple = ()

    @staticmethod
    def of(*events) -> "FaultPlan":
        return FaultPlan(events=tuple(events))


def parse_serve_faults(spec: str) -> FaultPlan:
    """Parse the ``policy_serve --faults`` plan syntax: comma-separated
    ``slow:IDX:EXTRA_S`` / ``flood:AT_S:DUR_S:MULT`` /
    ``corrupt:IDX[:MODE]`` events -> a ``FaultPlan``."""
    events: List = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        kind = fields[0]
        try:
            if kind == "slow" and len(fields) == 3:
                events.append(SlowDispatch(at_dispatch=int(fields[1]),
                                           extra_s=float(fields[2])))
            elif kind == "flood" and len(fields) == 4:
                events.append(RequestFlood(at_s=float(fields[1]),
                                           duration_s=float(fields[2]),
                                           multiplier=int(fields[3])))
            elif kind == "corrupt" and len(fields) in (2, 3):
                events.append(CorruptCheckpoint(
                    at_reload=int(fields[1]),
                    mode=fields[2] if len(fields) == 3 else "nan"))
            else:
                raise ValueError
        except ValueError:
            raise ValueError(
                f"bad fault spec {part!r} — expected slow:IDX:EXTRA_S, "
                f"flood:AT_S:DUR_S:MULT, or corrupt:IDX[:MODE]") from None
    return FaultPlan(events=tuple(events))


class FaultInjector:
    """Stateful view over a ``FaultPlan``: the trainer consults it at
    its deterministic seams; each event fires at most once and every
    applied event is logged (``applied``) so tests can assert the plan
    actually executed, not just that nothing crashed."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending: List = list(plan.events)
        self.applied: List = []

    def _take_where(self, kind, pred):
        for ev in self._pending:
            if isinstance(ev, kind) and pred(ev):
                self._pending.remove(ev)
                self.applied.append(ev)
                return ev
        return None

    def _take(self, kind, tick: int, worker_id: int):
        return self._take_where(
            kind, lambda ev: (ev.at_tick == tick
                              and ev.worker_id == worker_id))

    def should_kill(self, tick: int, worker_id: int) -> bool:
        return self._take(KillWorker, tick, worker_id) is not None

    def delay_ticks(self, tick: int, worker_id: int) -> int:
        ev = self._take(DelayBatch, tick, worker_id)
        return ev.ticks if ev is not None else 0

    # ------------------------------------------------- serving seams

    def dispatch_delay_s(self, dispatch_idx: int) -> float:
        """Extra service seconds for dispatch ``dispatch_idx`` (the
        ``SlowDispatch`` seam in ``PolicyServer.serve``); 0.0 when no
        event targets it."""
        ev = self._take_where(SlowDispatch,
                              lambda e: e.at_dispatch == dispatch_idx)
        return ev.extra_s if ev is not None else 0.0

    def take_floods(self) -> List[RequestFlood]:
        """Pop (and log as applied) every pending ``RequestFlood`` —
        the server applies them to the trace before replay starts."""
        evs = [ev for ev in self._pending
               if isinstance(ev, RequestFlood)]
        for ev in evs:
            self._pending.remove(ev)
            self.applied.append(ev)
        return evs

    def corrupt_params(self, reload_idx: int, params: Any) -> Any:
        """The ``CorruptCheckpoint`` seam: mutate the params of the
        ``reload_idx``-th hot-reload attempt when an event targets it,
        pass them through untouched otherwise."""
        ev = self._take_where(CorruptCheckpoint,
                              lambda e: e.at_reload == reload_idx)
        if ev is None:
            return params
        return corrupt_tree(params, mode=ev.mode)

    # --------------------------------------------------- accounting

    @property
    def kills_applied(self) -> int:
        return sum(isinstance(ev, KillWorker) for ev in self.applied)

    @property
    def exhausted(self) -> bool:
        return not self._pending

    def applied_counts(self) -> Dict[str, int]:
        """Applied events per type name — the stats snapshot the chaos
        smoke compares against the plan's literal event counts."""
        out: Dict[str, int] = {}
        for ev in self.applied:
            name = type(ev).__name__
            out[name] = out.get(name, 0) + 1
        return out

    def assert_exhausted(self) -> None:
        """Fail loudly when any planned event never fired. ``exhausted``
        is only meaningful *after* a run — a fault test that forgets to
        check it passes vacuously when the plan's coordinates drift off
        the schedule, which is exactly the silent rot this raises on."""
        if self._pending:
            raise AssertionError(
                f"fault plan not exhausted: {len(self._pending)} event(s) "
                f"never fired: {self._pending!r} "
                f"(applied: {self.applied!r})")


def torn_save(ckpt_dir, step: int, tree, tear: str = "no-commit",
              metadata=None) -> Path:
    """Simulate a save killed mid-write. Performs a real ``ckpt.save``
    into a scratch directory, then reconstructs the torn layout in
    ``ckpt_dir``:

    - ``"tmp-only"``: the crash hit before the atomic rename —
      ``step_X.tmp`` exists, no final directory.
    - ``"no-commit"``: the final directory exists but the COMMITTED
      sentinel (written last) is missing — e.g. a foreign writer that
      renamed early.
    - ``"truncated"``: COMMITTED missing *and* the array payload is cut
      short — the worst case a hard kill can leave.
    - ``"torn-meta"``: COMMITTED missing *and* ``meta.msgpack`` is cut
      short — the kill landed inside the metadata write itself, so even
      the cheap no-payload readers (``ckpt.read_metadata``) see a
      partial file.

    Returns the torn path. The contract under test: ``ckpt.latest_step``
    must not surface ``step``, ``ckpt.restore`` must fall back to the
    previous committed checkpoint, the explicit-step readers raise
    instead of decoding garbage, and the next successful ``ckpt.save``
    sweeps the debris.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    scratch = ckpt_dir / f".torn_scratch_{step}"
    if scratch.exists():
        shutil.rmtree(scratch)
    ckpt.save(scratch, step, tree, metadata=metadata)
    src = scratch / f"step_{step:09d}"
    (src / "COMMITTED").unlink()
    if tear == "tmp-only":
        dst = ckpt_dir / f"step_{step:09d}.tmp"
    elif tear in ("no-commit", "truncated", "torn-meta"):
        dst = ckpt_dir / f"step_{step:09d}"
    else:
        raise ValueError(f"unknown tear mode: {tear!r}")
    if dst.exists():
        shutil.rmtree(dst)
    shutil.move(str(src), str(dst))
    shutil.rmtree(scratch, ignore_errors=True)
    if tear == "truncated":
        npz = dst / "arrays.npz"
        raw = npz.read_bytes()
        npz.write_bytes(raw[: max(1, len(raw) // 2)])
    elif tear == "torn-meta":
        mp = dst / "meta.msgpack"
        raw = mp.read_bytes()
        mp.write_bytes(raw[: max(1, len(raw) // 2)])
    return dst
