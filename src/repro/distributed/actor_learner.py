"""Disaggregated actor/learner PPO: N rollout workers, one learner.

SRL's scaling study (PAPERS.md) shows the throughput ceiling for deep RL
at fleet scale comes from decoupling rollout generation from learning and
tolerating worker churn. This module is that decoupling for the IALS
training stack: each **worker** drives the fused whole-horizon acting
program (``rl/ppo.py::rollout`` over the unified engine — the
``policy_rollout`` kernel route on TPU) and streams trajectory batches,
tagged ``(worker_id, policy_version, rng_position)``, through a bounded
queue into a single **learner** that applies the exact PPO update the
integrated trainer uses (``rl/ppo.py::learner_update_fn`` — shared
verbatim, so the two trainers are bitwise-interchangeable on identical
batches).

Staleness contract (the documented drop policy): a batch acted under
policy version ``p`` arriving when the learner is at version ``v`` has
staleness ``v - p``. Batches with ``staleness <= max_staleness`` are
applied — PPO's clipped ratio ``exp(logp_new - logp_behavior)`` *is* the
importance correction for the version gap (``logp`` in the batch is the
acting policy's) — and anything staler is dropped and counted, never
silently averaged in. ``publish_every`` throttles parameter publication,
which bounds worst-case self-inflicted staleness at
``publish_every - 1 + queue residence``.

Two schedules, one state:

- ``deterministic=True`` (default): workers produce round-robin in the
  learner's thread. The whole run is a pure function of
  ``FleetConfig.seed`` — every key is ``fold_in``-derived from a stream
  *position* (never a split chain), so a run killed at version k and
  resumed from a ``FleetState`` checkpoint replays the **bitwise
  identical** remaining trajectory (tests/test_actor_learner.py pins
  this against an uninterrupted run).
- ``deterministic=False``: free-running worker threads (jax ops release
  the GIL), the throughput mode ``benchmarks/fleet_throughput.py``
  measures. No bitwise claim — arrival order is wall-clock — but the
  same staleness/drop/checkpoint machinery applies.

``FleetState`` is the full RL training state — policy params, optimizer
state, learner version, and per-worker (rollout/env state, RNG stream
position, restart count) — a plain pytree that round-trips through
``checkpoint/ckpt.py`` unchanged. ``resume_fleet`` restores it from the
latest committed checkpoint, *resharding the fleet* when the worker
count changed: learner state always survives, matching workers keep
their exact stream positions, new workers initialize deterministically.

Fault injection (``distributed/fault_injection.py``) hooks in at two
seams: ``before_produce`` (kill/restart a worker — its in-memory rollout
state is lost and re-initialized from its restart stream) and
``delay_batch`` (hold a produced batch for n ticks so it ages past
``max_staleness``). Both are consulted at deterministic points, so a
faulted run is replayable.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.rl import ppo


@dataclass(frozen=True)
class FleetConfig:
    n_workers: int = 2
    queue_size: int = 8        # bounded trajectory queue (backpressure)
    max_staleness: int = 4     # drop batches staler than this many versions
    publish_every: int = 1     # learner updates between param publications
    deterministic: bool = True  # round-robin schedule (bitwise-resumable)
    seed: int = 0


class TrajectoryBatch(NamedTuple):
    worker_id: int
    policy_version: int
    rng_position: int
    batch: Any                 # PPO streams, (T, n_envs, [A,] ...) leaves
    v_last: Any                # bootstrap values from the acting policy


class WorkerState(NamedTuple):
    rs: Any                    # ppo.RolloutState (env + frames + t_in_ep)
    rng_position: jax.Array    # () int32: rollouts produced on this stream
    restarts: jax.Array        # () int32: kill/restart count


class FleetState(NamedTuple):
    params: Any
    opt_state: Any
    version: jax.Array         # () int32: learner updates applied
    tick: jax.Array            # () int32: deterministic scheduler ticks
    workers: Tuple[WorkerState, ...]


class ParamStore:
    """Versioned, lock-protected publication point between the learner
    and the workers (threads in async mode; same-thread reads in
    deterministic mode)."""

    def __init__(self, params, version: int = 0):
        self._lock = threading.Lock()
        self._params = params
        self._version = version

    def publish(self, params, version: int):
        with self._lock:
            self._params, self._version = params, version

    def snapshot(self):
        with self._lock:
            return self._params, self._version


class ActorLearnerTrainer:
    """The disaggregated trainer. ``env`` is anything PPO can act in —
    the fused IALS engine is the intended workload. All randomness
    derives from ``FleetConfig.seed`` via position-based ``fold_in``
    streams (worker w's rollout p, worker w's restart r, learner update
    v), never split chains — that is what makes ``FleetState`` a
    complete description of the run."""

    # fold_in tags for the independent streams
    _LEARNER, _POLICY, _WORKER, _RESTART = 1, 2, 1000, 2000

    def __init__(self, env, cfg: ppo.PPOConfig, fleet: FleetConfig,
                 injector=None):
        self.env = env
        self.cfg = cfg
        self.fleet = fleet
        self.injector = injector
        self._root = jax.random.PRNGKey(fleet.seed)
        self.opt = ppo.make_optimizer(cfg)
        # workers all run the same acting program; no donation — in async
        # mode the ParamStore's snapshot must outlive the learner update
        self._produce = jax.jit(
            lambda params, rs, key: ppo.rollout(env, cfg, params, rs, key))
        self._update = jax.jit(ppo.learner_update_fn(cfg, self.opt))

    # -- RNG streams (positions, not chains) ---------------------------
    def _worker_key(self, w: int, position: int):
        return jax.random.fold_in(
            jax.random.fold_in(self._root, self._WORKER + w), position)

    def _restart_key(self, w: int, restarts: int):
        return jax.random.fold_in(
            jax.random.fold_in(self._root, self._RESTART + w), restarts)

    def _learner_key(self, version: int):
        return jax.random.fold_in(
            jax.random.fold_in(self._root, self._LEARNER), version)

    # -- state construction --------------------------------------------
    def _init_worker(self, w: int, restarts: int = 0) -> WorkerState:
        rs = ppo.init_rollout_state(self.env, self.cfg,
                                    self._restart_key(w, restarts))
        return WorkerState(rs=rs, rng_position=jnp.int32(0),
                           restarts=jnp.int32(restarts))

    def init_state(self) -> FleetState:
        params = ppo.init_policy(
            self.cfg, jax.random.fold_in(self._root, self._POLICY))
        return FleetState(
            params=params, opt_state=self.opt.init(params),
            version=jnp.int32(0), tick=jnp.int32(0),
            workers=tuple(self._init_worker(w)
                          for w in range(self.fleet.n_workers)))

    def state_template(self, n_workers: Optional[int] = None) -> FleetState:
        """A FleetState with ``n_workers`` worker slots (default: this
        fleet's) — the restore target for checkpoints written by a fleet
        of that size."""
        n = self.fleet.n_workers if n_workers is None else n_workers
        params = ppo.init_policy(
            self.cfg, jax.random.fold_in(self._root, self._POLICY))
        return FleetState(
            params=params, opt_state=self.opt.init(params),
            version=jnp.int32(0), tick=jnp.int32(0),
            workers=tuple(self._init_worker(min(w, self.fleet.n_workers - 1)
                                            if self.fleet.n_workers else 0)
                          for w in range(n)))

    # -- the produce step (shared by both schedules) --------------------
    def _produce_one(self, w: int, wstate: WorkerState, params,
                     version: int, tick: int):
        """-> (WorkerState, TrajectoryBatch | None). Consults the
        injector's kill schedule first: a killed worker loses its rollout
        state and restarts from its deterministic restart stream, then
        produces normally (supervisor-with-auto-restart semantics)."""
        if self.injector is not None and self.injector.should_kill(tick, w):
            wstate = self._init_worker(w, int(wstate.restarts) + 1)
        pos = int(wstate.rng_position)
        rs, batch, v_last = self._produce(params, wstate.rs,
                                          self._worker_key(w, pos))
        wstate = wstate._replace(rs=rs, rng_position=jnp.int32(pos + 1))
        return wstate, TrajectoryBatch(worker_id=w, policy_version=version,
                                       rng_position=pos, batch=batch,
                                       v_last=v_last)

    def _apply(self, state: FleetState, item: TrajectoryBatch,
               stats: dict, history: list):
        """Staleness gate + learner update; returns the new FleetState
        (unchanged when the batch is dropped)."""
        version = int(state.version)
        staleness = version - item.policy_version
        if staleness > self.fleet.max_staleness:
            stats["dropped"] += 1
            history.append({"version": version, "worker": item.worker_id,
                            "staleness": staleness, "dropped": True})
            return state
        params, opt_state, metrics = self._update(
            state.params, state.opt_state, item.batch, item.v_last,
            self._learner_key(version))
        stats["updates"] += 1
        history.append({"version": version + 1, "worker": item.worker_id,
                        "staleness": staleness, "dropped": False,
                        "loss": float(metrics["loss"]),
                        "mean_reward": float(metrics["mean_reward"])})
        return state._replace(params=params, opt_state=opt_state,
                              version=jnp.int32(version + 1))

    # -- deterministic (round-robin) schedule ---------------------------
    def _run_deterministic(self, state: FleetState, n_updates: int,
                           should_stop, stats, history):
        target = int(state.version) + n_updates
        workers = list(state.workers)
        store = ParamStore(state.params, int(state.version))
        pending: List[Tuple[int, TrajectoryBatch]] = []  # (due_tick, item)
        # the tick counter lives in FleetState so fault schedules (keyed
        # on global ticks) and resume both see one monotonic clock
        # across run() chunks
        tick = int(state.tick)
        # ticks are bounded: every tick produces one batch and every
        # batch is eventually applied or dropped, so the only slack is
        # drops — cap generously and report if exhausted
        max_ticks = tick + n_updates * (self.fleet.max_staleness + 4) + 16
        while int(state.version) < target and tick < max_ticks:
            if should_stop is not None and should_stop():
                break
            w = tick % self.fleet.n_workers
            params, version = store.snapshot()
            workers[w], item = self._produce_one(w, workers[w], params,
                                                 version, tick)
            stats["produced"] += 1
            delay = (self.injector.delay_ticks(tick, w)
                     if self.injector is not None else 0)
            if delay > 0:
                stats["delayed"] += 1
            pending.append((tick + delay, item))
            # deliver everything due, in FIFO order of due-tick then age
            pending.sort(key=lambda p: p[0])
            while pending and pending[0][0] <= tick \
                    and int(state.version) < target:
                _, due = pending.pop(0)
                state = self._apply(state, due, stats, history)
                if int(state.version) % self.fleet.publish_every == 0:
                    store.publish(state.params, int(state.version))
            tick += 1
        # quiesce: deliver (or drop) anything still in flight so the
        # returned FleetState is a complete description of the run —
        # never a batch left in a queue
        for _, due in sorted(pending, key=lambda p: p[0]):
            if int(state.version) < target:
                state = self._apply(state, due, stats, history)
            else:
                stats["dropped"] += 1   # delayed past the chunk's end
        return state._replace(workers=tuple(workers), tick=jnp.int32(tick))

    # -- async (free-running threads) schedule --------------------------
    def _run_async(self, state: FleetState, n_updates: int, should_stop,
                   stats, history):
        target = int(state.version) + n_updates
        store = ParamStore(state.params, int(state.version))
        q: queue.Queue = queue.Queue(maxsize=self.fleet.queue_size)
        stop = threading.Event()
        workers = list(state.workers)
        wlock = threading.Lock()

        def worker_loop(w: int):
            wstate = workers[w]
            while not stop.is_set():
                params, version = store.snapshot()
                # async "ticks" are per-worker produce counts (= the RNG
                # stream position), so fault plans stay meaningful and
                # resume-stable without a global clock
                wstate, item = self._produce_one(
                    w, wstate, params, version, int(wstate.rng_position))
                with wlock:
                    stats["produced"] += 1
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
            with wlock:
                workers[w] = wstate

        threads = [threading.Thread(target=worker_loop, args=(w,),
                                    daemon=True)
                   for w in range(self.fleet.n_workers)]
        for t in threads:
            t.start()
        try:
            while int(state.version) < target:
                if should_stop is not None and should_stop():
                    break
                try:
                    item = q.get(timeout=0.2)
                except queue.Empty:
                    continue
                state = self._apply(state, item, stats, history)
                if int(state.version) % self.fleet.publish_every == 0:
                    store.publish(state.params, int(state.version))
        finally:
            stop.set()
            try:                     # unblock producers mid-put
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            for t in threads:
                t.join(timeout=5.0)
        return state._replace(workers=tuple(workers))

    def run(self, state: FleetState, n_updates: int, *,
            should_stop: Optional[Callable[[], bool]] = None):
        """Advance the fleet by ``n_updates`` learner updates ->
        (FleetState, info). Returns early when ``should_stop()`` goes
        true (the SIGTERM hook — the caller checkpoints the returned
        state, which is quiescent: no in-flight batches). ``info`` has
        ``history`` (one row per applied/dropped batch) and the fleet
        counters."""
        stats = {"produced": 0, "updates": 0, "dropped": 0, "delayed": 0}
        history: list = []
        t0 = time.perf_counter()
        run = (self._run_deterministic if self.fleet.deterministic
               else self._run_async)
        state = run(state, n_updates, should_stop, stats, history)
        stats["wallclock_s"] = time.perf_counter() - t0
        if self.injector is not None:
            stats["kills"] = self.injector.kills_applied
        return state, {"history": history, **stats}

    # -- checkpoint plumbing -------------------------------------------
    def save_metadata(self, state: FleetState) -> dict:
        return {"n_workers": self.fleet.n_workers,
                "version": int(state.version),
                "tick": int(state.tick),
                "rng_positions": [int(w.rng_position)
                                  for w in state.workers],
                "restarts": [int(w.restarts) for w in state.workers]}


def resume_fleet(ckpt_dir, trainer: ActorLearnerTrainer,
                 extra_template=None):
    """Restore a ``FleetState`` (optionally wrapped with an ``extra``
    pytree — e.g. the simulator's AIP params) from the latest committed
    checkpoint, *resharding the fleet* if the worker count changed:

    - same ``n_workers``: exact restore — every worker resumes at its
      recorded RNG stream position with its exact rollout state (the
      bitwise-resume path);
    - different ``n_workers`` (elastic restart): the learner state
      (params, opt state, version) survives; workers present in the
      checkpoint keep their streams, new workers initialize from their
      deterministic restart streams. No bitwise claim across a resize.

    -> (FleetState, extra, start_version) or (None, None, 0) when no
    committed checkpoint exists.
    """
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return None, None, 0
    meta = ckpt.read_metadata(ckpt_dir, step)
    saved_workers = int(meta.get("n_workers", trainer.fleet.n_workers))
    target = trainer.state_template(saved_workers)
    if extra_template is not None:
        target = {"fleet": target, "extra": extra_template}
    tree, step, _ = ckpt.restore(ckpt_dir, target, step)
    if extra_template is not None:
        state, extra = tree["fleet"], tree["extra"]
    else:
        state, extra = tree, None
    n = trainer.fleet.n_workers
    if saved_workers != n:
        kept = list(state.workers[:n])
        fresh = [trainer._init_worker(w) for w in range(len(kept), n)]
        state = state._replace(workers=tuple(kept + fresh))
    return state, extra, int(state.version)
