"""Fault tolerance: preemption-safe training, stragglers, elastic DP.

Pieces (each unit-tested; the training driver in launch/train.py wires them):

- ``TrainingGuard``: wraps the step loop — periodic + preemption-triggered
  checkpointing (SIGTERM handler), automatic resume from the latest
  committed checkpoint, and crash-loop backoff bookkeeping.
- ``StragglerDetector``: EWMA step-time watchdog. On real multi-host pods a
  straggling host shows up as a slow collective everywhere; the detector
  flags sustained slowdowns so the orchestrator can trigger an elastic
  restart excluding the slow host (the policy hook is ``on_straggler``).
- ``elastic_plan``: given the surviving host set, picks the largest valid
  (data, model) mesh <= survivors and the per-host batch reshard plan;
  restart then resumes from the checkpoint onto the smaller mesh (restore
  reshards — see checkpoint/ckpt.py). Scale-up re-admits hosts the same way.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from repro.checkpoint import ckpt


# ---------------------------------------------------------------------------
# Preemption-safe training loop guard
# ---------------------------------------------------------------------------

class TrainingGuard:
    """Preemption-safe step-loop guard.

    SIGTERM flips ``preempted``; the next ``maybe_save`` then flushes a
    checkpoint and *clears the flag* (a forced save answers the
    preemption — without clearing, every later step would re-save
    forever). The previous SIGTERM handler is **chained**, not replaced:
    whatever the process had installed (another guard, a supervisor's
    handler) still runs. ``uninstall()`` restores the prior handler for
    scoped use; drivers that exit on preemption read ``preempted``
    *before* calling ``maybe_save``."""

    def __init__(self, ckpt_dir: str | Path, *, save_every: int = 100,
                 keep: int = 3, install_signal_handler: bool = True):
        self.ckpt_dir = Path(ckpt_dir)
        self.save_every = save_every
        self.keep = keep
        self.preempted = False
        self._prev_handler = None
        self._installed = False
        if install_signal_handler:
            try:
                self._prev_handler = signal.signal(signal.SIGTERM,
                                                   self._on_sigterm)
                self._installed = True
            except ValueError:
                pass  # not on main thread (tests)

    def _on_sigterm(self, signum, frame):
        self.preempted = True
        if callable(self._prev_handler):
            self._prev_handler(signum, frame)   # chain, don't swallow

    def uninstall(self):
        """Restore the SIGTERM handler this guard displaced."""
        if self._installed:
            signal.signal(signal.SIGTERM,
                          self._prev_handler or signal.SIG_DFL)
            self._installed = False

    def resume_or(self, init_fn: Callable, target=None, shardings=None):
        """-> (state, start_step). Restores the latest committed checkpoint
        if present, else calls ``init_fn()``."""
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return init_fn(), 0
        target = target if target is not None else init_fn()
        state, step, _ = ckpt.restore(self.ckpt_dir, target, step,
                                      shardings=shardings)
        return state, step

    def maybe_save(self, step: int, state, *, force: bool = False,
                   metadata: Optional[Dict] = None) -> bool:
        due = force or self.preempted or \
            (self.save_every > 0 and step > 0 and step % self.save_every == 0)
        if due:
            ckpt.save(self.ckpt_dir, step, state, metadata=metadata,
                      keep=self.keep)
            self.preempted = False  # the forced flush answered the signal
        return due


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

@dataclass
class StragglerDetector:
    """EWMA step-time watchdog: sustained step times above
    ``threshold x EWMA`` for ``patience`` consecutive steps => straggler."""
    threshold: float = 2.0
    alpha: float = 0.05
    patience: int = 5
    warmup: int = 10
    _ewma: float = 0.0
    _n: int = 0
    _over: int = 0
    events: List[Tuple[int, float, float]] = field(default_factory=list)

    def update(self, step: int, step_time_s: float) -> bool:
        """Returns True when a sustained straggle is detected at ``step``."""
        self._n += 1
        if self._n <= self.warmup:
            self._ewma = (step_time_s if self._n == 1 else
                          (1 - self.alpha) * self._ewma
                          + self.alpha * step_time_s)
            return False
        is_slow = step_time_s > self.threshold * self._ewma
        if is_slow:
            self._over += 1
        else:
            self._over = 0
            self._ewma = (1 - self.alpha) * self._ewma \
                + self.alpha * step_time_s
        if self._over >= self.patience:
            self.events.append((step, step_time_s, self._ewma))
            self._over = 0
            return True
        return False


# ---------------------------------------------------------------------------
# Elastic data-parallel resize
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    active_hosts: int
    global_batch: int
    per_host_batch: int
    dropped_hosts: Tuple[int, ...]


def elastic_plan(n_hosts_alive: int, chips_per_host: int, *,
                 model_parallel: int, global_batch: int,
                 pods: int = 1) -> ElasticPlan:
    """Largest valid mesh on the surviving hosts.

    Keeps ``model`` fixed (TP degree is architectural), shrinks ``data`` to
    the largest value such that data*model divides the surviving chips and
    the global batch stays divisible (gradient-accumulation picks up any
    slack). Raises if fewer chips than one model replica.
    """
    chips = n_hosts_alive * chips_per_host
    if chips < model_parallel:
        raise ValueError(
            f"{chips} chips cannot host model_parallel={model_parallel}")
    data = chips // model_parallel
    # batch must divide across data shards; shrink data to a divisor
    while data > 1 and global_batch % data != 0:
        data -= 1
    used_hosts = (data * model_parallel) // chips_per_host
    shape = ((pods, data // pods, model_parallel)
             if pods > 1 and data % pods == 0
             else (data, model_parallel))
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    return ElasticPlan(
        mesh_shape=shape, mesh_axes=axes, active_hosts=used_hosts,
        global_batch=global_batch,
        per_host_batch=global_batch // max(data, 1),
        dropped_hosts=tuple(range(used_hosts, n_hosts_alive)))
