"""Sharding rules: map every parameter / input / cache leaf to a PartitionSpec.

Mesh axes (see launch/mesh.py): ``("pod", "data", "model")`` multi-pod or
``("data", "model")`` single-pod.

- tensor parallelism on ``model``: attention heads, FFN hidden, experts, vocab
- FSDP on ``data``: the d_model-sized dim of each weight (ZeRO-3-style; XLA
  inserts per-layer all-gathers inside the scan-over-layers loop)
- pure DP on ``pod``: params replicated, gradients all-reduced across pods;
  optimizer moments are additionally sharded over ``pod`` where divisible
  (ZeRO-1 across pods)
- batch on ``("pod","data")``; for batch-1 long-context decode the cache
  sequence dim shards over ``data`` instead.

Every rule degrades to replication when a dim is not divisible by the axis
size (e.g. whisper's vocab 51865 on model=16) — correctness first, the
roofline/§Perf loop then attacks what this leaves on the table.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, name) -> Optional[object]:
    if name is None:
        return None
    if isinstance(name, tuple):  # combined axes (fsdp_only profile)
        n = 1
        for a in name:
            if a not in mesh.axis_names:
                return None
            n *= mesh.shape[a]
        if dim % n == 0 and n > 1:
            return name
        # fall back to the first axis alone
        return _fits(dim, mesh, name[0])
    if name in mesh.axis_names and dim % mesh.shape[name] == 0 \
            and mesh.shape[name] > 1:
        return name
    return None


def dp_axes(mesh: Mesh, profile: str = "tp"):
    """Batch axes: ("pod","data") (+"model" in the fsdp_only profile)."""
    names = (("pod", "data", "model") if profile == "fsdp_only"
             else ("pod", "data"))
    return tuple(a for a in names if a in mesh.axis_names)


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1,
               profile: str = "tp") -> P:
    """Spec for (B, ...) activations: shard B over as many dp axes as divide."""
    axes = []
    rem = batch
    for a in dp_axes(mesh, profile):
        if rem % mesh.shape[a] == 0:
            axes.append(a)
            rem //= mesh.shape[a]
    lead = tuple(axes) if axes else None
    return P(lead, *([None] * extra_dims))


# ---------------------------------------------------------------------------
# Parameter rules, keyed on tree-path names
# ---------------------------------------------------------------------------

# (matched path key) -> (dim roles), roles: "fsdp" | "tp" | None per dim,
# for the *unstacked* (per-layer) shape; a stacked leading layer dim gets None.
_RULES = {
    # embeddings / heads: vocab on tp; embed dim NOT fsdp-sharded (a gather
    # from a 2-way-sharded table forces involuntary full remat in GSPMD)
    "table": ("tp", None),
    "lm_head.w": ("fsdp", "tp"),
    # attention
    "wq.w": ("fsdp", "tp"), "wk.w": ("fsdp", "tp"), "wv.w": ("fsdp", "tp"),
    "wq.b": ("tp",), "wk.b": ("tp",), "wv.b": ("tp",),
    "wo.w": ("tp", "fsdp"), "wo.b": (None,),
    # MLA
    "wq_a.w": ("fsdp", "tp"), "wq_b.w": ("fsdp", "tp"),
    "wkv_a.w": ("fsdp", None), "wkv_b.w": ("fsdp", "tp"),
    # MLP
    "w_gate": ("fsdp", "tp"), "w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp"),
    # MoE: experts sharded on E only (pure expert parallelism). FSDP-sharding
    # the d dims too made GSPMD all-reduce the (E, C, d_ff) dispatch
    # activations (346 MB x2 per layer per microbatch measured) instead of
    # all-gathering the 65 MB of local expert weights — see §Perf hillclimb 2.
    "experts.w_gate": ("tp", None, None),
    "experts.w_in": ("tp", None, None),
    "experts.w_out": ("tp", None, None),
    "router": (None, None),
    # mamba
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "conv_w": ("tp", None), "conv_b": ("tp",),
    "x_proj": ("tp", None), "dt_w": (None, "tp"),
    "A_log": ("tp", None), "D": ("tp",),
    # mLSTM / sLSTM (bare (NH, DH, DH) block-diagonal projections)
    "up_proj": ("fsdp", "tp"), "down_proj": ("tp", "fsdp"),
    "wq": (None, "tp", None), "wk": (None, "tp", None),
    "wv": (None, "tp", None),
    "w_if.w": ("tp", None), "w_if.b": (None,),
    "r_z": (None, "tp", None), "r_i": (None, "tp", None),
    "r_f": (None, "tp", None), "r_o": (None, "tp", None),
    "ff_up": ("fsdp", "tp"), "ff_down": ("tp", "fsdp"),
    "w_in.w": ("fsdp", "tp"), "w_in.b": ("tp",),
}

_AXIS_FOR_ROLE = {"fsdp": "data", "tp": "model"}
_AXIS_FOR_ROLE_FSDP_ONLY = {"fsdp": ("data", "model"), "tp": None}

# per-run override: expert-dim axes for "experts.*" leaves ("model" default;
# ("data","model") for 2-D EP — set from ArchConfig.moe_expert_axes)
_EP_AXES = ("model",)


def set_moe_expert_axes(axes: str) -> None:
    global _EP_AXES
    _EP_AXES = ("data", "model") if axes == "data_model" else ("model",)


def _path_names(path) -> list:
    return [str(getattr(k, "key", getattr(k, "name", k))) for k in path]


def param_pspec(path, leaf, mesh: Mesh, *, stacked_under: str = "blocks",
                profile: str = "tp") -> P:
    """Assign a PartitionSpec to one parameter leaf by its tree path."""
    names = _path_names(path)
    # match the most specific rule (all dotted parts present in the path)
    best = None
    for key, roles in _RULES.items():
        parts = key.split(".")
        if all(p in names for p in parts):
            if best is None or len(key) > len(best[0]):
                best = (key, roles)
    shape = leaf.shape
    stacked = "blocks" in names  # decoder + encoder stacks are scan-stacked
    if best is None:
        roles = tuple([None] * (len(shape) - (1 if stacked else 0)))
    else:
        roles = best[1]
    role_map = dict(_AXIS_FOR_ROLE_FSDP_ONLY if profile == "fsdp_only"
                    else _AXIS_FOR_ROLE)
    if best is not None and best[0].startswith("experts."):
        role_map["tp"] = _EP_AXES if len(_EP_AXES) > 1 else _EP_AXES[0]
    specs = []
    offset = 0
    if stacked:
        specs.append(None)  # layer-stack dim
        offset = 1
    for i in range(offset, len(shape)):
        ridx = i - offset
        role = roles[ridx] if ridx < len(roles) else None
        ax = role_map.get(role)
        specs.append(_fits(shape[i], mesh, ax) if ax else None)
    return P(*specs)


def param_specs(abstract_params, mesh: Mesh, profile: str = "tp"):
    """Pytree of PartitionSpec matching an (abstract) param pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, mesh, profile=profile),
        abstract_params)


def opt_state_specs(abstract_opt_state, mesh: Mesh, pspecs):
    """Moments shard like params, then widen over axes the param leaves
    unused ('pod' always — ZeRO-1 across pods; 'data' too, which matters for
    EP-only expert weights whose d dims are unsharded)."""
    def mom(spec_tree):
        def widen(path, leaf):
            base = _lookup(pspecs, path)
            if base is None:
                return P()
            parts = list(base) + [None] * (len(leaf.shape) - len(base))
            used = set()
            for cur in parts:
                for a in (cur if isinstance(cur, tuple) else (cur,)):
                    if a:
                        used.add(a)
            for ax in ("pod", "data"):
                if ax not in mesh.axis_names or ax in used:
                    continue
                for i, (cur, dim) in enumerate(zip(parts, leaf.shape)):
                    if cur is None and dim % mesh.shape[ax] == 0 and dim > 1:
                        parts[i] = ax
                        used.add(ax)
                        break
            return P(*parts)
        return jax.tree_util.tree_map_with_path(widen, spec_tree)

    mu = mom(abstract_opt_state.mu)
    nu = mom(abstract_opt_state.nu)
    return type(abstract_opt_state)(step=P(), mu=mu, nu=nu)


def _lookup(tree, path):
    node = tree
    for k in path:
        key = getattr(k, "key", getattr(k, "name", None))
        if key is None:
            return None
        try:
            node = node[key]
        except (KeyError, TypeError, IndexError):
            return None
    return node if isinstance(node, P) else None


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------

def cache_pspec(path, leaf, mesh: Mesh, batch: int) -> P:
    """KV/state cache leaves. Batch shards over dp axes where divisible;
    KV heads over 'model' where divisible; whatever axes remain unused go to
    the sequence dim (sequence-parallel cache — a 40L MHA kv=20 cache at
    32k x 128 batch is 1.7 TB global; every idle mesh axis matters)."""
    names = _path_names(path)
    stacked = "blocks" in names
    shape = leaf.shape
    specs = [None] * len(shape)
    bdim = 1 if stacked else 0
    used = set()
    # batch across dp axes
    axes = []
    rem = shape[bdim]
    for a in dp_axes(mesh):
        if rem % mesh.shape[a] == 0 and mesh.shape[a] > 1:
            axes.append(a)
            used.add(a)
            rem //= mesh.shape[a]
    if axes:
        specs[bdim] = tuple(axes) if len(axes) > 1 else axes[0]
    # kv heads on model when divisible: (..., S, KH, hd)
    if any(k in ("k", "v", "mk", "mv") for k in names) \
            and len(shape) >= bdim + 3 and "model" not in used:
        kh = shape[bdim + 2]
        if _fits(kh, mesh, "model"):
            specs[bdim + 2] = "model"
            used.add("model")
    # remaining axes -> sequence dim (seq-parallel cache)
    is_seq_cache = any(k in ("k", "v", "ckv", "krope") for k in names)
    if is_seq_cache and len(shape) > bdim + 1:
        seq_axes = []
        rem = shape[bdim + 1]
        for a in ("data", "model"):
            if a in mesh.axis_names and a not in used \
                    and mesh.shape[a] > 1 and rem % mesh.shape[a] == 0:
                seq_axes.append(a)
                used.add(a)
                rem //= mesh.shape[a]
        if seq_axes:
            specs[bdim + 1] = (tuple(seq_axes) if len(seq_axes) > 1
                               else seq_axes[0])
    return P(*specs)


def cache_specs(abstract_cache, mesh: Mesh, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_pspec(path, leaf, mesh, batch),
        abstract_cache)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# IALS partition rules (the unified whole-horizon engine, core/engine.py)
# ---------------------------------------------------------------------------
#
# The engine's state layout is fixed by construction: every state leaf is
# (B, ...) single-agent or (B, A, ...) multi-agent (``_unflat`` guarantees
# the agent axis is dim 1 on every leaf), PPO rollout-state leaves follow
# the same convention (frames (B, [A,] k, d), t_in_ep (B,)), and streamed
# leaves prepend a horizon axis ((T, B, [A,] ...)). The rules:
#
# - env lanes (B) shard over the data-parallel axes ("pod", "data"), plus
#   "model" when the agent axis leaves it idle — rollouts are
#   embarrassingly parallel over lanes, so every divisible mesh axis is a
#   free throughput multiplier;
# - the agent axis (A) and the stacked per-agent AIP weights (leading
#   (A, ...) leaves) co-shard over "model": each device owns its agents'
#   lanes AND those agents' weights, so the per-agent weight indexing at
#   the kernel boundary stays local;
# - PPO policy/optimizer params replicate (pure DP — gradients all-reduce).
#
# Every rule degrades to replication when a dim does not divide its axis
# (A ∈ {25, 36} on a 16-wide "model" axis replicates; A=36 on 2 shards).

IALS_LANE_AXES = ("pod", "data")
IALS_AGENT_AXIS = "model"


def mesh_size(mesh) -> int:
    """Device count of a Mesh (duck-typed: only ``.shape`` consulted)."""
    n = 1
    for v in dict(mesh.shape).values():
        n *= v
    return n


def ials_lane_axes(batch: int, n_agents: int, mesh: Mesh):
    """-> (lane_axes, agent_axis | None): which mesh axes the env-lane dim
    and the agent dim take, with divisibility fallback. The two are
    decided together so lanes can absorb an idle "model" axis."""
    agent_ax = None
    if (n_agents > 1 and IALS_AGENT_AXIS in mesh.axis_names
            and mesh.shape[IALS_AGENT_AXIS] > 1
            and n_agents % mesh.shape[IALS_AGENT_AXIS] == 0):
        agent_ax = IALS_AGENT_AXIS
    lane = []
    rem = batch
    cand = IALS_LANE_AXES + (() if agent_ax else (IALS_AGENT_AXIS,))
    for a in cand:
        if a in mesh.axis_names and mesh.shape[a] > 1 \
                and rem % mesh.shape[a] == 0:
            lane.append(a)
            rem //= mesh.shape[a]
    return tuple(lane), agent_ax


def _lead(axes):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _pspec(specs, ndim) -> P:
    """Pad to ndim, then trim trailing Nones (a fully-replicated leaf is
    the canonical P())."""
    specs = list(specs) + [None] * (ndim - len(specs))
    while specs and specs[-1] is None:
        specs.pop()
    return P(*specs)


def ials_state_pspec(leaf, mesh: Mesh, n_agents: int) -> P:
    """One engine-state / rollout-state leaf -> PartitionSpec. Dim 0 is
    the env-lane (B) dim; dim 1 is the agent dim when the leaf carries it
    (multi-agent leaves have ``shape[1] == n_agents`` by the engine's
    ``_unflat`` layout); everything else replicates."""
    shape = getattr(leaf, "shape", ())
    if len(shape) == 0:
        return P()
    lane, agent_ax = ials_lane_axes(shape[0], n_agents, mesh)
    specs = [_lead(lane)]
    if (n_agents > 1 and len(shape) >= 2 and shape[1] == n_agents
            and agent_ax is not None):
        specs.append(agent_ax)
    return _pspec(specs, len(shape))


def ials_state_specs(state, mesh: Mesh, n_agents: int = 1):
    """PartitionSpec pytree for an engine ``IALSState`` (or a PPO
    ``RolloutState`` — any pytree following the (B, [A,] ...) layout)."""
    return jax.tree_util.tree_map(
        lambda l: ials_state_pspec(l, mesh, n_agents), state)


def ials_stream_pspec(leaf, mesh: Mesh, batch: int, n_agents: int) -> P:
    """A streamed (T, B, [A,] ...) leaf (actions, Gumbel noise, bulk env
    noise, T-stacked reset states): time replicated, then the state rule
    shifted one dim right."""
    shape = getattr(leaf, "shape", ())
    if len(shape) <= 1:
        return P()
    lane, agent_ax = ials_lane_axes(batch, n_agents, mesh)
    specs = [None, _lead(lane) if shape[1] == batch else None]
    if (n_agents > 1 and len(shape) >= 3 and shape[2] == n_agents
            and agent_ax is not None and shape[1] == batch):
        specs.append(agent_ax)
    return _pspec(specs, len(shape))


def ials_stream_specs(tree, mesh: Mesh, batch: int, n_agents: int = 1):
    return jax.tree_util.tree_map(
        lambda l: ials_stream_pspec(l, mesh, batch, n_agents), tree)


def ials_aip_param_specs(params, mesh: Mesh, n_agents: int = 1,
                         batch: int = 0):
    """Stacked per-agent AIP weights co-shard with the agent axis: each
    (A, ...) leaf puts A on the same axis the state's agent dim took
    (replicated when A does not divide). Single-agent AIPs replicate."""
    _, agent_ax = ials_lane_axes(batch or 1, n_agents, mesh)

    def spec(leaf):
        shape = getattr(leaf, "shape", ())
        if (n_agents > 1 and len(shape) >= 1 and shape[0] == n_agents
                and agent_ax is not None):
            return _pspec([agent_ax], len(shape))
        return P()

    return jax.tree_util.tree_map(spec, params)


def ials_replicated_specs(params):
    """PPO policy / optimizer params: replicated everywhere (pure DP)."""
    return jax.tree_util.tree_map(lambda _: P(), params)


def constrain_ials_state(state, mesh: Mesh, n_agents: int = 1):
    """``with_sharding_constraint`` an engine/rollout state onto the IALS
    rules — a no-op on a trivial (size-1) mesh, so the single-device
    program is bitwise-unchanged."""
    if mesh is None or mesh_size(mesh) == 1:
        return state
    return jax.tree_util.tree_map(
        lambda l: jax.lax.with_sharding_constraint(
            l, NamedSharding(mesh, ials_state_pspec(l, mesh, n_agents))),
        state)


def shard_ials_state(state, mesh: Mesh, n_agents: int = 1):
    """``device_put`` an already-materialized state across the mesh (the
    eager-side twin of ``constrain_ials_state``)."""
    if mesh is None or mesh_size(mesh) == 1:
        return state
    return jax.tree_util.tree_map(
        lambda l: jax.device_put(
            l, NamedSharding(mesh, ials_state_pspec(l, mesh, n_agents))),
        state)
