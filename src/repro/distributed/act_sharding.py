"""Activation sharding constraints.

Without explicit constraints GSPMD happily replicates (B, T, d) activations
and all-reduces partial sums the size of the *logits* (measured: 435 GB/step
on whisper train_4k before this module existed — see EXPERIMENTS.md §Perf).
Model code calls ``constrain(x, "dp", None, None)`` at block boundaries; the
launcher installs the mesh via ``use_mesh`` before tracing. A no-op when no
mesh is installed (pure-CPU smoke tests).

Roles: "dp" -> batch axes ("pod","data"), "tp" -> "model", "fsdp" -> "data".
Dims that don't divide their axis fall back to unconstrained.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_PROFILE: str = "tp"


def set_mesh(mesh: Optional[Mesh], profile: str = "tp") -> None:
    global _MESH, _PROFILE
    _MESH = mesh
    _PROFILE = profile


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh, profile: str = "tp"):
    prev, prev_p = _MESH, _PROFILE
    set_mesh(mesh, profile)
    try:
        yield
    finally:
        set_mesh(prev, prev_p)


def _role_axes(role: Optional[str]) -> Tuple[str, ...]:
    if role == "dp":
        names = (("pod", "data", "model") if _PROFILE == "fsdp_only"
                 else ("pod", "data"))
        return tuple(a for a in names if a in _MESH.axis_names)
    if role == "tp":
        if _PROFILE == "fsdp_only":  # the model axis serves as DP/FSDP
            return ()
        return ("model",) if "model" in _MESH.axis_names else ()
    if role == "fsdp":
        return ("data",) if "data" in _MESH.axis_names else ()
    return ()


def constrain(x: jax.Array, *roles) -> jax.Array:
    """roles: one of "dp"|"tp"|"fsdp"|None per dim of x."""
    if _MESH is None:
        return x
    spec = []
    used = set()
    for dim, role in zip(x.shape, roles):
        axes = _role_axes(role)
        picked = []
        rem = dim
        for a in axes:
            n = _MESH.shape[a]
            if n > 1 and rem % n == 0 and a not in used:
                picked.append(a)
                used.add(a)
                rem //= n
        spec.append(tuple(picked) if len(picked) > 1
                    else (picked[0] if picked else None))
    return lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))
