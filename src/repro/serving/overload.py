"""Overload hardening: admission control, load shedding, brownout.

The schedulers in ``scheduler.py`` are deliberately drop-free — every
admitted request is dispatched, and a missed deadline is *recorded*,
never used to shed load. That is the right contract for the scheduler's
own accounting, but it means a server run past saturation admits
everything, the queue grows without bound, and misses pile up silently:
past ~1x capacity, *every* class's latency collapses together. This
module is the policy layer on top — the overload contract of
docs/ARCHITECTURE.md §8 — which turns silent misses into explicit,
counted sheds at admit time and degrades the service gracefully instead
of collapsing it:

- ``DispatchLatencyModel`` — an EWMA of *measured* per-shape dispatch
  latency, the server's own service-time estimate (seeded with a
  configured default until the first dispatch of a shape lands).
- ``AdmissionController`` — three admit-time gates, in order: a
  **bounded queue** (``queue_cap`` pending requests; beyond it the
  server is saturated by definition and the request is shed), a
  **brownout shed** (below), and **deadline feasibility**: with ``P``
  requests pending and the drain running full slots of shape ``S``, a
  new request completes no earlier than
  ``now + (P // S + 1) * ewma(S) * slack`` — if that is already past
  its absolute deadline, admitting it can only burn capacity on a
  guaranteed miss, so it is rejected at the door. Every shed is counted
  (``ServeStats.rejected`` / ``rejected_by_reason`` / ``shed_by_class``)
  — explicit rejections replace silent deadline misses.
- ``BrownoutController`` — graceful degradation with hysteresis. The
  backlog estimate is observed at every admit and dispatch; after
  ``hold`` consecutive observations above ``enter_s`` the brownout
  level rises, and only after ``hold`` consecutive observations below
  ``exit_s`` (< ``enter_s``: the hysteresis band prevents flapping)
  does it fall. Level k sheds the k *loosest* deadline classes — the
  bulk traffic with the most slack is degraded first so the queue stays
  short enough for latency-sensitive classes to remain feasible; the
  tightest class is never shed by brownout. At ``max_level`` the
  controller also collapses a bucketed scheduler to its coarsest shape
  (``BucketedSlotScheduler.set_coarse``): under sustained overload
  batches are large anyway, and one big program amortises per-dispatch
  overhead. Recovery undoes both as the backlog drains.

The controller is deliberately stateful-but-replayable: its decisions
are a pure function of the observed request/latency sequence, so a
``mode="virtual"`` replay (fixed service time per dispatch) makes every
admission decision deterministic — the property the overload tests and
the ``benchmarks/serve_throughput.py`` overload sweep pin.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class OverloadConfig:
    """Admission + brownout policy knobs (see the module docstring).

    ``queue_cap`` bounds pending requests; ``default_latency_s`` seeds
    the per-shape EWMA before the first dispatch lands (match it to the
    virtual-mode ``service_time_s`` for exact replays); ``slack`` > 1
    makes the feasibility estimate more conservative. Brownout enters a
    level after ``brownout_hold`` consecutive backlog observations above
    ``brownout_enter_s`` and exits after as many below
    ``brownout_exit_s`` — the gap is the hysteresis band."""
    queue_cap: int = 8192
    ewma_alpha: float = 0.25
    default_latency_s: float = 1e-3
    slack: float = 1.0
    feasibility: bool = True
    brownout: bool = True
    brownout_enter_s: float = 0.05
    brownout_exit_s: float = 0.02
    brownout_hold: int = 3
    max_level: int = 2
    coarse_in_brownout: bool = True

    def __post_init__(self):
        if self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")
        if self.brownout_exit_s >= self.brownout_enter_s:
            raise ValueError(
                f"hysteresis needs brownout_exit_s < brownout_enter_s, got "
                f"exit {self.brownout_exit_s} >= enter "
                f"{self.brownout_enter_s}")
        if self.brownout_hold < 1:
            raise ValueError(f"brownout_hold must be >= 1, got "
                             f"{self.brownout_hold}")
        if self.max_level < 1:
            raise ValueError(f"max_level must be >= 1, got {self.max_level}")


class DispatchLatencyModel:
    """EWMA of measured per-shape dispatch latency — the admission
    controller's service-time estimate. One EWMA per slot shape (XLA
    programs are per-shape, so their latencies are too); a shape that
    has never dispatched estimates ``default_s``."""

    def __init__(self, alpha: float = 0.25, default_s: float = 1e-3):
        self.alpha = alpha
        self.default_s = default_s
        self._ewma: Dict[int, float] = {}

    def observe(self, shape: int, seconds: float) -> None:
        prev = self._ewma.get(shape)
        self._ewma[shape] = (seconds if prev is None else
                             (1 - self.alpha) * prev + self.alpha * seconds)

    def estimate(self, shape: int) -> float:
        got = self._ewma.get(shape)
        if got is not None:
            return got
        # nearest observed shape is a better guess than the cold default
        if self._ewma:
            near = min(self._ewma, key=lambda s: abs(s - shape))
            return self._ewma[near]
        return self.default_s


class BrownoutController:
    """Degradation level with hysteresis (0 = normal service).

    ``observe(backlog_s)`` drives a small state machine: ``hold``
    consecutive observations above ``enter_s`` raise the level (up to
    ``max_level``), ``hold`` consecutive below ``exit_s`` lower it;
    observations inside the hysteresis band reset both streaks, holding
    the current level. ``entries``/``exits`` count transitions (the
    chaos harness asserts the controller actually cycled)."""

    def __init__(self, cfg: OverloadConfig):
        self.cfg = cfg
        self.level = 0
        self.entries = 0
        self.exits = 0
        self._over = 0
        self._under = 0

    def observe(self, backlog_s: float) -> int:
        cfg = self.cfg
        if backlog_s > cfg.brownout_enter_s:
            self._over += 1
            self._under = 0
            if self._over >= cfg.brownout_hold and self.level < cfg.max_level:
                self.level += 1
                self.entries += 1
                self._over = 0
        elif backlog_s < cfg.brownout_exit_s:
            self._under += 1
            self._over = 0
            if self._under >= cfg.brownout_hold and self.level > 0:
                self.level -= 1
                self.exits += 1
                self._under = 0
        else:                       # inside the band: hold the level
            self._over = 0
            self._under = 0
        return self.level


class AdmissionController:
    """Admit-or-shed policy in front of a ``SlotScheduler``.

    ``admit(req, now, sched, stats)`` either enqueues ``req`` on
    ``sched`` and returns True, or records one counted rejection on
    ``stats`` (reason ∈ {``queue_full``, ``brownout``, ``infeasible``})
    and returns False. ``observe_dispatch(shape, seconds, sched)``
    feeds the latency EWMA + brownout after every dispatch. The
    controller owns no per-replay counters — those live in the
    ``ServeStats`` of the serve call — so one controller can persist
    across replays (its latency model and brownout state carry over,
    like a long-running server's would).

    Deadline-class bounds are *learned* from the requests themselves
    (``deadline - arrival``), so the controller needs no trace config;
    brownout level k sheds the k loosest learned classes, never the
    tightest."""

    def __init__(self, cfg: Optional[OverloadConfig] = None):
        self.cfg = cfg if cfg is not None else OverloadConfig()
        self.latency = DispatchLatencyModel(self.cfg.ewma_alpha,
                                            self.cfg.default_latency_s)
        self.brownout = BrownoutController(self.cfg)
        self._class_bound: Dict[int, float] = {}

    def backlog_s(self, sched) -> float:
        """Estimated time to drain ``sched``'s pending queue at full
        slots of the scheduler's largest shape."""
        slot = sched.slot
        est = self.latency.estimate(slot) * self.cfg.slack
        return -(-sched.pending // slot) * est if sched.pending else 0.0

    def shed_classes(self) -> Tuple[int, ...]:
        """Classes the current brownout level sheds: the ``level``
        loosest learned deadline classes — never all of them (the
        tightest class always stays admissible)."""
        level = self.brownout.level
        if level == 0 or len(self._class_bound) < 2:
            return ()
        ranked = sorted(self._class_bound,
                        key=lambda k: (-self._class_bound[k], k))
        return tuple(ranked[:min(level, len(ranked) - 1)])

    def _sync_coarse(self, sched) -> None:
        if self.cfg.coarse_in_brownout and hasattr(sched, "set_coarse"):
            sched.set_coarse(self.brownout.level >= self.cfg.max_level)

    def admit(self, req, now: float, sched, stats) -> bool:
        """One admit-or-shed decision (see the class docstring)."""
        bound = req.deadline - req.arrival
        prev = self._class_bound.get(req.klass)
        if prev is None or bound > prev:
            self._class_bound[req.klass] = bound
        backlog = self.backlog_s(sched)
        if self.cfg.brownout:
            self.brownout.observe(backlog)
            self._sync_coarse(sched)
        reason = None
        if sched.pending >= self.cfg.queue_cap:
            reason = "queue_full"
        elif self.cfg.brownout and req.klass in self.shed_classes():
            reason = "brownout"
        elif self.cfg.feasibility:
            # with P pending draining in full slots of shape S, this
            # request rides dispatch P // S (0-indexed from the next
            # one) and completes no earlier than (P // S + 1) slots out
            est = self.latency.estimate(sched.slot) * self.cfg.slack
            eta = now + (sched.pending // sched.slot + 1) * est
            if eta > req.deadline:
                reason = "infeasible"
        if reason is not None:
            stats.record_rejection(reason, req.klass)
            return False
        sched.admit(req)
        return True

    def observe_dispatch(self, shape: int, seconds: float, sched) -> None:
        """Feed one measured dispatch back into the latency EWMA and the
        brownout state machine (recovery happens here: draining backlog
        is only observable when dispatches complete)."""
        self.latency.observe(shape, seconds)
        if self.cfg.brownout:
            self.brownout.observe(self.backlog_s(sched))
            self._sync_coarse(sched)
