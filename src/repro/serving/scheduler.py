"""Continuous-batching slot scheduler: EDF, FIFO-in-class, no silent drops.

``SlotScheduler`` owns the in-flight request queue between trace replay
and the fixed-slot policy forward. Its guarantees (the serving contract,
docs/ARCHITECTURE.md §8 — each is pinned by a property test in
``tests/test_serving.py``):

1. **No silent drops.** Every admitted request is dispatched exactly
   once: ``next_batch`` pops at most ``slot`` requests and never
   discards; a missed deadline is *recorded*, never used to shed load.
   (Load shedding would be a policy choice layered on top — the
   scheduler's own accounting must stay exact either way.)
2. **EDF across classes, FIFO within a class.** The queue is a heap on
   ``(deadline, seq)`` with ``seq`` the admission order. Deadlines are
   absolute (``arrival + class bound``), so within one class deadline
   order IS arrival order — earliest-deadline-first gives FIFO per class
   for free, and the ``seq`` tiebreak makes equal-deadline pops
   deterministic and admission-ordered.
3. **No starvation.** A pending request's deadline is fixed while every
   later arrival's deadline grows with its arrival time, so any waiting
   request becomes the queue minimum after boundedly many admissions —
   EDF on absolute deadlines cannot strand it.
4. **Exact miss accounting.** ``complete`` compares each request's
   completion time against its absolute deadline; ``deadline_misses`` /
   ``misses_by_class`` equal a ground-truth recount of the completion
   log on any adversarial trace, by construction and by test.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

from repro.serving.request import Request


class SlotScheduler:
    """Packs in-flight requests into fixed-``slot``-size batches.

    Call pattern (the server's loop): ``admit`` requests in arrival
    order, ``next_batch`` to pop up to ``slot`` of them
    (earliest-deadline-first), run the forward, then ``complete(batch,
    t_done)`` with the batch's shared completion time. ``completions``
    is the full audit log ``(rid, klass, arrival, deadline, t_done)``
    the miss counters are derivable from."""

    def __init__(self, slot: int):
        if slot < 1:
            raise ValueError(f"slot must be >= 1, got {slot}")
        self.slot = slot
        self._heap: List[Tuple[float, int, Request]] = []
        self._seq = 0
        self.admitted = 0
        self.served = 0
        self.deadline_misses = 0
        self.misses_by_class: Dict[int, int] = {}
        self.max_queue_depth = 0
        self.completions: List[Tuple[int, int, float, float, float]] = []

    @property
    def pending(self) -> int:
        return len(self._heap)

    def admit(self, req: Request) -> None:
        """Enqueue one request. Admission order is the FIFO tiebreak, so
        callers must admit in arrival order (trace replay does)."""
        heapq.heappush(self._heap, (req.deadline, self._seq, req))
        self._seq += 1
        self.admitted += 1
        self.max_queue_depth = max(self.max_queue_depth, len(self._heap))

    def next_batch(self) -> List[Request]:
        """Pop up to ``slot`` requests, earliest absolute deadline first
        (admission order among equal deadlines). Never discards: what is
        not popped stays queued for the next batch."""
        n = min(self.slot, len(self._heap))
        return [heapq.heappop(self._heap)[2] for _ in range(n)]

    def complete(self, batch: List[Request], t_done: float) -> None:
        """Record a dispatched batch finishing at ``t_done`` (seconds on
        the trace clock). All requests in one slot share the completion
        time — the whole slot returns from one fused dispatch."""
        for req in batch:
            self.served += 1
            self.completions.append(
                (req.rid, req.klass, req.arrival, req.deadline, t_done))
            if t_done > req.deadline:
                self.deadline_misses += 1
                self.misses_by_class[req.klass] = (
                    self.misses_by_class.get(req.klass, 0) + 1)
