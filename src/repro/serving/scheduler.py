"""Continuous-batching slot schedulers: EDF, FIFO-in-class, no drops.

``SlotScheduler`` owns the in-flight request queue between trace replay
and the fixed-slot policy forward; ``BucketedSlotScheduler`` extends it
with a small set of compiled slot *shapes* (buckets) so a lightly
filled batch dispatches in a right-sized program instead of one big
mostly-padded slot, and ``calibrate_buckets`` picks the shape set
offline from a trace's burst-size distribution. Their guarantees (the
serving contract, docs/ARCHITECTURE.md §8 — each is pinned by a
property test in ``tests/test_serving.py``):

1. **No silent drops.** Every admitted request is dispatched exactly
   once: ``next_batch`` pops at most ``slot`` requests and never
   discards; a missed deadline is *recorded*, never used to shed load.
   (Load shedding would be a policy choice layered on top — the
   scheduler's own accounting must stay exact either way.)
2. **EDF across classes, FIFO within a class.** The queue is a heap on
   ``(deadline, seq)`` with ``seq`` the admission order. Deadlines are
   absolute (``arrival + class bound``), so within one class deadline
   order IS arrival order — earliest-deadline-first gives FIFO per class
   for free, and the ``seq`` tiebreak makes equal-deadline pops
   deterministic and admission-ordered.
3. **No starvation.** A pending request's deadline is fixed while every
   later arrival's deadline grows with its arrival time, so any waiting
   request becomes the queue minimum after boundedly many admissions —
   EDF on absolute deadlines cannot strand it.
4. **Exact miss accounting.** ``complete`` compares each request's
   completion time against its absolute deadline; ``deadline_misses`` /
   ``misses_by_class`` equal a ground-truth recount of the completion
   log on any adversarial trace, by construction and by test.
5. **Smallest admissible bucket** (``BucketedSlotScheduler`` only).
   Admission assigns every request the smallest bucket whose slot shape
   admits its region burst (``bucket_for``), and every dispatch runs in
   the smallest bucket shape that admits its popped batch — so
   per-dispatch padding is bounded by the bucket granularity instead of
   by the one compiled slot shape, while guarantees 1-4 hold unchanged
   (one global EDF heap underneath; the buckets partition *shapes*, not
   the queue order).
"""
from __future__ import annotations

import bisect
import heapq
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.serving.request import Request


class SlotScheduler:
    """Packs in-flight requests into fixed-``slot``-size batches.

    Call pattern (the server's loop): ``admit`` requests in arrival
    order, ``next_batch`` to pop up to ``slot`` of them
    (earliest-deadline-first), run the forward, then ``complete(batch,
    t_done)`` with the batch's shared completion time. ``completions``
    is the full audit log ``(rid, klass, arrival, deadline, t_done)``
    the miss counters are derivable from."""

    def __init__(self, slot: int):
        if slot < 1:
            raise ValueError(f"slot must be >= 1, got {slot}")
        self.slot = slot
        self._heap: List[Tuple[float, int, Request]] = []
        self._seq = 0
        self.admitted = 0
        self.served = 0
        self.deadline_misses = 0
        self.misses_by_class: Dict[int, int] = {}
        self.max_queue_depth = 0
        self.completions: List[Tuple[int, int, float, float, float]] = []

    @property
    def pending(self) -> int:
        return len(self._heap)

    def admit(self, req: Request) -> None:
        """Enqueue one request. Admission order is the FIFO tiebreak, so
        callers must admit in arrival order (trace replay does)."""
        heapq.heappush(self._heap, (req.deadline, self._seq, req))
        self._seq += 1
        self.admitted += 1
        self.max_queue_depth = max(self.max_queue_depth, len(self._heap))

    def next_batch(self) -> List[Request]:
        """Pop up to ``slot`` requests, earliest absolute deadline first
        (admission order among equal deadlines). Never discards: what is
        not popped stays queued for the next batch."""
        n = min(self.slot, len(self._heap))
        return [heapq.heappop(self._heap)[2] for _ in range(n)]

    def next_dispatch(self) -> Tuple[int, List[Request]]:
        """-> (slot shape to dispatch at, popped batch) — the server's
        uniform drain interface. The fixed-slot scheduler always answers
        with its one compiled shape; the bucketed scheduler right-sizes
        it per batch."""
        return self.slot, self.next_batch()

    def complete(self, batch: List[Request], t_done: float) -> None:
        """Record a dispatched batch finishing at ``t_done`` (seconds on
        the trace clock). All requests in one slot share the completion
        time — the whole slot returns from one fused dispatch."""
        for req in batch:
            self.served += 1
            self.completions.append(
                (req.rid, req.klass, req.arrival, req.deadline, t_done))
            if t_done > req.deadline:
                self.deadline_misses += 1
                self.misses_by_class[req.klass] = (
                    self.misses_by_class.get(req.klass, 0) + 1)


class BucketedSlotScheduler(SlotScheduler):
    """``SlotScheduler`` over a small set of compiled slot shapes.

    ``buckets`` is the ascending shape set (e.g. ``(16, 64, 256)``) —
    each is one compiled ``serve_forward`` program the server warms at
    startup, so the bucket count is the compiled-programs budget the
    offline ``calibrate_buckets`` pass optimises under.

    Two rules, both pinned by property tests:

    - **Admission** tags every request with its *admissible bucket*: the
      smallest bucket whose shape covers the request's region burst
      (``bucket_for(req.size)``; a burst larger than the largest bucket
      rides the largest, split across dispatches — the same splitting a
      single-slot server does). ``admitted_by_bucket`` counts them.
    - **Dispatch** (``next_dispatch``) pops the EDF batch exactly as the
      base scheduler would at slot = max bucket, then runs it in the
      smallest bucket shape that admits the popped count — under light
      load a 3-lane batch dispatches in the small shape instead of a
      mostly-padded big one (the padded-lane waste the bimodal bench
      row measures), and under queue pressure the batch grows until it
      right-sizes into the biggest program, so saturated throughput is
      never worse than the single-slot server's.

    Everything else — EDF/FIFO-in-class order, no-drop, exact miss
    accounting — is inherited unchanged: the buckets partition the
    *shape* a batch runs at, never the order requests pop in.
    """

    def __init__(self, buckets: Sequence[int]):
        shapes = sorted(set(int(b) for b in buckets))
        if not shapes or shapes[0] < 1:
            raise ValueError(f"buckets must be >= 1, got {buckets!r}")
        super().__init__(shapes[-1])
        self.buckets: Tuple[int, ...] = tuple(shapes)
        self.coarse = False
        self.admitted_by_bucket: Dict[int, int] = {b: 0 for b in shapes}
        self.dispatches_by_bucket: Dict[int, int] = {b: 0 for b in shapes}

    def set_coarse(self, coarse: bool) -> None:
        """Brownout collapse (the overload contract, ARCHITECTURE §8):
        while ``coarse`` is set every dispatch runs at the largest
        bucket shape — under sustained overload batches are near-full
        anyway, and one big program amortises per-dispatch overhead.
        Pop order, no-drop, and miss accounting are untouched (this
        only coarsens the *shape* a popped batch runs at); the
        admission-side brownout controller toggles it both ways."""
        self.coarse = bool(coarse)

    def bucket_for(self, size: int) -> int:
        """-> the smallest bucket shape >= ``size`` (the burst's
        admissible bucket); the largest bucket when no shape covers it
        (the burst is split across dispatches of that shape)."""
        i = bisect.bisect_left(self.buckets, size)
        return self.buckets[min(i, len(self.buckets) - 1)]

    def admit(self, req: Request) -> None:
        super().admit(req)
        self.admitted_by_bucket[self.bucket_for(req.size)] += 1

    def next_dispatch(self) -> Tuple[int, List[Request]]:
        """Pop the EDF batch (up to max-bucket lanes) and right-size it:
        the dispatch shape is the smallest bucket admitting the batch —
        or the largest bucket while the brownout collapse
        (``set_coarse``) is active."""
        batch = self.next_batch()
        shape = self.slot if self.coarse else self.bucket_for(len(batch))
        self.dispatches_by_bucket[shape] += 1
        return shape, batch


# ---------------------------------------------------------------------
# Offline bucket calibration: shapes from a trace's size distribution
# ---------------------------------------------------------------------

def burst_sizes(trace: Iterable[Request]) -> List[int]:
    """-> one entry per region burst in ``trace`` (a size-k burst is k
    requests sharing one (region, arrival); each contributes its size
    once) — the empirical size distribution ``calibrate_buckets``
    optimises over."""
    seen = set()
    out = []
    for req in trace:
        key = (req.region, req.arrival)
        if key not in seen:
            seen.add(key)
            out.append(max(1, int(req.size)))
    return out


def expected_padded_waste(sizes: Sequence[int], buckets: Sequence[int],
                          *, max_slot: int = 256) -> int:
    """Total padded lanes when each burst dispatches alone in its
    admissible bucket (bursts beyond ``max_slot`` split into full
    chunks first) — the calibration objective, also the tests' ground
    truth for the monotonicity property. A *lower bound* of zero queue
    pressure: co-queued bursts that share a dispatch only reduce waste
    further."""
    shapes = sorted(set(buckets))
    waste = 0
    for s0 in sizes:
        s0 = int(s0)
        chunks = []
        while s0 > max_slot:           # same decomposition as calibration
            chunks.append(max_slot)
            s0 -= max_slot
        if s0:
            chunks.append(s0)
        for s in chunks:
            i = bisect.bisect_left(shapes, s)
            b = shapes[min(i, len(shapes) - 1)]
            # ceil-division split for chunks above the largest bucket
            n_disp = -(-s // b)
            waste += n_disp * b - s
    return waste


def calibrate_buckets(trace: Iterable[Request], max_buckets: int = 3, *,
                      min_slot: int = 16,
                      max_slot: int = 256) -> Tuple[int, ...]:
    """Pick <= ``max_buckets`` slot shapes minimising expected
    padded-lane waste over ``trace``'s burst-size distribution.

    The model: a burst of size s dispatches alone in the smallest chosen
    bucket >= s (bursts above ``max_slot`` split into ``max_slot``
    chunks first), wasting (bucket - s) padded lanes. Candidate shapes
    are the observed burst sizes clamped to [``min_slot``,
    ``max_slot``] — any other value is dominated by rounding down to
    the largest size it covers; ``min_slot`` floors the shapes because
    below it per-dispatch overhead, not padded FLOPs, dominates (the
    same reason the serve bench quotes dispatch rate). The largest
    candidate is always chosen (every burst must be admissible), and
    the optimum is exact by an O(n^2 k) partition DP — so adding a
    bucket to the budget can never increase the optimal waste (the
    property test's monotonicity claim).
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    if min_slot > max_slot:
        raise ValueError(f"min_slot {min_slot} > max_slot {max_slot}")
    sizes = burst_sizes(trace)
    if not sizes:
        return (min_slot,)
    # decompose oversize bursts into full chunks + remainder, then clamp
    eff: List[int] = []
    for s in sizes:
        while s > max_slot:
            eff.append(max_slot)
            s -= max_slot
        if s:
            eff.append(s)
    counts: Dict[int, int] = {}
    for e in eff:
        counts[e] = counts.get(e, 0) + 1
    cands = sorted({min(max(e, min_slot), max_slot) for e in counts})
    sizes_sorted = sorted(counts)
    m = len(cands)
    k = min(max_buckets, m)

    def seg_cost(lo_cand: int, cand: int) -> int:
        """Waste of covering every size in (lo_cand, cand] with
        ``cand`` (lo_cand = 0 for the first chosen bucket)."""
        return sum(counts[e] * (cand - e) for e in sizes_sorted
                   if lo_cand < e <= cand)

    INF = float("inf")
    # best[j][b]: min waste covering sizes <= cands[j] with b buckets,
    # cands[j] chosen; parent pointers reconstruct the shape set
    best = [[INF] * (k + 1) for _ in range(m)]
    parent = [[None] * (k + 1) for _ in range(m)]
    for j in range(m):
        best[j][1] = seg_cost(0, cands[j])
        for b in range(2, k + 1):
            for i in range(j):
                if best[i][b - 1] is INF:
                    continue
                cost = best[i][b - 1] + seg_cost(cands[i], cands[j])
                if cost < best[j][b]:
                    best[j][b] = cost
                    parent[j][b] = i
    b_opt = min(range(1, k + 1), key=lambda b: best[m - 1][b])
    chosen = [cands[m - 1]]
    j, b = m - 1, b_opt
    while parent[j][b] is not None:
        j, b = parent[j][b], b - 1
        chosen.append(cands[j])
    return tuple(sorted(chosen))
