"""PolicyServer: fixed-slot continuous-batching policy inference.

One server = one trained policy + ONE jitted slot program. Every
dispatch runs ``kernels/ops.py::serve_forward`` on a packed
(slot, frame_dim) batch with a lane-validity mask — pad lanes are zeroed
inside the dispatch (the ragged-batch contract, ``envs/api.py``), and
actions are the greedy ``argmax`` over the masked logits, exactly the
deployment policy ``rl/ppo.py::make_evaluator`` measures.

Reproducibility contract (docs/ARCHITECTURE.md §8): the slot shape is
static per server, and the forward always runs as the same jitted
program — XLA's GEMM reduction order is shape- and program-dependent, so
the *compiled fixed-slot program* is the unit of bitwise
reproducibility. Within it, a real lane's (logits, v, action) are
bitwise-identical whatever the pad lanes hold and wherever in the slot
the lane sits — pinned by ``tests/test_serving.py`` on both the oracle
and forced-interpret-kernel routes.

Latency measurement (the driver + bench method): open-loop trace replay
on a wall clock. Request latency = (slot dispatch completion, blocked on
device outputs) - (trace arrival time); a request that waits in queue
pays its queueing delay in full, and arrivals never throttle to the
server's pace. ``mode="virtual"`` replaces the wall clock with a fixed
per-dispatch service time so scheduler tests are deterministic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.api import pad_mask
from repro.kernels import ops
from repro.rl.ppo import flat_policy_weights, policy_forward
from repro.serving.request import Request
from repro.serving.scheduler import SlotScheduler


@dataclass
class ServeReport:
    """One trace replay's results. Latencies in seconds; ``qps`` is
    served requests / makespan (first arrival -> last completion)."""
    requests: int
    served: int
    p50_s: float
    p99_s: float
    qps: float
    deadline_misses: int
    misses_by_class: Dict[int, int]
    max_queue_depth: int
    dispatches: int
    mean_occupancy: float        # mean real lanes per dispatched slot
    latencies_s: List[float] = field(repr=False, default_factory=list)

    def summary(self) -> Dict:
        """JSON-ready summary (drops the raw latency list)."""
        return {
            "requests": self.requests, "served": self.served,
            "p50_ms": self.p50_s * 1e3, "p99_ms": self.p99_s * 1e3,
            "qps": self.qps, "deadline_misses": self.deadline_misses,
            "misses_by_class": {str(k): v for k, v
                                in sorted(self.misses_by_class.items())},
            "max_queue_depth": self.max_queue_depth,
            "dispatches": self.dispatches,
            "mean_occupancy": self.mean_occupancy,
        }


class PolicyServer:
    """Continuous-batching inference over one fixed-slot jitted program.

    ``route`` selects the forward implementation (all three agree on
    logits/actions bitwise under jit; see the module docstring):
      - ``"auto"``: the production ``ops.serve_forward`` dispatch
        (compiled Pallas kernel on TPU, identical-math oracle elsewhere);
      - ``"interpret"``: force the Pallas kernel in interpret mode (the
        parity tests' route);
      - ``"xla"``: masked ``rl/ppo.py::policy_forward`` — the training
        net verbatim (its separate value-head GEMM makes ``v`` the
        documented 1-ulp leaf vs the fused routes).
    """

    def __init__(self, params, *, obs_dim: int, n_actions: int,
                 frame_stack: int = 1, slot: int = 64,
                 fast_gates: bool = True, route: str = "auto"):
        if route not in ("auto", "interpret", "xla"):
            raise ValueError(f"unknown route: {route!r}")
        self.slot = slot
        self.frame_dim = obs_dim * frame_stack
        self.n_actions = n_actions
        pw = flat_policy_weights(params)

        if route == "xla":
            def fwd(frames, mask):
                logits, v = policy_forward(params, frames,
                                           fast_gates=fast_gates)
                m = mask != 0
                logits = jnp.where(m[:, None], logits, 0.0)
                v = jnp.where(m, v, 0.0)
                return jnp.argmax(logits, -1), logits, v
        else:
            interpret = True if route == "interpret" else None

            def fwd(frames, mask):
                logits, v = ops.serve_forward(frames, mask, pw,
                                              fast_gates=fast_gates,
                                              interpret=interpret)
                return jnp.argmax(logits, -1), logits, v

        self._fwd = jax.jit(fwd)

    def forward_slot(self, frames, n_valid: int):
        """One dispatch on an already-padded (slot, frame_dim) batch with
        ``n_valid`` real lanes -> (actions (slot,), logits, v), blocked
        on device completion. Pad-lane outputs are zeros (and action 0)
        by the kernel-boundary mask — garbage by contract."""
        out = self._fwd(jnp.asarray(frames),
                        pad_mask(n_valid, self.slot))
        return jax.block_until_ready(out)

    def _pack(self, batch: List[Request]) -> np.ndarray:
        frames = np.zeros((self.slot, self.frame_dim), np.float32)
        frames[: len(batch)] = [req.frame for req in batch]
        return frames

    def serve(self, trace: List[Request],
              scheduler: Optional[SlotScheduler] = None, *,
              mode: str = "wallclock",
              service_time_s: float = 1e-3) -> ServeReport:
        """Replay an arrival-sorted open-loop ``trace`` to completion.

        ``mode="wallclock"`` measures real dispatch latency (the bench /
        driver path; idles until the next arrival when the queue runs
        dry, so offered load stays open-loop). ``mode="virtual"``
        advances a deterministic clock by ``service_time_s`` per
        dispatch — no timers, same scheduler decisions every run (the
        property tests' path)."""
        if mode not in ("wallclock", "virtual"):
            raise ValueError(f"unknown mode: {mode!r}")
        sched = scheduler if scheduler is not None else SlotScheduler(
            self.slot)
        latencies: List[float] = []
        occupancy: List[int] = []
        next_req = 0
        n = len(trace)
        t_start = time.perf_counter()
        now = 0.0
        last_done = 0.0

        while next_req < n or sched.pending:
            if mode == "wallclock":
                now = time.perf_counter() - t_start
            while next_req < n and trace[next_req].arrival <= now:
                sched.admit(trace[next_req])
                next_req = next_req + 1
            if not sched.pending:
                # open-loop idle: jump/sleep to the next arrival
                now = trace[next_req].arrival
                if mode == "wallclock":
                    wait = now - (time.perf_counter() - t_start)
                    if wait > 0:
                        time.sleep(wait)
                continue
            batch = sched.next_batch()
            self.forward_slot(self._pack(batch), len(batch))
            if mode == "wallclock":
                now = time.perf_counter() - t_start
            else:
                now = now + service_time_s
            sched.complete(batch, now)
            last_done = now
            occupancy.append(len(batch))
            latencies.extend(now - r.arrival for r in batch)

        makespan = max(last_done - (trace[0].arrival if trace else 0.0),
                       1e-9)
        lat = np.asarray(latencies) if latencies else np.zeros(1)
        return ServeReport(
            requests=n, served=sched.served,
            p50_s=float(np.percentile(lat, 50)),
            p99_s=float(np.percentile(lat, 99)),
            qps=sched.served / makespan,
            deadline_misses=sched.deadline_misses,
            misses_by_class=dict(sched.misses_by_class),
            max_queue_depth=sched.max_queue_depth,
            dispatches=len(occupancy),
            mean_occupancy=(float(np.mean(occupancy)) if occupancy
                            else 0.0),
            latencies_s=latencies)
