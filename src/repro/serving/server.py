"""PolicyServer: multi-slot, multi-policy continuous-batching inference.

One server = one or more trained policies + a small table of jitted slot
programs. Every dispatch runs one compiled masked slot forward on a
packed (shape, frame_dim) batch with a lane-validity mask — pad lanes
are zeroed inside the dispatch (the ragged-batch contract,
``envs/api.py``), and actions are the greedy ``argmax`` over the masked
logits, exactly the deployment policy ``rl/ppo.py::make_evaluator``
measures.

**Slot shapes.** ``slot`` is either one shape (the PR-8 fixed-slot
server: ONE compiled program, every dispatch padded to it) or an
ascending bucket set, e.g. ``(16, 64, 256)`` — one compiled program per
shape, all warmed before the serving clock starts (``warmup``), with
``scheduler.py::BucketedSlotScheduler`` right-sizing each dispatch into
the smallest admissible shape. Packing reuses one preallocated staging
buffer per shape (no per-dispatch allocation; pad lanes keep whatever
the previous dispatch left — garbage by contract, masked at the kernel
boundary).

**Policies.** ``params`` is either one policy tree (the single-tenant
``kernels/ops.py::serve_forward`` program) or a list of N trees —
cross-policy batching: the weights stack into one leading policy axis
(``rl/ppo.py::stack_policy_weights``) and every lane of a packed slot
selects its own checkpoint by index inside the one dispatch
(``kernels/ops.py::serve_forward_multi``), so one server process serves
a whole family of per-region checkpoints.

**Lifecycle + overload hardening (PR 10, the overload contract of
docs/ARCHITECTURE.md §8).** The server walks ``warming -> serving ->
draining -> drained``: ``warmup`` compiles every slot program before
the clock starts, ``serve`` flips to ``serving``, transitions to
``draining`` once the trace's arrivals are exhausted (only backlog
remains; ``drain`` is the standalone version), and lands on ``drained``
with a final stats snapshot. ``serve`` optionally takes an
``overload.py::AdmissionController`` (bounded queue +
deadline-feasibility rejection + brownout shedding — explicit counted
sheds instead of silent deadline misses), a
``distributed/fault_injection.py::FaultInjector`` (``SlowDispatch``,
``RequestFlood``, ``CorruptCheckpoint`` fire at deterministic
dispatch/reload seams), and ``reload_at`` hot-reload points.

**Hot policy reload.** ``reload(params)`` swaps the serving weights
in-place — same compiled programs, new weights (the forward takes the
weight pytree as a jit *argument*, so a same-shape swap never
recompiles) — but only after validation: (1) an ABI check (the
candidate's weight pytree must match the serving weights' structure,
shapes, and dtypes exactly), (2) a canary forward on a pinned probe
slot whose outputs must be finite, and (3) bitwise agreement of that
canary with the candidate's *own fresh server* at the same probe shape.
Any failure rolls back to the previous weights and counts
``reload_rejected`` — the server keeps serving bitwise-identical
outputs on the old weights. ``reload_from_checkpoint`` wires the same
gate to ``checkpoint/ckpt.py::restore_subtree``, so a torn or corrupt
checkpoint (COMMITTED missing, truncated payload, mangled metadata) is
rejected at restore and can never be swapped in.

Reproducibility contract (docs/ARCHITECTURE.md §8): the slot shape set
is static per server, and each forward always runs as the same jitted
program — XLA's GEMM reduction order is shape- and program-dependent, so
the *compiled slot program* is the unit of bitwise reproducibility.
Within one program, a real lane's (logits, v, action) are
bitwise-identical whatever the pad lanes hold and wherever in the slot
the lane sits — and a multi-policy lane is bitwise-identical to the
single-policy server of its own checkpoint at the same shape. Pinned by
``tests/test_serving.py`` on both the oracle and
forced-interpret-kernel routes.

Latency measurement (the driver + bench method): open-loop trace replay
on a wall clock. Request latency = (slot dispatch completion, blocked on
device outputs) - (trace arrival time); a request that waits in queue
pays its queueing delay in full, and arrivals never throttle to the
server's pace. ``mode="virtual"`` replaces the wall clock with a fixed
per-dispatch service time so scheduler tests — and every overload /
fault-injection decision — are deterministic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.envs.api import pad_mask
from repro.kernels import ops
from repro.rl.ppo import (flat_policy_weights, policy_forward,
                          stack_policy_weights)
from repro.serving.request import Request, flood_trace
from repro.serving.scheduler import BucketedSlotScheduler, SlotScheduler

#: occupancy-fraction bins per slot shape in ``ServeStats`` histograms
HIST_BINS = 8

#: server lifecycle states, in order
LIFECYCLE = ("warming", "serving", "draining", "drained")


@dataclass
class ServeStats:
    """Padding-waste + overload observability, accumulated per replay.

    ``record(shape, n)`` logs one dispatch of ``n`` real lanes in a
    ``shape``-lane program; ``record_rejection(reason, klass)`` logs one
    counted admission shed. The exported counters (all in ``summary()``
    and surfaced by ``repro.launch.policy_serve`` + the serve bench
    JSON): dispatches and real/padded lane totals per slot shape, the
    aggregate ``padded_lane_frac`` (padded lanes / dispatched lanes —
    the pure-waste FLOP fraction the bucketed scheduler exists to
    shrink), a per-shape occupancy histogram (``HIST_BINS`` equal
    occupancy-fraction bins; a healthy bucket loads the last bin), and
    the overload counters: ``rejected`` total with
    ``rejected_by_reason`` (queue_full / brownout / infeasible) and
    ``shed_by_class`` breakdowns, plus the replay's hot-reload outcomes
    (``reloads`` accepted, ``reload_rejected`` rolled back) and the
    lifecycle state at snapshot time (``final_state``). Every ratio is
    guarded for the zero-dispatch replay (empty or fully shed trace):
    ``summary()`` on a fresh instance is all zeros/empties, never a
    division error."""
    dispatches_by_slot: Dict[int, int] = field(default_factory=dict)
    lanes_by_slot: Dict[int, int] = field(default_factory=dict)
    occupancy_hist_by_slot: Dict[int, List[int]] = field(
        default_factory=dict)
    rejected: int = 0
    rejected_by_reason: Dict[str, int] = field(default_factory=dict)
    shed_by_class: Dict[int, int] = field(default_factory=dict)
    reloads: int = 0
    reload_rejected: int = 0
    final_state: str = ""

    def record(self, shape: int, n: int) -> None:
        self.dispatches_by_slot[shape] = (
            self.dispatches_by_slot.get(shape, 0) + 1)
        self.lanes_by_slot[shape] = self.lanes_by_slot.get(shape, 0) + n
        hist = self.occupancy_hist_by_slot.setdefault(
            shape, [0] * HIST_BINS)
        hist[min(HIST_BINS - 1, max(0, (n - 1) * HIST_BINS // shape))] += 1

    def record_rejection(self, reason: str, klass: int) -> None:
        """One counted admission shed (the overload contract: explicit
        rejections replace silent deadline misses)."""
        self.rejected += 1
        self.rejected_by_reason[reason] = (
            self.rejected_by_reason.get(reason, 0) + 1)
        self.shed_by_class[klass] = self.shed_by_class.get(klass, 0) + 1

    @property
    def dispatches(self) -> int:
        return sum(self.dispatches_by_slot.values())

    @property
    def total_lanes(self) -> int:
        """Dispatched lanes, real + padded (occupancy denominator)."""
        return sum(s * k for s, k in self.dispatches_by_slot.items())

    @property
    def real_lanes(self) -> int:
        return sum(self.lanes_by_slot.values())

    @property
    def padded_lane_frac(self) -> float:
        total = self.total_lanes
        return (total - self.real_lanes) / total if total else 0.0

    def summary(self) -> Dict:
        return {
            "padded_lane_frac": self.padded_lane_frac,
            "dispatches_by_slot": {str(s): k for s, k in
                                   sorted(self.dispatches_by_slot.items())},
            "mean_occupancy_by_slot": {
                str(s): self.lanes_by_slot[s] / (s * k)
                for s, k in sorted(self.dispatches_by_slot.items())},
            "occupancy_hist_by_slot": {
                str(s): list(h) for s, h in
                sorted(self.occupancy_hist_by_slot.items())},
            "rejected": self.rejected,
            "rejected_by_reason": dict(sorted(
                self.rejected_by_reason.items())),
            "shed_by_class": {str(k): v for k, v in
                              sorted(self.shed_by_class.items())},
            "reloads": self.reloads,
            "reload_rejected": self.reload_rejected,
            "final_state": self.final_state,
        }


@dataclass
class ServeReport:
    """One trace replay's results. Latencies in seconds; ``qps`` is
    served requests / makespan (first arrival -> last completion);
    ``stats`` is the padding-waste + overload observability
    (``ServeStats`` — rejections, sheds, reload outcomes, lifecycle)."""
    requests: int
    served: int
    p50_s: float
    p99_s: float
    qps: float
    deadline_misses: int
    misses_by_class: Dict[int, int]
    max_queue_depth: int
    dispatches: int
    mean_occupancy: float        # mean real lanes per dispatched slot
    stats: ServeStats = field(default_factory=ServeStats)
    latencies_s: List[float] = field(repr=False, default_factory=list)

    def summary(self) -> Dict:
        """JSON-ready summary (drops the raw latency list)."""
        return {
            "requests": self.requests, "served": self.served,
            "p50_ms": self.p50_s * 1e3, "p99_ms": self.p99_s * 1e3,
            "qps": self.qps, "deadline_misses": self.deadline_misses,
            "misses_by_class": {str(k): v for k, v
                                in sorted(self.misses_by_class.items())},
            "max_queue_depth": self.max_queue_depth,
            "dispatches": self.dispatches,
            "mean_occupancy": self.mean_occupancy,
            **self.stats.summary(),
        }


class _ReloadRejected(Exception):
    """Internal: a reload validation gate failed (reason in args)."""


class PolicyServer:
    """Continuous-batching inference over a table of jitted slot programs.

    ``slot``: one shape (fixed-slot server) or an ascending bucket set
    (multi-slot server; dispatches right-size via
    ``BucketedSlotScheduler``). ``params``: one policy tree, or a list
    of N trees for cross-policy batching (lane -> checkpoint by the
    request's ``policy`` index).

    ``route`` selects the forward implementation (all three agree on
    logits/actions bitwise under jit; see the module docstring):
      - ``"auto"``: the production ``ops.serve_forward`` /
        ``ops.serve_forward_multi`` dispatch (compiled Pallas kernel on
        TPU, identical-math oracle elsewhere);
      - ``"interpret"``: force the Pallas kernel in interpret mode (the
        parity tests' route);
      - ``"xla"``: masked ``rl/ppo.py::policy_forward`` — the training
        net verbatim (its separate value-head GEMM makes ``v`` the
        documented 1-ulp leaf vs the fused routes).

    The forward takes its weight pytree as a jit *argument* (not a
    closure constant), which is what makes ``reload`` an atomic swap:
    same shapes -> same compiled programs, zero recompiles.
    """

    def __init__(self, params, *, obs_dim: int, n_actions: int,
                 frame_stack: int = 1,
                 slot: Union[int, Sequence[int]] = 64,
                 fast_gates: bool = True, route: str = "auto"):
        if route not in ("auto", "interpret", "xla"):
            raise ValueError(f"unknown route: {route!r}")
        shapes = (slot,) if isinstance(slot, int) else tuple(slot)
        shapes = tuple(sorted(set(int(s) for s in shapes)))
        if not shapes or shapes[0] < 1:
            raise ValueError(f"slot shapes must be >= 1, got {slot!r}")
        self.slots = shapes
        self.slot = shapes[-1]           # the largest compiled shape
        self.obs_dim = obs_dim
        self.frame_stack = frame_stack
        self.frame_dim = obs_dim * frame_stack
        self.n_actions = n_actions
        self.fast_gates = fast_gates
        self.route = route
        multi = isinstance(params, (list, tuple))
        self.n_policies = len(params) if multi else 1
        self._staging: Dict[int, np.ndarray] = {}
        self._pidx_staging: Dict[int, np.ndarray] = {}
        self._warmed: set = set()
        self.state = "warming"
        self.policy_version = 0
        self.reloads = 0
        self.reload_rejected = 0
        self.reload_log: List[Tuple[str, str]] = []
        # pinned probe slot for reload canaries: fixed frames at the
        # smallest compiled shape, every checkpoint exercised
        self._probe_frames = np.random.default_rng(0).standard_normal(
            (self.slots[0], self.frame_dim)).astype(np.float32)

        interpret = True if route == "interpret" else None
        if multi:
            if route == "xla":
                def fwd(frames, mask, pidx, weights):
                    m = mask != 0
                    logits = jnp.zeros(
                        (frames.shape[0], n_actions), jnp.float32)
                    v = jnp.zeros((frames.shape[0],), jnp.float32)
                    for n, p in enumerate(weights):
                        lg_n, v_n = policy_forward(p, frames,
                                                   fast_gates=fast_gates)
                        sel = pidx == n
                        logits = jnp.where(sel[:, None], lg_n, logits)
                        v = jnp.where(sel, v_n, v)
                    logits = jnp.where(m[:, None], logits, 0.0)
                    v = jnp.where(m, v, 0.0)
                    return jnp.argmax(logits, -1), logits, v

                def make_weights(ps):
                    return tuple(ps)
            else:
                def fwd(frames, mask, pidx, weights):
                    logits, v = ops.serve_forward_multi(
                        frames, mask, pidx, weights, fast_gates=fast_gates,
                        interpret=interpret)
                    return jnp.argmax(logits, -1), logits, v

                def make_weights(ps):
                    return stack_policy_weights(list(ps))
        else:
            if route == "xla":
                def fwd(frames, mask, pidx, weights):
                    del pidx             # single policy: one checkpoint
                    logits, v = policy_forward(weights, frames,
                                               fast_gates=fast_gates)
                    m = mask != 0
                    logits = jnp.where(m[:, None], logits, 0.0)
                    v = jnp.where(m, v, 0.0)
                    return jnp.argmax(logits, -1), logits, v

                def make_weights(ps):
                    return ps
            else:
                def fwd(frames, mask, pidx, weights):
                    del pidx             # single policy: one checkpoint
                    logits, v = ops.serve_forward(frames, mask, weights,
                                                  fast_gates=fast_gates,
                                                  interpret=interpret)
                    return jnp.argmax(logits, -1), logits, v

                def make_weights(ps):
                    return flat_policy_weights(ps)

        self._params = list(params) if multi else params
        self._make_weights = make_weights
        self._weights = make_weights(self._params)
        self._fwd = jax.jit(fwd)

    def forward_slot(self, frames, n_valid: int, pidx=None):
        """One dispatch on an already-padded (shape, frame_dim) batch
        with ``n_valid`` real lanes -> (actions (shape,), logits, v),
        blocked on device completion. The compiled program is selected
        by the batch's shape (one jitted specialization per slot shape).
        ``pidx`` (shape,) int32 routes each lane to its checkpoint on a
        multi-policy server (zeros — checkpoint 0 — when omitted).
        Pad-lane outputs are zeros (and action 0) by the kernel-boundary
        mask — garbage by contract."""
        frames = jnp.asarray(frames)
        shape = frames.shape[0]
        if pidx is None:
            pidx = jnp.zeros((shape,), jnp.int32)
        out = self._fwd(frames, pad_mask(n_valid, shape),
                        jnp.asarray(pidx, dtype=jnp.int32), self._weights)
        self._warmed.add(shape)
        return jax.block_until_ready(out)

    def warmup(self, shapes: Optional[Sequence[int]] = None) -> None:
        """Compile every slot program before the serving clock starts —
        a trace+compile must never land on a dispatch latency. Idempotent
        per shape; ``serve`` calls it with the scheduler's shape set."""
        for shape in shapes if shapes is not None else self.slots:
            if shape not in self._warmed:
                frames, pidx = self._pack([], shape)
                self.forward_slot(frames, 0, pidx)

    # ---------------------------------------------------- hot reload

    def _probe_pidx(self, shape: int) -> np.ndarray:
        return (np.arange(shape, dtype=np.int32) % self.n_policies)

    def reload(self, params) -> bool:
        """Validated atomic hot swap of the serving weights (the reload
        gate of the overload contract, ARCHITECTURE §8). Three gates, in
        order, all on the *candidate* — the serving weights are untouched
        until every gate passes:

        1. **ABI check**: the candidate's weight pytree (built by the
           same route-specific builder as the serving weights) must
           match structure, shapes, and dtypes exactly.
        2. **Canary forward** on the pinned probe slot (fixed frames at
           the smallest compiled shape, every checkpoint of a
           multi-policy server exercised): all outputs must be finite —
           a NaN/Inf-poisoned payload (torn write, bit rot) dies here.
        3. **Bitwise parity vs the candidate's own fresh server**: a new
           ``PolicyServer`` built from the candidate at the probe shape
           must produce bitwise-identical (action, logits, v) — the
           live program with swapped weights IS the program a fresh
           deployment of those weights would run.

        Success swaps weights + params atomically (same compiled
        programs — the weights are a jit argument), bumps
        ``policy_version`` and ``reloads``, and returns True. Any
        failure (including exceptions from malformed candidates) rolls
        back to the previous weights, counts ``reload_rejected``, logs
        the reason in ``reload_log``, and returns False — the server
        keeps serving bitwise-identical outputs on the old weights."""
        multi = isinstance(self._params, list)
        try:
            if multi != isinstance(params, (list, tuple)):
                raise _ReloadRejected(
                    "abi: single/multi policy kind mismatch")
            if multi and len(params) != self.n_policies:
                raise _ReloadRejected(
                    f"abi: {len(params)} policies for a "
                    f"{self.n_policies}-policy server")
            cand_params = list(params) if multi else params
            try:
                cand = self._make_weights(cand_params)
            except Exception as e:
                raise _ReloadRejected(f"abi: weight build failed: {e}")
            cur_leaves, cur_def = jax.tree_util.tree_flatten(self._weights)
            cand_leaves, cand_def = jax.tree_util.tree_flatten(cand)
            if cand_def != cur_def:
                raise _ReloadRejected("abi: weight tree structure differs")
            for old, new in zip(cur_leaves, cand_leaves):
                if (tuple(np.shape(old)) != tuple(np.shape(new))
                        or np.asarray(old).dtype != np.asarray(new).dtype):
                    raise _ReloadRejected(
                        f"abi: leaf {tuple(np.shape(old))}/"
                        f"{np.asarray(old).dtype} != "
                        f"{tuple(np.shape(new))}/{np.asarray(new).dtype}")

            probe = self.slots[0]
            pidx = self._probe_pidx(probe)
            out = jax.block_until_ready(self._fwd(
                jnp.asarray(self._probe_frames), pad_mask(probe, probe),
                jnp.asarray(pidx), cand))
            if not all(bool(jnp.isfinite(x).all()) for x in out[1:]):
                raise _ReloadRejected(
                    "canary: non-finite logits/values on the probe slot")
            fresh = PolicyServer(
                cand_params, obs_dim=self.obs_dim,
                n_actions=self.n_actions, frame_stack=self.frame_stack,
                slot=probe, fast_gates=self.fast_gates, route=self.route)
            ref = fresh.forward_slot(self._probe_frames, probe, pidx)
            if not all(bool(jnp.array_equal(a, b))
                       for a, b in zip(out, ref)):
                raise _ReloadRejected(
                    "canary: probe outputs differ from the candidate's "
                    "own fresh server (not bitwise)")
        except _ReloadRejected as e:
            reason = str(e)
        except Exception as e:           # malformed candidate trees etc.
            reason = f"abi: {type(e).__name__}: {e}"
        else:
            self._weights = cand
            self._params = cand_params
            self.policy_version += 1
            self.reloads += 1
            self.reload_log.append(("ok", f"v{self.policy_version}"))
            return True
        self.reload_rejected += 1
        self.reload_log.append(("rejected", reason))
        return False

    def reload_from_checkpoint(self, ckpt_dir, step: Optional[int] = None
                               ) -> bool:
        """Hot-reload the policy subtree of an ``rl_train`` checkpoint
        through the full reload gate. A torn or corrupt checkpoint
        (missing COMMITTED, truncated payload, mangled metadata — every
        layout ``distributed/fault_injection.py::torn_save`` builds)
        makes ``ckpt.restore_subtree`` raise, which is counted as a
        rejected reload — it can never be swapped in, and the server
        keeps serving on the old weights."""
        if self.n_policies != 1:
            raise ValueError(
                "reload_from_checkpoint serves single-policy servers; "
                "restore each checkpoint and call reload([..]) instead")
        try:
            params, _, _ = ckpt.restore_subtree(
                ckpt_dir, self._params, "['policy']", step=step)
        except Exception as e:
            self.reload_rejected += 1
            self.reload_log.append(
                ("rejected", f"restore: {type(e).__name__}: {e}"))
            return False
        return self.reload(params)

    # ------------------------------------------------------- packing

    def _pack(self, batch: List[Request], shape: int):
        """Pack ``batch`` into the preallocated ``shape``-lane staging
        buffers -> (frames (shape, frame_dim) f32, pidx (shape,) i32).
        One buffer pair per slot shape, allocated on first use and
        reused every dispatch — no per-dispatch allocation, and no
        re-pad of the tail: pad lanes keep whatever the previous
        dispatch left there, which the kernel-boundary mask makes
        garbage by contract (pinned by the pad-content property test).
        A slot-sized batch overwrites every lane, so it skips even
        that."""
        frames = self._staging.get(shape)
        if frames is None:
            frames = self._staging.setdefault(
                shape, np.zeros((shape, self.frame_dim), np.float32))
            self._pidx_staging[shape] = np.zeros((shape,), np.int32)
        pidx = self._pidx_staging[shape]
        if batch:
            frames[:len(batch)] = [req.frame for req in batch]
            pidx[:len(batch)] = [req.policy for req in batch]
        return frames, pidx

    def make_scheduler(self) -> SlotScheduler:
        """The server's matching scheduler: bucketed over ``slots`` when
        the server compiled several shapes, fixed-slot otherwise."""
        if len(self.slots) > 1:
            return BucketedSlotScheduler(self.slots)
        return SlotScheduler(self.slot)

    # -------------------------------------------------------- replay

    def _dispatch_once(self, sched, stats: ServeStats,
                       latencies: List[float], now: float, mode: str,
                       service_time_s: float, t_start: float,
                       extra_s: float) -> float:
        """Pop + pack + forward one batch, advance the clock (virtual:
        ``service_time_s + extra_s``; wallclock: real time plus a
        slept ``extra_s``), complete the batch -> (new now, measured
        dispatch seconds)."""
        t_disp = time.perf_counter()
        shape, batch = sched.next_dispatch()
        frames, pidx = self._pack(batch, shape)
        self.forward_slot(frames, len(batch), pidx)
        if mode == "wallclock":
            if extra_s > 0:
                time.sleep(extra_s)
            now = time.perf_counter() - t_start
            dt = time.perf_counter() - t_disp
        else:
            dt = service_time_s + extra_s
            now = now + dt
        sched.complete(batch, now)
        stats.record(shape, len(batch))
        latencies.extend(now - r.arrival for r in batch)
        return now, dt, shape

    def drain(self, sched, *, stats: Optional[ServeStats] = None,
              now: float = 0.0, service_time_s: float = 1e-3
              ) -> Tuple[ServeStats, float]:
        """Complete every in-flight batch on ``sched`` — no new
        admissions — on a virtual clock starting at ``now``, then land
        the lifecycle on ``drained`` and emit the final stats snapshot:
        -> (stats, completion time). ``serve`` does the same inline for
        the tail of a trace; this is the standalone path for shutting
        down a server whose scheduler still holds work."""
        self.state = "draining"
        stats = stats if stats is not None else ServeStats()
        latencies: List[float] = []
        while sched.pending:
            now, _, _ = self._dispatch_once(
                sched, stats, latencies, now, "virtual", service_time_s,
                0.0, 0.0)
        self.state = "drained"
        stats.final_state = self.state
        return stats, now

    def serve(self, trace: List[Request],
              scheduler: Optional[SlotScheduler] = None, *,
              mode: str = "wallclock",
              service_time_s: float = 1e-3,
              admission=None, faults=None,
              reload_at: Sequence[int] = (),
              reload_params=None) -> ServeReport:
        """Replay an arrival-sorted open-loop ``trace`` to completion.

        ``mode="wallclock"`` measures real dispatch latency (the bench /
        driver path; idles until the next arrival when the queue runs
        dry, so offered load stays open-loop). ``mode="virtual"``
        advances a deterministic clock by ``service_time_s`` per
        dispatch — no timers, same scheduler decisions every run (the
        property tests' path, and the overload/fault tests': every
        admission and fault decision replays exactly).

        ``admission`` (an ``overload.py::AdmissionController``) gates
        every would-be ``sched.admit`` — rejections are counted in the
        report's stats, never silently dropped. ``faults`` (a
        ``FaultInjector``) fires ``RequestFlood`` on the trace before
        replay, ``SlowDispatch`` at its dispatch index, and
        ``CorruptCheckpoint`` at the matching hot-reload attempt.
        ``reload_at`` lists dispatch indices at which the server
        attempts ``reload(reload_params)`` (defaults to its own current
        params — a self-refresh, the canary path chaos plans corrupt);
        attempts past the last dispatch fire during the final drain so
        a plan never silently expires.

        Lifecycle: ``serving`` while arrivals remain, ``draining`` once
        only backlog is left, ``drained`` at return (the stats snapshot
        records it)."""
        if mode not in ("wallclock", "virtual"):
            raise ValueError(f"unknown mode: {mode!r}")
        if faults is not None:
            for fl in faults.take_floods():
                trace = flood_trace(trace, fl.at_s, fl.duration_s,
                                    fl.multiplier)
        sched = scheduler if scheduler is not None else \
            self.make_scheduler()
        self.warmup(getattr(sched, "buckets", (sched.slot,)))
        self.state = "serving"
        stats = ServeStats()
        reloads0 = self.reloads
        rejected0 = self.reload_rejected
        pending_reloads = sorted(set(int(d) for d in reload_at))
        reload_attempt = 0

        def try_reloads(dispatch_idx: Optional[int]) -> None:
            nonlocal reload_attempt
            while pending_reloads and (
                    dispatch_idx is None
                    or pending_reloads[0] <= dispatch_idx):
                pending_reloads.pop(0)
                cand = (reload_params if reload_params is not None
                        else self._params)
                if faults is not None:
                    cand = faults.corrupt_params(reload_attempt, cand)
                self.reload(cand)
                reload_attempt += 1

        latencies: List[float] = []
        next_req = 0
        dispatch_idx = 0
        n = len(trace)
        t_start = time.perf_counter()
        now = 0.0
        last_done = 0.0

        while next_req < n or sched.pending:
            if mode == "wallclock":
                now = time.perf_counter() - t_start
            while next_req < n and trace[next_req].arrival <= now:
                req = trace[next_req]
                if admission is None:
                    sched.admit(req)
                else:
                    admission.admit(req, now, sched, stats)
                next_req = next_req + 1
            if next_req >= n and self.state == "serving":
                self.state = "draining"   # only backlog left
            if not sched.pending:
                if next_req >= n:
                    break                 # everything shed: nothing to run
                # open-loop idle: jump/sleep to the next arrival
                now = trace[next_req].arrival
                if mode == "wallclock":
                    wait = now - (time.perf_counter() - t_start)
                    if wait > 0:
                        time.sleep(wait)
                continue
            try_reloads(dispatch_idx)
            extra = (faults.dispatch_delay_s(dispatch_idx)
                     if faults is not None else 0.0)
            now, dt, shape = self._dispatch_once(
                sched, stats, latencies, now, mode, service_time_s,
                t_start, extra)
            if admission is not None:
                admission.observe_dispatch(shape, dt, sched)
            last_done = now
            dispatch_idx += 1
        try_reloads(None)                 # leftover plan: fire at drain
        self.state = "drained"
        stats.reloads = self.reloads - reloads0
        stats.reload_rejected = self.reload_rejected - rejected0
        stats.final_state = self.state

        makespan = max(last_done - (trace[0].arrival if trace else 0.0),
                       1e-9)
        lat = np.asarray(latencies) if latencies else np.zeros(1)
        return ServeReport(
            requests=n, served=sched.served,
            p50_s=float(np.percentile(lat, 50)),
            p99_s=float(np.percentile(lat, 99)),
            qps=sched.served / makespan,
            deadline_misses=sched.deadline_misses,
            misses_by_class=dict(sched.misses_by_class),
            max_queue_depth=sched.max_queue_depth,
            dispatches=stats.dispatches,
            mean_occupancy=(stats.real_lanes / stats.dispatches
                            if stats.dispatches else 0.0),
            stats=stats,
            latencies_s=latencies)
