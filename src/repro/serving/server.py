"""PolicyServer: multi-slot, multi-policy continuous-batching inference.

One server = one or more trained policies + a small table of jitted slot
programs. Every dispatch runs one compiled masked slot forward on a
packed (shape, frame_dim) batch with a lane-validity mask — pad lanes
are zeroed inside the dispatch (the ragged-batch contract,
``envs/api.py``), and actions are the greedy ``argmax`` over the masked
logits, exactly the deployment policy ``rl/ppo.py::make_evaluator``
measures.

**Slot shapes.** ``slot`` is either one shape (the PR-8 fixed-slot
server: ONE compiled program, every dispatch padded to it) or an
ascending bucket set, e.g. ``(16, 64, 256)`` — one compiled program per
shape, all warmed before the serving clock starts (``warmup``), with
``scheduler.py::BucketedSlotScheduler`` right-sizing each dispatch into
the smallest admissible shape. Packing reuses one preallocated staging
buffer per shape (no per-dispatch allocation; pad lanes keep whatever
the previous dispatch left — garbage by contract, masked at the kernel
boundary).

**Policies.** ``params`` is either one policy tree (the single-tenant
``kernels/ops.py::serve_forward`` program) or a list of N trees —
cross-policy batching: the weights stack into one leading policy axis
(``rl/ppo.py::stack_policy_weights``) and every lane of a packed slot
selects its own checkpoint by index inside the one dispatch
(``kernels/ops.py::serve_forward_multi``), so one server process serves
a whole family of per-region checkpoints.

Reproducibility contract (docs/ARCHITECTURE.md §8): the slot shape set
is static per server, and each forward always runs as the same jitted
program — XLA's GEMM reduction order is shape- and program-dependent, so
the *compiled slot program* is the unit of bitwise reproducibility.
Within one program, a real lane's (logits, v, action) are
bitwise-identical whatever the pad lanes hold and wherever in the slot
the lane sits — and a multi-policy lane is bitwise-identical to the
single-policy server of its own checkpoint at the same shape. Pinned by
``tests/test_serving.py`` on both the oracle and
forced-interpret-kernel routes.

Latency measurement (the driver + bench method): open-loop trace replay
on a wall clock. Request latency = (slot dispatch completion, blocked on
device outputs) - (trace arrival time); a request that waits in queue
pays its queueing delay in full, and arrivals never throttle to the
server's pace. ``mode="virtual"`` replaces the wall clock with a fixed
per-dispatch service time so scheduler tests are deterministic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.api import pad_mask
from repro.kernels import ops
from repro.rl.ppo import (flat_policy_weights, policy_forward,
                          stack_policy_weights)
from repro.serving.request import Request
from repro.serving.scheduler import BucketedSlotScheduler, SlotScheduler

#: occupancy-fraction bins per slot shape in ``ServeStats`` histograms
HIST_BINS = 8


@dataclass
class ServeStats:
    """Padding-waste observability, accumulated per dispatch.

    ``record(shape, n)`` logs one dispatch of ``n`` real lanes in a
    ``shape``-lane program. The exported counters (all in ``summary()``
    and surfaced by ``repro.launch.policy_serve`` + the serve bench
    JSON): dispatches and real/padded lane totals per slot shape, the
    aggregate ``padded_lane_frac`` (padded lanes / dispatched lanes —
    the pure-waste FLOP fraction the bucketed scheduler exists to
    shrink), and a per-shape occupancy histogram (``HIST_BINS`` equal
    occupancy-fraction bins; a healthy bucket loads the last bin)."""
    dispatches_by_slot: Dict[int, int] = field(default_factory=dict)
    lanes_by_slot: Dict[int, int] = field(default_factory=dict)
    occupancy_hist_by_slot: Dict[int, List[int]] = field(
        default_factory=dict)

    def record(self, shape: int, n: int) -> None:
        self.dispatches_by_slot[shape] = (
            self.dispatches_by_slot.get(shape, 0) + 1)
        self.lanes_by_slot[shape] = self.lanes_by_slot.get(shape, 0) + n
        hist = self.occupancy_hist_by_slot.setdefault(
            shape, [0] * HIST_BINS)
        hist[min(HIST_BINS - 1, max(0, (n - 1) * HIST_BINS // shape))] += 1

    @property
    def dispatches(self) -> int:
        return sum(self.dispatches_by_slot.values())

    @property
    def total_lanes(self) -> int:
        """Dispatched lanes, real + padded (occupancy denominator)."""
        return sum(s * k for s, k in self.dispatches_by_slot.items())

    @property
    def real_lanes(self) -> int:
        return sum(self.lanes_by_slot.values())

    @property
    def padded_lane_frac(self) -> float:
        total = self.total_lanes
        return (total - self.real_lanes) / total if total else 0.0

    def summary(self) -> Dict:
        return {
            "padded_lane_frac": self.padded_lane_frac,
            "dispatches_by_slot": {str(s): k for s, k in
                                   sorted(self.dispatches_by_slot.items())},
            "mean_occupancy_by_slot": {
                str(s): self.lanes_by_slot[s] / (s * k)
                for s, k in sorted(self.dispatches_by_slot.items())},
            "occupancy_hist_by_slot": {
                str(s): list(h) for s, h in
                sorted(self.occupancy_hist_by_slot.items())},
        }


@dataclass
class ServeReport:
    """One trace replay's results. Latencies in seconds; ``qps`` is
    served requests / makespan (first arrival -> last completion);
    ``stats`` is the padding-waste observability (``ServeStats``)."""
    requests: int
    served: int
    p50_s: float
    p99_s: float
    qps: float
    deadline_misses: int
    misses_by_class: Dict[int, int]
    max_queue_depth: int
    dispatches: int
    mean_occupancy: float        # mean real lanes per dispatched slot
    stats: ServeStats = field(default_factory=ServeStats)
    latencies_s: List[float] = field(repr=False, default_factory=list)

    def summary(self) -> Dict:
        """JSON-ready summary (drops the raw latency list)."""
        return {
            "requests": self.requests, "served": self.served,
            "p50_ms": self.p50_s * 1e3, "p99_ms": self.p99_s * 1e3,
            "qps": self.qps, "deadline_misses": self.deadline_misses,
            "misses_by_class": {str(k): v for k, v
                                in sorted(self.misses_by_class.items())},
            "max_queue_depth": self.max_queue_depth,
            "dispatches": self.dispatches,
            "mean_occupancy": self.mean_occupancy,
            **self.stats.summary(),
        }


class PolicyServer:
    """Continuous-batching inference over a table of jitted slot programs.

    ``slot``: one shape (fixed-slot server) or an ascending bucket set
    (multi-slot server; dispatches right-size via
    ``BucketedSlotScheduler``). ``params``: one policy tree, or a list
    of N trees for cross-policy batching (lane -> checkpoint by the
    request's ``policy`` index).

    ``route`` selects the forward implementation (all three agree on
    logits/actions bitwise under jit; see the module docstring):
      - ``"auto"``: the production ``ops.serve_forward`` /
        ``ops.serve_forward_multi`` dispatch (compiled Pallas kernel on
        TPU, identical-math oracle elsewhere);
      - ``"interpret"``: force the Pallas kernel in interpret mode (the
        parity tests' route);
      - ``"xla"``: masked ``rl/ppo.py::policy_forward`` — the training
        net verbatim (its separate value-head GEMM makes ``v`` the
        documented 1-ulp leaf vs the fused routes).
    """

    def __init__(self, params, *, obs_dim: int, n_actions: int,
                 frame_stack: int = 1,
                 slot: Union[int, Sequence[int]] = 64,
                 fast_gates: bool = True, route: str = "auto"):
        if route not in ("auto", "interpret", "xla"):
            raise ValueError(f"unknown route: {route!r}")
        shapes = (slot,) if isinstance(slot, int) else tuple(slot)
        shapes = tuple(sorted(set(int(s) for s in shapes)))
        if not shapes or shapes[0] < 1:
            raise ValueError(f"slot shapes must be >= 1, got {slot!r}")
        self.slots = shapes
        self.slot = shapes[-1]           # the largest compiled shape
        self.frame_dim = obs_dim * frame_stack
        self.n_actions = n_actions
        multi = isinstance(params, (list, tuple))
        self.n_policies = len(params) if multi else 1
        self._staging: Dict[int, np.ndarray] = {}
        self._pidx_staging: Dict[int, np.ndarray] = {}
        self._warmed: set = set()

        if multi:
            pws = stack_policy_weights(list(params))
            if route == "xla":
                def fwd(frames, mask, pidx):
                    m = mask != 0
                    logits = jnp.zeros(
                        (frames.shape[0], n_actions), jnp.float32)
                    v = jnp.zeros((frames.shape[0],), jnp.float32)
                    for n, p in enumerate(params):
                        lg_n, v_n = policy_forward(p, frames,
                                                   fast_gates=fast_gates)
                        sel = pidx == n
                        logits = jnp.where(sel[:, None], lg_n, logits)
                        v = jnp.where(sel, v_n, v)
                    logits = jnp.where(m[:, None], logits, 0.0)
                    v = jnp.where(m, v, 0.0)
                    return jnp.argmax(logits, -1), logits, v
            else:
                interpret = True if route == "interpret" else None

                def fwd(frames, mask, pidx):
                    logits, v = ops.serve_forward_multi(
                        frames, mask, pidx, pws, fast_gates=fast_gates,
                        interpret=interpret)
                    return jnp.argmax(logits, -1), logits, v
        else:
            pw = flat_policy_weights(params)
            if route == "xla":
                def fwd(frames, mask, pidx):
                    logits, v = policy_forward(params, frames,
                                               fast_gates=fast_gates)
                    m = mask != 0
                    logits = jnp.where(m[:, None], logits, 0.0)
                    v = jnp.where(m, v, 0.0)
                    return jnp.argmax(logits, -1), logits, v
            else:
                interpret = True if route == "interpret" else None

                def fwd(frames, mask, pidx):
                    del pidx             # single policy: one checkpoint
                    logits, v = ops.serve_forward(frames, mask, pw,
                                                  fast_gates=fast_gates,
                                                  interpret=interpret)
                    return jnp.argmax(logits, -1), logits, v

        self._fwd = jax.jit(fwd)

    def forward_slot(self, frames, n_valid: int, pidx=None):
        """One dispatch on an already-padded (shape, frame_dim) batch
        with ``n_valid`` real lanes -> (actions (shape,), logits, v),
        blocked on device completion. The compiled program is selected
        by the batch's shape (one jitted specialization per slot shape).
        ``pidx`` (shape,) int32 routes each lane to its checkpoint on a
        multi-policy server (zeros — checkpoint 0 — when omitted).
        Pad-lane outputs are zeros (and action 0) by the kernel-boundary
        mask — garbage by contract."""
        frames = jnp.asarray(frames)
        shape = frames.shape[0]
        if pidx is None:
            pidx = jnp.zeros((shape,), jnp.int32)
        out = self._fwd(frames, pad_mask(n_valid, shape),
                        jnp.asarray(pidx, dtype=jnp.int32))
        self._warmed.add(shape)
        return jax.block_until_ready(out)

    def warmup(self, shapes: Optional[Sequence[int]] = None) -> None:
        """Compile every slot program before the serving clock starts —
        a trace+compile must never land on a dispatch latency. Idempotent
        per shape; ``serve`` calls it with the scheduler's shape set."""
        for shape in shapes if shapes is not None else self.slots:
            if shape not in self._warmed:
                frames, pidx = self._pack([], shape)
                self.forward_slot(frames, 0, pidx)

    def _pack(self, batch: List[Request], shape: int):
        """Pack ``batch`` into the preallocated ``shape``-lane staging
        buffers -> (frames (shape, frame_dim) f32, pidx (shape,) i32).
        One buffer pair per slot shape, allocated on first use and
        reused every dispatch — no per-dispatch allocation, and no
        re-pad of the tail: pad lanes keep whatever the previous
        dispatch left there, which the kernel-boundary mask makes
        garbage by contract (pinned by the pad-content property test).
        A slot-sized batch overwrites every lane, so it skips even
        that."""
        frames = self._staging.get(shape)
        if frames is None:
            frames = self._staging.setdefault(
                shape, np.zeros((shape, self.frame_dim), np.float32))
            self._pidx_staging[shape] = np.zeros((shape,), np.int32)
        pidx = self._pidx_staging[shape]
        if batch:
            frames[:len(batch)] = [req.frame for req in batch]
            pidx[:len(batch)] = [req.policy for req in batch]
        return frames, pidx

    def make_scheduler(self) -> SlotScheduler:
        """The server's matching scheduler: bucketed over ``slots`` when
        the server compiled several shapes, fixed-slot otherwise."""
        if len(self.slots) > 1:
            return BucketedSlotScheduler(self.slots)
        return SlotScheduler(self.slot)

    def serve(self, trace: List[Request],
              scheduler: Optional[SlotScheduler] = None, *,
              mode: str = "wallclock",
              service_time_s: float = 1e-3) -> ServeReport:
        """Replay an arrival-sorted open-loop ``trace`` to completion.

        ``mode="wallclock"`` measures real dispatch latency (the bench /
        driver path; idles until the next arrival when the queue runs
        dry, so offered load stays open-loop). ``mode="virtual"``
        advances a deterministic clock by ``service_time_s`` per
        dispatch — no timers, same scheduler decisions every run (the
        property tests' path)."""
        if mode not in ("wallclock", "virtual"):
            raise ValueError(f"unknown mode: {mode!r}")
        sched = scheduler if scheduler is not None else \
            self.make_scheduler()
        self.warmup(getattr(sched, "buckets", (sched.slot,)))
        stats = ServeStats()
        latencies: List[float] = []
        next_req = 0
        n = len(trace)
        t_start = time.perf_counter()
        now = 0.0
        last_done = 0.0

        while next_req < n or sched.pending:
            if mode == "wallclock":
                now = time.perf_counter() - t_start
            while next_req < n and trace[next_req].arrival <= now:
                sched.admit(trace[next_req])
                next_req = next_req + 1
            if not sched.pending:
                # open-loop idle: jump/sleep to the next arrival
                now = trace[next_req].arrival
                if mode == "wallclock":
                    wait = now - (time.perf_counter() - t_start)
                    if wait > 0:
                        time.sleep(wait)
                continue
            shape, batch = sched.next_dispatch()
            frames, pidx = self._pack(batch, shape)
            self.forward_slot(frames, len(batch), pidx)
            if mode == "wallclock":
                now = time.perf_counter() - t_start
            else:
                now = now + service_time_s
            sched.complete(batch, now)
            last_done = now
            stats.record(shape, len(batch))
            latencies.extend(now - r.arrival for r in batch)

        makespan = max(last_done - (trace[0].arrival if trace else 0.0),
                       1e-9)
        lat = np.asarray(latencies) if latencies else np.zeros(1)
        return ServeReport(
            requests=n, served=sched.served,
            p50_s=float(np.percentile(lat, 50)),
            p99_s=float(np.percentile(lat, 99)),
            qps=sched.served / makespan,
            deadline_misses=sched.deadline_misses,
            misses_by_class=dict(sched.misses_by_class),
            max_queue_depth=sched.max_queue_depth,
            dispatches=stats.dispatches,
            mean_occupancy=(stats.real_lanes / stats.dispatches
                            if stats.dispatches else 0.0),
            stats=stats,
            latencies_s=latencies)
