"""Serving tier: continuous-batching policy inference under latency bounds.

The deployment half of the paper's claim — a trained IALS policy acting
in the real networked system for heavy request traffic. Three pieces
(the serving contract, docs/ARCHITECTURE.md §8):

- ``request.py`` — the request model (agent-region id, frame-stacked
  observation, deadline class) and a deterministic synthetic open-loop
  traffic generator: thousands of heterogeneous agent regions with
  ragged grid sizes and staggered episode phases.
- ``scheduler.py`` — ``SlotScheduler``: packs in-flight requests into
  fixed-shape slots, earliest-deadline-first, FIFO within a deadline
  class, no silent drops, exact deadline-miss accounting.
- ``server.py`` — ``PolicyServer``: drives packed slots through ONE
  jitted masked policy forward (``kernels/ops.py::serve_forward``) at a
  fixed slot shape, replays open-loop traces, and reports p50/p99
  latency + sustained QPS.
"""
from repro.serving.request import Request, TraceConfig, synthetic_trace
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import PolicyServer, ServeReport

__all__ = ["Request", "TraceConfig", "synthetic_trace", "SlotScheduler",
           "PolicyServer", "ServeReport"]
