"""Serving tier: continuous-batching policy inference under latency bounds.

The deployment half of the paper's claim — a trained IALS policy acting
in the real networked system for heavy request traffic. Four pieces
(the serving contract + overload contract, docs/ARCHITECTURE.md §8):

- ``request.py`` — the request model (agent-region id, frame-stacked
  observation, region burst size, per-region checkpoint index, deadline
  class) and a deterministic synthetic open-loop traffic generator:
  thousands of heterogeneous agent regions with ragged grid sizes and
  staggered episode phases, optionally bimodal in burst size;
  ``flood_trace`` densifies a window of it for flood chaos events.
- ``scheduler.py`` — ``SlotScheduler``: packs in-flight requests into
  fixed-shape slots, earliest-deadline-first, FIFO within a deadline
  class, no silent drops, exact deadline-miss accounting.
  ``BucketedSlotScheduler`` right-sizes every dispatch into the
  smallest compiled slot shape that admits it (``set_coarse`` collapses
  it to the largest shape under brownout); ``calibrate_buckets`` picks
  the shape set offline from a trace's burst-size distribution.
- ``overload.py`` — the policy layer the drop-free schedulers refuse to
  be: ``AdmissionController`` (bounded queue + deadline-feasibility
  rejection on an EWMA of measured dispatch latency), and
  ``BrownoutController`` (graceful degradation with hysteresis —
  sheds the loosest deadline classes first, never the tightest).
  Every shed is explicit and counted, never a silent miss.
- ``server.py`` — ``PolicyServer``: drives packed slots through a table
  of jitted masked policy forwards (``kernels/ops.py::serve_forward``,
  one compiled program per slot shape, warmed before the clock starts),
  optionally batching N checkpoints per dispatch
  (``kernels/ops.py::serve_forward_multi``), replays open-loop traces
  through the warming -> serving -> draining -> drained lifecycle with
  optional admission control and deterministic fault injection, hot
  reloads weights atomically behind an ABI + canary + bitwise-parity
  gate (``reload``; failures roll back), and reports p50/p99 latency +
  sustained QPS + padded-lane waste + shed/reload accounting
  (``ServeStats``).
"""
from repro.serving.overload import (AdmissionController, BrownoutController,
                                    DispatchLatencyModel, OverloadConfig)
from repro.serving.request import (BIMODAL_SIZES, BIMODAL_WEIGHTS, Request,
                                   TraceConfig, flood_trace, synthetic_trace)
from repro.serving.scheduler import (BucketedSlotScheduler, SlotScheduler,
                                     burst_sizes, calibrate_buckets,
                                     expected_padded_waste)
from repro.serving.server import (PolicyServer, ServeReport, ServeStats)

__all__ = ["Request", "TraceConfig", "synthetic_trace", "flood_trace",
           "BIMODAL_SIZES", "BIMODAL_WEIGHTS", "SlotScheduler",
           "BucketedSlotScheduler", "burst_sizes", "calibrate_buckets",
           "expected_padded_waste", "OverloadConfig", "AdmissionController",
           "BrownoutController", "DispatchLatencyModel", "PolicyServer",
           "ServeReport", "ServeStats"]
