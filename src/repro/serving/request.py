"""The serving request model + deterministic synthetic open-loop traffic.

A ``Request`` is one agent region asking for actions on one frame-stacked
observation before a deadline. Traffic is *open-loop*: arrival times are
fixed by the trace, not by how fast the server answers — the standard way
to measure a serving system honestly (a closed loop self-throttles and
hides queueing collapse).

``synthetic_trace`` models the north-star workload shape: ``n_regions``
heterogeneous agent regions with ragged sizes (a region of size k submits
k requests per episode tick — one per agent lane of its grid) and
staggered episode phases (each region's tick train has its own phase
offset, so bursts interleave instead of beating in sync). Every draw
comes from one seeded ``numpy.random.Generator``, so a trace is a pure
function of its config — the property tests replay exact traces.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One action request: ``frame`` is the (frame_stack * obs_dim,) f32
    observation the policy acts on; ``deadline`` is absolute
    (``arrival + deadline class bound``), which is what makes
    earliest-deadline-first scheduling FIFO within a class.

    ``size`` is the request's *size class* — the lane count of the region
    burst it arrived in (a size-k region submits k requests per tick, all
    sharing ``size=k``). It is what the bucketed scheduler's admission
    rule keys on: the smallest compiled slot shape >= ``size`` is the
    burst's admissible bucket (``scheduler.py::BucketedSlotScheduler``).
    ``policy`` is the region-family checkpoint index for cross-policy
    batched serving (``kernels/ops.py::serve_forward_multi``): one
    server, many checkpoints, one policy per region family."""
    rid: int            # unique, assigned in arrival order
    region: int         # agent-region id (which grid submitted it)
    klass: int          # deadline-class index into TraceConfig.classes_s
    arrival: float      # seconds since trace start (open-loop, fixed)
    deadline: float     # absolute seconds: arrival + classes_s[klass]
    frame: np.ndarray   # (frame_dim,) f32
    size: int = 1       # lanes in this request's region burst (size class)
    policy: int = 0     # region-family checkpoint index (multi-tenant)


@dataclass(frozen=True)
class TraceConfig:
    """Synthetic open-loop traffic shape. ``mean_rps`` is the aggregate
    offered load; each region ticks with a common period ``L / mean_rps``
    (L = total agent lanes) at its own random phase, submitting one
    request per lane per tick, so region size is exactly its traffic
    share and bursts stay staggered.

    ``region_size_weights`` (same length as ``region_sizes``; ``None`` =
    uniform) skews the region-size draw — the bimodal serving workload
    (many tiny regions plus a few large ones) is just a weighted size
    distribution, e.g. ``region_sizes=(1, 2, 4, 64)`` with weights
    ``(0.72, 0.18, 0.06, 0.04)``. ``n_policies`` > 1 assigns each region
    to a checkpoint family (``region % n_policies``) for cross-policy
    batched serving; every request carries its region's ``policy``."""
    n_regions: int = 64
    region_sizes: Tuple[int, ...] = (1, 2, 4, 8)   # ragged grid sizes
    mean_rps: float = 2000.0
    horizon_s: float = 1.0
    classes_s: Tuple[float, ...] = (0.005, 0.025, 0.1)
    class_mix: Tuple[float, ...] = (0.25, 0.5, 0.25)
    frame_dim: int = 41
    seed: int = 0
    region_size_weights: Optional[Tuple[float, ...]] = None
    n_policies: int = 1


#: The bimodal serving workload of the serve bench's bucketed-vs-single
#: rows: mostly tiny regions (1-4 lanes — each tick would ride a mostly
#: padded lane batch at one big compiled slot shape) plus a 4% family of
#: 64-lane regions that carry roughly half the request volume.
BIMODAL_SIZES: Tuple[int, ...] = (1, 2, 4, 64)
BIMODAL_WEIGHTS: Tuple[float, ...] = (0.72, 0.18, 0.06, 0.04)


def flood_trace(trace: List[Request], at_s: float, duration_s: float,
                multiplier: int) -> List[Request]:
    """Deterministic traffic spike: every request arriving in
    ``[at_s, at_s + duration_s)`` is duplicated to ``multiplier`` copies
    (same arrival, class, absolute deadline, frame, burst size — the
    extra copies model more lanes arriving at once), rids reassigned
    dense in arrival order. The trace transform behind the
    ``RequestFlood`` fault event
    (``distributed/fault_injection.py::RequestFlood``): open-loop
    arrivals stay open-loop, just ``multiplier``× denser over the
    window. A pure function of its inputs — two floods of the same
    trace are identical."""
    if multiplier < 1:
        raise ValueError(f"multiplier must be >= 1, got {multiplier}")
    out: List[Request] = []
    for req in trace:
        copies = (multiplier if at_s <= req.arrival < at_s + duration_s
                  else 1)
        out.extend([req] * copies)
    # input is arrival-sorted and copies are adjacent, so order is kept
    return [dataclasses.replace(req, rid=i) for i, req in enumerate(out)]


def synthetic_trace(cfg: TraceConfig,
                    frame_pool: Optional[np.ndarray] = None
                    ) -> List[Request]:
    """-> arrival-sorted requests, rids dense in arrival order.

    ``frame_pool`` (N, frame_dim) supplies real observation frames (e.g.
    engine-rollout states) sampled per request; absent, frames are unit
    normal — the forward cost is data-independent, so latency numbers are
    identical either way."""
    rng = np.random.default_rng(cfg.seed)
    weights = cfg.region_size_weights
    if weights is not None:
        if len(weights) != len(cfg.region_sizes):
            raise ValueError(
                f"region_size_weights has {len(weights)} entries for "
                f"{len(cfg.region_sizes)} region_sizes")
        w = np.asarray(weights, dtype=np.float64)
        weights = w / w.sum()
    sizes = rng.choice(np.asarray(cfg.region_sizes), size=cfg.n_regions,
                       p=weights)
    total_lanes = int(sizes.sum())
    period = total_lanes / cfg.mean_rps
    phases = rng.uniform(0.0, period, size=cfg.n_regions)
    mix = np.asarray(cfg.class_mix, dtype=np.float64)
    mix = mix / mix.sum()

    events = []          # (arrival, region, klass, lanes)
    for region in range(cfg.n_regions):
        t = float(phases[region])
        while t < cfg.horizon_s:
            klass = int(rng.choice(len(cfg.classes_s), p=mix))
            events.append((t, region, klass, int(sizes[region])))
            t += period
    events.sort(key=lambda e: (e[0], e[1]))

    out: List[Request] = []
    for arrival, region, klass, lanes in events:
        for _ in range(lanes):
            if frame_pool is not None:
                frame = np.asarray(
                    frame_pool[rng.integers(0, len(frame_pool))],
                    dtype=np.float32)
            else:
                frame = rng.standard_normal(cfg.frame_dim).astype(
                    np.float32)
            out.append(Request(rid=len(out), region=region, klass=klass,
                               arrival=arrival,
                               deadline=arrival + cfg.classes_s[klass],
                               frame=frame, size=lanes,
                               policy=region % cfg.n_policies))
    return out
