"""AdamW with global-norm clipping and schedules (optax is not available).

Moments are fp32 regardless of param dtype; updates are computed in fp32 and
cast back. State is a plain pytree so it shards/checkpoints like params.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.asarray(lr_val, jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros32, params),
                          nu=jax.tree_util.tree_map(zeros32, params))

    def update(grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g32
            v2 = b2 * v + (1 - b2) * g32 * g32
            u = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m2, v2

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr_t}
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics

    return Optimizer(init=init, update=update)
