"""Gradient compression for the cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarcest bandwidth (data-center
interconnect, not ICI). We compress the pod-axis gradient all-reduce with
int8 block quantisation + error feedback (Seide et al. 2014; 1-bit Adam
lineage): quantisation residuals are carried in the optimizer state and
re-added next step, so the compression bias does not accumulate — training
remains convergent while moving 4x fewer bytes across pods.

``compressed_psum(x, axis)`` is the drop-in for ``lax.psum`` under
``shard_map``; ``compress/decompress`` are also used standalone (tested
numerically in tests/test_grad_compress.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compress(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 values, per-block fp32 scales). Blocks along the flat dim."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(x: jax.Array, err: jax.Array,
                           block: int = 256):
    """Error-feedback compression: returns (q, scale, new_err) where
    new_err = (x + err) - decompress(q, scale)."""
    target = x.astype(jnp.float32) + err
    q, scale = compress(target, block)
    approx = decompress(q, scale, x.shape, jnp.float32)
    return q, scale, target - approx


def compressed_psum(x: jax.Array, axis: str, err: jax.Array,
                    block: int = 256):
    """int8-compressed psum over a (pod) mesh axis inside shard_map.

    Each participant quantises its local contribution (with error
    feedback), the int8 payload is summed in int32 (exact — no double
    quantisation error on the wire), and scales are combined conservatively
    by summing. Returns (approx psum result fp32, new error state).
    """
    q, scale, new_err = compress_with_feedback(x, err, block)
    q_sum = lax.psum(q.astype(jnp.int32), axis)       # wire: int8-sized data
    scale_max = lax.pmax(scale, axis)
    out = (q_sum.astype(jnp.float32) * scale_max[:, None]).reshape(-1)
    n = 1
    for s in x.shape:
        n *= s
    return out[:n].reshape(x.shape), new_err


def compression_ratio(shape, dtype=jnp.float32, block: int = 256) -> float:
    n = 1
    for s in shape:
        n *= s
    raw = n * jnp.dtype(dtype).itemsize
    comp = n * 1 + (n // block + 1) * 4
    return raw / comp
