"""Llama-3.2-11B-Vision [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attn image layers every 5th layer; vision frontend
stubbed (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0, cross_attn_period=5, n_vision_tokens=1024,
))
