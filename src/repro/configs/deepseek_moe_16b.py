"""DeepSeekMoE-16B [moe]: 28L d_model=2048 16H (MHA kv=16) d_expert=1408
vocab=102400 — 2 shared + 64 routed top-6 fine-grained experts, first layer
dense (d_ff=10944). [arXiv:2401.06066; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    n_routed_experts=64, n_shared_experts=2, moe_top_k=6, d_expert=1408,
    first_k_dense=1, dense_d_ff=10944,
))
