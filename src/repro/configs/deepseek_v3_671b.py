"""DeepSeek-V3-671B [moe]: 61L d_model=7168 128H MLA d_expert=2048
vocab=129280, MoE 1 shared + 256 routed top-8, first 3 layers dense
(d_ff=18432). MLA: q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128.
MTP head available via ``mtp=True`` override (off for dry-run cells).
[arXiv:2412.19437; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab_size=129280,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_routed_experts=256, n_shared_experts=1, moe_top_k=8, d_expert=2048,
    first_k_dense=3, dense_d_ff=18432,
    # 2-D expert parallelism: 256 experts over data x model = 1/device
    # (16/device over model alone = 81 GB of expert weights resident)
    moe_expert_axes="data_model",
))
