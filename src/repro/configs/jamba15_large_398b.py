"""Jamba-1.5-Large-398B [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every other
layer. No RoPE (Mamba carries position). [arXiv:2403.19887; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    use_rope=False, attn_period=8,
    n_routed_experts=16, moe_top_k=2, d_expert=24576, moe_period=2,
    sub_quadratic=True,
))
