"""ArchConfig: one declarative description drives init/forward/decode/sharding.

A config expands into a *layer plan*: an optional unrolled prologue plus a
repeating *pattern* of layers that is scanned ``n_groups`` times with stacked
parameters (scan-over-layers keeps HLO size and 512-way SPMD compile time flat
in depth). Every assigned architecture is expressible as (prologue, pattern).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerSpec:
    kind: str          # attn | mla | xattn | mamba | mlstm | slstm
    ffn: str = "gated_mlp"  # gated_mlp | mlp | moe | none


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "silu"
    norm: str = "rmsnorm"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    mlp_kind: str = "gated_mlp"      # gated_mlp | mlp (nemotron/whisper)
    tie_embeddings: bool = False
    sub_quadratic: bool = False      # eligible for long_500k
    # --- MoE ---
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    dense_d_ff: int = 0              # d_ff of non-MoE (prologue) layers
    first_k_dense: int = 0
    moe_period: int = 1              # within pattern: MoE on i % period == period-1
    capacity_factor: float = 1.25
    moe_impl: str = "ep"            # ep (shard_map expert-parallel) | gspmd
    moe_expert_axes: str = "model"  # model | data_model (2-D EP, huge E)
    lb_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- hybrid (jamba): 1 attn layer leading each group of attn_period ---
    attn_period: int = 0
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    # --- ssm (xlstm): 1 sLSTM closing each group of slstm_period ---
    slstm_period: int = 0
    mlstm_proj_factor: float = 2.0
    # --- vlm: 1 gated cross-attn layer leading each group ---
    cross_attn_period: int = 0
    n_vision_tokens: int = 0
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 0
    learned_pos: bool = False
    max_position_embeddings: int = 0
    # --- runtime knobs (hillclimb levers; overridable per cell) ---
    parallelism: str = "tp"          # tp | fsdp_only (model axis as extra
    #                                  FSDP/DP — right for <=8B dense archs)
    force_microbatches: int = 0      # 0 = use the shape cell default
    remat: str = "full"              # none | full | dots | names
    scan_layers: bool = True
    param_dtype: str = "bfloat16"
    mamba_chunk: int = 128
    rnn_chunk: int = 64
    attn_q_chunk: int = 1024
    attn_k_chunk: int = 1024

    # ------------------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]

    def layer_plan(self) -> Tuple[List[LayerSpec], List[LayerSpec], int]:
        """-> (prologue, pattern, n_groups); decoder stack only."""
        moe = self.n_routed_experts > 0
        if self.family in ("dense", "encdec"):
            return [], [LayerSpec("attn", self.mlp_kind)], self.n_layers
        if self.family == "vlm":
            per = self.cross_attn_period
            pattern = [LayerSpec("xattn", self.mlp_kind)] + \
                [LayerSpec("attn", self.mlp_kind)] * (per - 1)
            return [], pattern, self.n_layers // per
        if self.family == "moe":
            kind = "mla" if self.use_mla else "attn"
            pro = [LayerSpec(kind, "dense_mlp")] * self.first_k_dense
            n_moe = self.n_layers - self.first_k_dense
            pattern = [LayerSpec(kind, "moe")]
            return pro, pattern, n_moe
        if self.family == "hybrid":
            per = self.attn_period
            pattern = []
            for i in range(per):
                kind = "attn" if i == 0 else "mamba"
                ffn = "moe" if (moe and i % self.moe_period == self.moe_period - 1) \
                    else self.mlp_kind
                pattern.append(LayerSpec(kind, ffn))
            return [], pattern, self.n_layers // per
        if self.family == "ssm":
            per = self.slstm_period
            pattern = [LayerSpec("mlstm", "none")] * (per - 1) + \
                      [LayerSpec("slstm", "none")]
            return [], pattern, self.n_layers // per
        raise ValueError(self.family)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape cells (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    n_microbatches: int = 1


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train", n_microbatches=8),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason if skipped (per DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attn)"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one pattern group)."""
    _, pattern, _ = cfg.layer_plan()
    kw = dict(
        n_layers=len(pattern) + min(cfg.first_k_dense, 1),
        d_model=64, n_heads=4,
        n_kv_heads=4 if cfg.n_kv_heads == cfg.n_heads else 2,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        first_k_dense=min(cfg.first_k_dense, 1),
        param_dtype="float32",
        mamba_chunk=8, rnn_chunk=8, attn_q_chunk=16, attn_k_chunk=16,
    )
    if cfg.n_routed_experts:
        kw.update(n_routed_experts=8, moe_top_k=min(cfg.moe_top_k, 2),
                  d_expert=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  dense_d_ff=128 if cfg.dense_d_ff else 0)
    if cfg.use_mla:
        kw.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16)
    if cfg.family == "vlm":
        kw.update(n_vision_tokens=8)
    if cfg.family == "encdec":
        kw.update(n_encoder_layers=1, n_audio_frames=8,
                  max_position_embeddings=128)
    if cfg.family == "ssm":
        kw.update(n_heads=2, n_kv_heads=2)
    return cfg.with_overrides(name=cfg.name + "-reduced", **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import archs  # noqa: F401  (registers everything)
