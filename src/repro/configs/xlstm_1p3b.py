"""xLSTM-1.3B [ssm]: 48 blocks d_model=2048 4H vocab=50304 — mLSTM (matrix
memory) blocks with one sLSTM block per 8 (7:1 ratio). No FFN (d_ff=0);
blocks carry their own up/down projections. [arXiv:2405.04517; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    use_rope=False, slstm_period=8, mlstm_proj_factor=2.0,
    sub_quadratic=True,
    rnn_chunk=256,   # §Perf hillclimb #1: chunkwise mLSTM sweet spot
))
