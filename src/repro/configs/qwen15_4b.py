"""Qwen1.5-4B [dense]: 40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936.

QKV bias (Qwen1/1.5 signature), full MHA. [hf:Qwen/Qwen1.5-0.5B family; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0,

    # §Perf hillclimb #3: a 4B dense model on a 256-chip pod is over-TP'd;
    # using the model axis as extra FSDP removes the per-layer Megatron
    # all-reduces (t_coll 9.1s -> 1.2s measured on train_4k)
    parallelism="fsdp_only", force_microbatches=1,
))
