"""Imports every per-arch config module so registration side-effects run."""
from . import (qwen3_4b, qwen15_4b, llama3_405b, nemotron4_340b,  # noqa: F401
               llama32_vision_11b, jamba15_large_398b, deepseek_v3_671b,
               deepseek_moe_16b, whisper_base, xlstm_1p3b)
