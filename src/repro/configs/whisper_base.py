"""Whisper-base [audio enc-dec]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — conv frontend STUBBED (input_specs provides post-conv frame
embeddings, 1500 frames), learned positions, LayerNorm + GELU.
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865,
    act="gelu", norm="layernorm", mlp_kind="mlp",
    use_rope=False, learned_pos=True, max_position_embeddings=32768,
    n_audio_frames=1500,
))
