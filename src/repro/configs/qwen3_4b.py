"""Qwen3-4B [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm (per-head RMSNorm on q/k), GQA, tied embeddings, RoPE theta 1e6.
[hf:Qwen/Qwen3-8B family; hf-verified tier]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,

    # §Perf hillclimb #3: a 4B dense model on a 256-chip pod is over-TP'd;
    # using the model axis as extra FSDP removes the per-layer Megatron
    # all-reduces (t_coll 9.1s -> 1.2s measured on train_4k)
    parallelism="fsdp_only", force_microbatches=1,
))
