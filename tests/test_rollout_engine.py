"""Whole-horizon rollout engine: the unified engine's native ``rollout``
(scan, kernel-glue, and interpret-mode Pallas paths) is bitwise-identical
to scanning the per-tick fused ``step`` for every {gru, fnn} x {single
A=1, multi} x {traffic, warehouse} combination; stacked-weight AIP steps
equal the vmapped per-agent construction; the kernel-boundary codec
round-trips; the native batched multi-agent GS matches the vmapped scalar
GS exactly; ``noise_fn``/``step_det`` obey the protocol invariant;
stateless F-IALS freezes (only) the AIP state; PPO's bulk-noise rollout
reproduces the keyed path bit-for-bit."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.core import engine, ials, influence, multi_ials
from repro.envs.api import batch_env, env_rollout, horizon_noise
from repro.envs.traffic import (TrafficConfig,
                                make_batched_local_traffic_env,
                                make_batched_multi_traffic_env,
                                make_multi_traffic_env)
from repro.envs.warehouse import (WarehouseConfig,
                                  make_batched_local_warehouse_env,
                                  make_batched_multi_warehouse_env,
                                  make_multi_warehouse_env)

AGENTS4 = jnp.array([[0, 0], [1, 3], [2, 2], [4, 1]])

COMBOS = [(d, k, A) for d in ("traffic", "warehouse")
          for k in ("gru", "fnn") for A in (1, 3)]


def _bls(domain, **cfg_kw):
    if domain == "traffic":
        return make_batched_local_traffic_env(TrafficConfig(**cfg_kw))
    return make_batched_local_warehouse_env(WarehouseConfig(**cfg_kw))


def _aip(bls, kind, A, seed=0):
    acfg = influence.AIPConfig(kind=kind, d_in=bls.spec.dset_dim,
                               n_out=bls.spec.n_influence, hidden=8,
                               stack=2)
    if A == 1:
        return acfg, influence.init_aip(acfg, jax.random.PRNGKey(seed))
    return acfg, jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(seed), A))


def _engine(domain, kind, n_agents=1, **kw):
    bls = _bls(domain)
    acfg, params = _aip(bls, kind, n_agents)
    return bls, engine.make_unified_ials(bls, params, acfg,
                                         n_agents=n_agents, **kw)


def _acts_keys(env, B, T, n_agents, seed=1):
    key = jax.random.PRNGKey(seed)
    shape = (T, B, n_agents) if n_agents > 1 else (T, B)
    acts = jax.random.randint(key, shape, 0, env.spec.n_actions)
    return acts, jax.random.split(jax.random.PRNGKey(seed + 1), T)


def _scan_step(benv):
    """The per-tick fused engine: a jitted scan of ``step`` — the
    baseline every whole-horizon path must reproduce bitwise."""

    def step(carry, xs):
        a, k = xs
        s, _, r, _ = benv.step(carry, a, k)
        return s, r

    return jax.jit(lambda s, a, k: jax.lax.scan(step, s, (a, k)))


def _trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        jnp.array_equal(x, y) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# whole-horizon rollout == scan of the per-tick fused step (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("domain,kind,A", COMBOS)
def test_whole_horizon_matches_per_tick_engine(domain, kind, A):
    """The unified engine's env_rollout (native rollout override) ==
    scanning the per-tick fused step, for every backbone x multiplicity
    x domain combination."""
    _, env = _engine(domain, kind, A)
    B, T = 4, 11
    s0 = env.reset(jax.random.PRNGKey(1), B)
    acts, keys = _acts_keys(env, B, T, A)
    sw, rw = jax.jit(
        lambda s, a, k: env_rollout(env, s, a, k))(s0, acts, keys)
    ss, rs = _scan_step(env)(s0, acts, keys)
    assert rw.shape == ((T, B, A) if A > 1 else (T, B))
    assert jnp.array_equal(rw, rs)
    assert _trees_equal(sw, ss)


@pytest.mark.parametrize("domain,kind,A", COMBOS)
def test_kernel_glue_route_matches_scan(domain, kind, A):
    """use_horizon_kernel=True exercises the full kernels.ops rollout
    glue (agent-major lane fold, leaf flatten/encode, tick/dset
    closures, stacked-weight plumbing) — off-TPU that lands on the
    stacked oracle, which must stay bitwise with the scan. Covers all
    four backbone x multiplicity combinations."""
    bls = _bls(domain)
    acfg, params = _aip(bls, kind, A)
    env_k = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=True)
    env_s = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=False)
    B, T = 4, 9
    s0 = env_k.reset(jax.random.PRNGKey(6), B)
    acts, keys = _acts_keys(env_k, B, T, A, seed=6)
    sk, rk = jax.jit(env_k.rollout)(s0, acts, keys)
    ss, rs = jax.jit(env_s.rollout)(s0, acts, keys)
    assert jnp.array_equal(rk, rs)
    assert _trees_equal(sk, ss)


@pytest.mark.parametrize("domain,kind", [
    ("traffic", "gru"), ("traffic", "fnn"),
    ("warehouse", "gru"), ("warehouse", "fnn"),
])
def test_interpret_kernel_matches_scan(domain, kind, monkeypatch):
    """The actual Pallas rollout kernels (interpret mode: the real
    (A·B-blocks, T) grid, BlockSpecs, per-agent weight indexing, VMEM
    scratch) reproduce the scan engine bitwise — stacked weights
    included (A=2)."""
    from repro.kernels import ops

    name = "ials_rollout_multi" if kind == "gru" else "fnn_rollout"
    orig = getattr(ops, name)

    def forced(*args, **kw):
        kw["interpret"] = True
        return orig(*args, **kw)

    monkeypatch.setattr(ops, name, forced)
    A = 2
    bls = _bls(domain)
    acfg, params = _aip(bls, kind, A)
    env_k = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=True)
    env_s = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=False)
    s0 = env_k.reset(jax.random.PRNGKey(1), 4)
    acts, keys = _acts_keys(env_k, 4, 7, A)
    # both sides eager: the interpret-mode kernel cannot be jitted into
    # the same program as the scan, and XLA fusion moves float results
    # by 1 ulp between program shapes — eager-to-eager is exact
    sk, rk = env_k.rollout(s0, acts, keys)
    ss, rs = env_s.rollout(s0, acts, keys)
    assert jnp.array_equal(rk, rs)
    assert _trees_equal(sk.ls_state, ss.ls_state)
    assert jnp.array_equal(sk.aip_state, ss.aip_state)


# ---------------------------------------------------------------------------
# stacked-weight AIP steps == vmapped per-agent construction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gru", "fnn"])
def test_stacked_weights_match_vmapped_per_agent(kind):
    """The stacked-weight multi-agent AIP tick (the formulation each
    whole-horizon kernel lane block runs against its agent's weight
    slice) equals vmapping the single-agent fused step over agents —
    and equals whatever formulation ``influence.step_sample_multi``
    (the engine's per-tick path) actually dispatches — weights, state,
    and the drawn u bits alike."""
    from repro.kernels import ref as kref

    A, B, D, M = 3, 5, 7, 4
    acfg = influence.AIPConfig(kind=kind, d_in=D, n_out=M, hidden=8,
                               stack=2)
    params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(0), A))
    key = jax.random.PRNGKey(1)
    d = jax.random.normal(key, (B, A, D))
    state = jax.random.normal(
        jax.random.PRNGKey(3),
        (B, A) + influence.init_state(acfg).shape) * 0.4
    bits = jax.random.bits(jax.random.PRNGKey(2), (B, A, M), jnp.uint32)

    if kind == "gru":                       # the kernels' stacked math
        st_s, lg_s, u_s = kref.aip_step_multi_ref(
            d, state, params["gru"]["wx"], params["gru"]["wh"],
            params["gru"]["b"], params["head"]["w"], params["head"]["b"],
            bits)
    else:                                   # fnn: the engine IS stacked
        lg_s, st_s, u_s = influence.step_sample_multi(params, acfg,
                                                      state, d, bits)

    lg_v, st_v, u_v = jax.vmap(
        lambda p, h, dd, bt: influence.step_sample(p, acfg, h, dd, bt),
        in_axes=(0, 1, 1, 1), out_axes=(1, 1, 1))(params, state, d, bits)
    assert jnp.allclose(lg_s, lg_v, atol=1e-6)
    assert jnp.allclose(st_s, st_v, atol=1e-6)
    assert jnp.array_equal(u_s, u_v)

    # the engine's dispatch agrees with both formulations
    lg_e, st_e, u_e = influence.step_sample_multi(params, acfg, state, d,
                                                  bits)
    assert jnp.allclose(lg_e, lg_v, atol=1e-6)
    assert jnp.allclose(st_e, st_v, atol=1e-6)
    assert jnp.array_equal(u_e, u_v)

    lg2_s, _ = influence.step_multi(params, acfg, state, d)
    lg2_v, _ = jax.vmap(lambda p, h, dd: influence.step(p, acfg, h, dd),
                        in_axes=(0, 1, 1), out_axes=(1, 1))(params, state,
                                                            d)
    assert jnp.allclose(lg2_s, lg2_v, atol=1e-6)


# ---------------------------------------------------------------------------
# kernel-boundary codec round-trip (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 7), n=st.integers(1, 9))
def test_kernel_codec_round_trip(seed, n):
    """bool/int8 leaves encode to int32 and decode back bit-exactly, and
    already-wide leaves pass through untouched — for any leaf mix."""
    from repro.envs.api import KERNEL_ENC_DTYPES, kernel_codec

    key = jax.random.PRNGKey(seed)
    tree = {
        "b": jax.random.bernoulli(key, 0.4, (n, 3)),
        "i8": jax.random.randint(key, (n,), -7, 7).astype(jnp.int8),
        "i32": jax.random.randint(key, (n, 2), 0, 100),
        "f32": jax.random.normal(key, (n, 4)),
        "u32": jax.random.bits(key, (n,), jnp.uint32),
    }
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    dtypes = tuple(l.dtype for l in leaves)
    enc, dec = kernel_codec(treedef, dtypes)
    encoded = enc(leaves)
    for e in encoded:
        assert e.dtype not in KERNEL_ENC_DTYPES
    for e, l in zip(encoded, leaves):
        if l.dtype in KERNEL_ENC_DTYPES:
            assert e.dtype == jnp.int32
        else:
            assert e.dtype == l.dtype
    back = dec(encoded)
    assert _trees_equal(back, tree)
    assert all(b.dtype == l.dtype
               for b, l in zip(jax.tree_util.tree_leaves(back), leaves))


def test_kernel_lane_blocking():
    """block_b splits the batch across the kernel's parallel grid axis;
    results must not depend on the blocking."""
    from repro.kernels.aip_step import aip_rollout
    from repro.kernels.ref import ials_rollout_ref

    H, M, Dd = 8, 4, 12
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 8)
    B, T = 6, 5
    wx = jax.random.normal(ks[0], (Dd, 3 * H)) * 0.2
    wh = jax.random.normal(ks[1], (H, 3 * H)) * 0.2
    b = jax.random.normal(ks[2], (3 * H,)) * 0.1
    hw = jax.random.normal(ks[3], (H, M)) * 0.2
    hb = jax.random.normal(ks[4], (M,)) * 0.1
    h0 = jax.random.normal(ks[5], (B, H)) * 0.5
    ls = (jax.random.normal(ks[6], (B, Dd)),)
    acts = jnp.zeros((T, B), jnp.int32)
    bits = jax.random.bits(ks[7], (T, B, M), jnp.uint32)

    def dset_fn(leaves, a):
        return leaves[0]

    def tick_fn(leaves, a, u, noise):
        # toy LS: state drifts by the drawn u (padded to Dd), reward
        # counts the u bits — enough to couple AIP and "LS" both ways
        x = leaves[0]
        x2 = x + jnp.pad(u, ((0, 0), (0, Dd - M)))
        return (x2,), u.sum(-1)

    outs = [aip_rollout(ls, h0, wx, wh, b, hw, hb, acts, bits, (),
                        tick_fn=tick_fn, dset_fn=dset_fn, block_b=bb,
                        interpret=True) for bb in (None, 2, 3)]
    ref = ials_rollout_ref(ls, h0, wx, wh, b, hw, hb, acts, bits, (),
                           tick_fn=tick_fn, dset_fn=dset_fn)
    for (lsk, hk, rk) in outs:
        assert jnp.allclose(lsk[0], ref[0][0], atol=1e-6)
        assert jnp.allclose(hk, ref[1], atol=1e-6)
        assert jnp.array_equal(rk, ref[2])


# ---------------------------------------------------------------------------
# native batched multi-agent GS == vmapped scalar multi-agent GS
# ---------------------------------------------------------------------------

def _gs_pair(domain):
    if domain == "traffic":
        cfg = TrafficConfig(p_in=0.0, ext_influence=True)
        return (make_multi_traffic_env(cfg, AGENTS4),
                make_batched_multi_traffic_env(cfg, AGENTS4))
    cfg = WarehouseConfig(p_item=0.0)
    return (make_multi_warehouse_env(cfg, AGENTS4),
            make_batched_multi_warehouse_env(cfg, AGENTS4))


@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_batched_multi_gs_matches_vmapped_scalar(domain):
    """With the internal randomness switched off (p=0) the native batched
    multi-agent GS must agree with the vmapped scalar GS exactly — same
    state, obs, rewards, u, and d-sets."""
    gs, bgs = _gs_pair(domain)
    vgs = batch_env(gs)
    bstep, vstep = jax.jit(bgs.step), jax.jit(vgs.step)
    key = jax.random.PRNGKey(8)
    B, T = 5, 4
    state = bgs.reset(key, B)
    for t in range(T):
        key, ka, ks = jax.random.split(key, 3)
        a = jax.random.randint(ka, (B, 4), 0, gs.spec.n_actions)
        s2, obs, r, info = bstep(state, a, ks)
        ws2, wobs, wr, winfo = vstep(state, a, ks)
        assert jnp.array_equal(obs, wobs)
        assert jnp.allclose(r, wr, atol=1e-6)
        for k in ("u", "dset", "dset_full"):
            assert jnp.array_equal(info[k], winfo[k]), k
        assert _trees_equal(s2, ws2)
        state = s2
    assert jnp.array_equal(bgs.observe(state), vgs.observe(state))


def test_batched_multi_gs_inflow_rate():
    """The bulk-noise path really injects: boundary inflow at p_in=0.5
    shows up at a plausible rate on the batched traffic GS."""
    cfg = TrafficConfig(p_in=0.5)
    bgs = make_batched_multi_traffic_env(
        cfg, jnp.array([[0, 0]], jnp.int32))
    key = jax.random.PRNGKey(9)
    state = bgs.reset(key, 8)
    total = 0.0
    for t in range(20):
        key, k = jax.random.split(key)
        state, _, _, info = jax.jit(bgs.step)(
            state, jnp.zeros((8, 1), jnp.int32), k)
        total += float(info["u"].mean())
    assert total / 20 > 0.05       # corner cell: 2 boundary lanes of 4


@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_batched_gs_step_det_invariant(domain):
    """step(s, a, k) == step_det(s, a, noise_fn(k, B)) on the batched
    multi-agent GS (full randomness on)."""
    if domain == "traffic":
        cfg = TrafficConfig()
        bgs = make_batched_multi_traffic_env(cfg, AGENTS4)
    else:
        cfg = WarehouseConfig()
        bgs = make_batched_multi_warehouse_env(cfg, AGENTS4)
    key = jax.random.PRNGKey(10)
    B = 4
    state = bgs.reset(key, B)
    a = jax.random.randint(key, (B, 4), 0, bgs.spec.n_actions)
    k = jax.random.PRNGKey(11)
    got = jax.jit(bgs.step)(state, a, k)
    want = jax.jit(bgs.step_det)(state, a, bgs.noise_fn(k, B))
    assert _trees_equal(got, want)


def test_env_rollout_bulk_noise_path_on_batched_gs():
    """The batched GS has noise_fn/step_det but no rollout override, so
    env_rollout takes the bulk-noise scan — bitwise vs scanning step."""
    bgs = make_batched_multi_traffic_env(TrafficConfig(), AGENTS4)
    key = jax.random.PRNGKey(12)
    B, T = 4, 8
    s0 = bgs.reset(key, B)
    acts = jax.random.randint(key, (T, B, 4), 0, 2)
    keys = jax.random.split(key, T)
    sw, rw = jax.jit(
        lambda s, a, k: env_rollout(bgs, s, a, k))(s0, acts, keys)
    ss, rs = _scan_step(bgs)(s0, acts, keys)
    assert jnp.array_equal(rw, rs)
    assert _trees_equal(sw, ss)


# ---------------------------------------------------------------------------
# stateless F-IALS
# ---------------------------------------------------------------------------

def test_stateless_f_ials_bitwise_and_frozen():
    """Stateless F-IALS: trajectories bit-identical to the stateful
    F-IALS (the marginal sampler never reads the AIP state), the state
    leaf keeps its shape (parity) but stays frozen at init."""
    bls = _bls("warehouse")
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=12, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    kw = dict(fixed_marginal=0.3)
    env_st = ials.make_batched_ials(bls, params, acfg, **kw)
    env_sl = ials.make_batched_ials(bls, params, acfg, stateless=True,
                                    **kw)
    key = jax.random.PRNGKey(13)
    B, T = 5, 12
    s0 = env_st.reset(key, B)
    acts = jax.random.randint(key, (T, B), 0, 5)
    keys = jax.random.split(key, T)
    s_st, r_st = jax.jit(env_st.rollout)(s0, acts, keys)
    s_sl, r_sl = jax.jit(env_sl.rollout)(s0, acts, keys)
    assert jnp.array_equal(r_st, r_sl)
    assert _trees_equal(s_st.ls_state, s_sl.ls_state)
    # same leaf shape (state parity), but frozen at init vs advanced
    assert s_sl.aip_state.shape == s_st.aip_state.shape
    assert jnp.array_equal(s_sl.aip_state, s0.aip_state)
    assert float(jnp.abs(s_st.aip_state - s0.aip_state).max()) > 0


def test_stateless_multi_f_ials_frozen():
    bls = _bls("traffic")
    A = 3
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=4, hidden=8)
    params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(1), A))
    env = multi_ials.make_batched_multi_ials(bls, params, acfg, A,
                                             fixed_marginal=0.2,
                                             stateless=True)
    key = jax.random.PRNGKey(14)
    s = env.reset(key, 4)
    s2, _, _, info = jax.jit(env.step)(s, jnp.zeros((4, A), jnp.int32),
                                       key)
    assert jnp.array_equal(s2.aip_state, s.aip_state)
    assert info["u"].shape == (4, A, 4)


def test_stateless_requires_marginal():
    bls = _bls("traffic")
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=4, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="stateless"):
        ials.make_batched_ials(bls, params, acfg, stateless=True)
    with pytest.raises(ValueError, match="stateless"):
        multi_ials.make_batched_multi_ials(bls, params, acfg, 2,
                                           stateless=True)


# ---------------------------------------------------------------------------
# PPO consumes the whole-horizon layer bitwise
# ---------------------------------------------------------------------------

def test_ppo_bulk_noise_rollout_matches_keyed_path():
    """PPO's rollout with noise_fn/step_det (bulk draws outside the scan)
    produces the exact batch the keyed per-tick path produced."""
    from repro.rl import ppo

    bls = _bls("warehouse")
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=12, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(2))
    env = ials.make_batched_ials(bls, params, acfg)
    legacy = env._replace(step_det=None, noise_fn=None, rollout=None)
    cfg = ppo.PPOConfig(obs_dim=bls.spec.obs_dim, n_actions=5, n_envs=4,
                        rollout_len=6, episode_len=4, hidden=16)
    key = jax.random.PRNGKey(15)
    pol = ppo.init_policy(cfg, key)
    rs0 = ppo.init_rollout_state(env, cfg, key)
    rs_a, batch_a, v_a = ppo.rollout(env, cfg, pol, rs0, key)
    rs_b, batch_b, v_b = ppo.rollout(legacy, cfg, pol, rs0, key)
    assert _trees_equal(batch_a, batch_b)
    assert _trees_equal(rs_a, rs_b)
    assert jnp.array_equal(v_a, v_b)
