"""Sharding rules + an end-to-end multi-device dry-run (subprocess: the
device-count override must not leak into other tests)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import lm

SRC = str(Path(__file__).resolve().parents[1] / "src")


class _FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted."""
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


@pytest.mark.slow
def test_param_specs_respect_divisibility_all_archs():
    from repro.distributed.sharding import param_specs
    mesh = _FakeMesh()
    for arch in list_configs():
        cfg = get_config(arch)
        shapes = lm.param_shapes(cfg)
        specs = param_specs(shapes, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(shapes),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))):
            assert len(spec) <= len(leaf.shape), (arch, path)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert dim % n == 0, (arch, path, dim, ax)


def test_opt_state_moments_widen_over_pod():
    from repro.distributed.sharding import opt_state_specs, param_specs
    from repro.optim.adamw import adamw
    mesh = _FakeMesh()
    cfg = get_config("qwen3-4b")
    shapes = lm.param_shapes(cfg)
    pspecs = param_specs(shapes, mesh)
    opt = adamw(1e-4)
    oshapes = jax.eval_shape(opt.init, shapes)
    ospecs = opt_state_specs(oshapes, mesh, pspecs)
    # at least one moment leaf picked up the "pod" axis (ZeRO-1)
    axes_used = set()
    for s in jax.tree_util.tree_leaves(
            ospecs.mu, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)):
        for a in tuple(s):
            if isinstance(a, tuple):
                axes_used.update(a)
            elif a:
                axes_used.add(a)
    assert "pod" in axes_used


@pytest.mark.slow
def test_multi_device_dryrun_cell():
    """Real multi-device lower+compile for one cell on a small mesh, in a
    subprocess with a forced host device count."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = (
            lambda multi_pod=False: jax.make_mesh((2, 2, 2),
                                                  ("pod", "data", "model"))
            if multi_pod else jax.make_mesh((4, 2), ("data", "model")))
        from repro.launch.dryrun import run_cell
        r1 = run_cell("whisper-base", "train_4k", "pod1")
        r2 = run_cell("whisper-base", "decode_32k", "pod2")
        print(json.dumps({"pod1": r1["status"], "pod2": r2["status"],
                          "coll": r1["hlo"]["collective_bytes_total"] > 0}))
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["pod1"] == "ok" and res["pod2"] == "ok"
    assert res["coll"]  # the mesh actually communicates


# ---------------------------------------------------------------------------
# IALS partition rules (the unified engine / PPO rollout state)
# ---------------------------------------------------------------------------

class _HostMesh:
    """Duck-typed host mesh of n simulated devices, (data, model)."""
    axis_names = ("data", "model")

    def __init__(self, data, model=1):
        self.shape = {"data": data, "model": model}


_HOST_MESHES = [_HostMesh(1), _HostMesh(2), _HostMesh(4, 2),
                _HostMesh(8)]                    # 1 / 2 / 8 devices


def _engine_state_shapes(domain, backbone, A, B):
    import jax.numpy as jnp
    from repro.core import engine, influence
    from repro.envs.traffic import (TrafficConfig,
                                    make_batched_local_traffic_env)
    from repro.envs.warehouse import (WarehouseConfig,
                                      make_batched_local_warehouse_env)
    bls = (make_batched_local_traffic_env(TrafficConfig())
           if domain == "traffic"
           else make_batched_local_warehouse_env(WarehouseConfig()))
    acfg = influence.AIPConfig(
        kind=backbone, d_in=bls.spec.dset_dim, n_out=bls.spec.n_influence,
        hidden=64, stack=8 if backbone == "fnn" else 1)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if A > 1:
        aip = jax.eval_shape(
            lambda ks: jax.vmap(lambda k: influence.init_aip(acfg, k))(ks),
            jax.ShapeDtypeStruct((A, 2), jnp.uint32))
    else:
        aip = jax.eval_shape(lambda k: influence.init_aip(acfg, k), key_s)
    env = engine.make_unified_ials(bls, aip, acfg, n_agents=A)
    state = jax.eval_shape(lambda k: env.reset(k, B), key_s)
    return state, aip


def _assert_divides(leaf, spec, mesh, ctx):
    assert len(tuple(spec)) <= len(leaf.shape), ctx
    for dim, ax in zip(leaf.shape, tuple(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert dim % n == 0, (ctx, dim, ax)


@pytest.mark.parametrize("domain,backbone,A",
                         [("traffic", "fnn", 1), ("traffic", "gru", 25),
                          ("warehouse", "gru", 36),
                          ("warehouse", "fnn", 36)])
def test_ials_state_specs_divide_or_replicate(domain, backbone, A):
    """Every engine state leaf gets a PartitionSpec that divides its dims
    (or cleanly falls back to replication) on 1/2/8 simulated host
    devices, for A in {1, 25, 36}."""
    from repro.distributed import sharding as shd
    B = 16
    state, aip = _engine_state_shapes(domain, backbone, A, B)
    for mesh in _HOST_MESHES:
        specs = shd.ials_state_specs(state, mesh, A)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(state),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))):
            _assert_divides(leaf, spec, mesh,
                            (domain, backbone, A, mesh.shape, path))
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(aip),
                jax.tree_util.tree_leaves_with_path(
                    shd.ials_aip_param_specs(aip, mesh, A, batch=B),
                    is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))):
            _assert_divides(leaf, spec, mesh,
                            (domain, backbone, A, mesh.shape, path))


def test_ials_lanes_shard_and_agents_coshard():
    """On a mesh whose axes divide: env lanes take the data axes, the
    agent axis and the stacked AIP leading dim co-shard on "model"; when
    A does not divide "model", both fall back to replication."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    mesh = _HostMesh(4, 2)
    lane, agent_ax = shd.ials_lane_axes(16, 4, mesh)
    assert lane == ("data",) and agent_ax == "model"
    state, aip = _engine_state_shapes("traffic", "gru", 4, 16)
    sspec = shd.ials_state_pspec(state.aip_state, mesh, 4)
    assert tuple(sspec)[:2] == ("data", "model")
    aip_specs = shd.ials_aip_param_specs(aip, mesh, 4, batch=16)
    assert tuple(aip_specs["gru"]["wx"])[0] == "model"   # co-sharded
    # A=25 does not divide model=2 -> agents replicate, lanes absorb model
    lane25, agent25 = shd.ials_lane_axes(16, 25, mesh)
    assert agent25 is None and lane25 == ("data", "model")
    state25, aip25 = _engine_state_shapes("traffic", "gru", 25, 16)
    assert tuple(shd.ials_state_pspec(state25.aip_state, mesh, 25)) \
        == (("data", "model"),)
    specs25 = shd.ials_aip_param_specs(aip25, mesh, 25, batch=16)
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs25, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec)))
    # trivial mesh: everything replicates
    for leaf in jax.tree_util.tree_leaves(
            shd.ials_state_specs(state, _HostMesh(1), 4),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)):
        assert leaf == P()


def test_ials_policy_specs_replicated():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.rl import ppo
    cfg = ppo.PPOConfig(obs_dim=6, n_actions=3)
    params = jax.eval_shape(
        lambda k: ppo.init_policy(cfg, k),
        jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    specs = shd.ials_replicated_specs(params)
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec)))


def test_ials_sharded_policy_rollout_bitwise_parity():
    """The acceptance bar: PPO's whole rollout (the engine's fused
    ``policy_rollout`` route) on a forced 8-host-device mesh is
    bitwise-equal to the single-device program, for both domains x both
    backbones. Lane sharding is pure data parallelism — no reduction
    order changes — so exact equality is required, not approximate."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import engine, influence
        from repro.envs.traffic import (TrafficConfig,
                                        make_batched_local_traffic_env)
        from repro.envs.warehouse import (WarehouseConfig,
                                          make_batched_local_warehouse_env)
        from repro.launch.mesh import make_host_mesh
        from repro.rl import ppo

        assert len(jax.devices()) == 8
        mesh = make_host_mesh(model=2)          # (4, 2) (data, model)
        A, B, T = 4, 8, 8
        for domain, backbone in [("traffic", "fnn"), ("traffic", "gru"),
                                 ("warehouse", "gru"),
                                 ("warehouse", "fnn")]:
            bls, fs = ((make_batched_local_traffic_env(TrafficConfig()), 1)
                       if domain == "traffic" else
                       (make_batched_local_warehouse_env(
                           WarehouseConfig()), 8))
            acfg = influence.AIPConfig(
                kind=backbone, d_in=bls.spec.dset_dim,
                n_out=bls.spec.n_influence, hidden=16,
                stack=8 if backbone == "fnn" else 1)
            key = jax.random.PRNGKey(0)
            ka, kp, ks, kr = jax.random.split(key, 4)
            aip = jax.vmap(lambda k: influence.init_aip(acfg, k))(
                jax.random.split(ka, A))
            kw = dict(n_agents=A, use_horizon_kernel=True)
            env1 = engine.make_unified_ials(bls, aip, acfg, **kw)
            env2 = engine.make_unified_ials(bls, aip, acfg, mesh=mesh,
                                            **kw)
            assert env1.policy_rollout is not None
            pcfg = ppo.PPOConfig(
                obs_dim=bls.spec.obs_dim, n_actions=bls.spec.n_actions,
                frame_stack=fs, n_envs=B, rollout_len=T, episode_len=T,
                n_agents=A)
            pol = ppo.init_policy(pcfg, kp)
            rs1 = ppo.init_rollout_state(env1, pcfg, ks)
            rs2 = ppo.init_rollout_state(env2, pcfg, ks, mesh=mesh)

            def run(env, rs):
                f = jax.jit(lambda p, r, k: ppo.rollout(env, pcfg, p,
                                                        r, k))
                return f(pol, rs, kr)

            o1, o2 = run(env1, rs1), run(env2, rs2)
            mism = [p for (p, a), (_, b) in zip(
                        jax.tree_util.tree_leaves_with_path(o1),
                        jax.tree_util.tree_leaves_with_path(o2))
                    if not np.array_equal(np.asarray(a), np.asarray(b))]
            assert not mism, (domain, backbone, mism)
            print(f"parity ok: {domain}/{backbone}")
        print("ALL_BITWISE_EQUAL")
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_BITWISE_EQUAL" in out.stdout


def test_cache_specs_long_context_batch1():
    """batch-1 long-context decode shards the cache sequence dim on data."""
    from repro.distributed.sharding import cache_specs
    cfg = get_config("xlstm-1.3b")
    mesh = _FakeMesh()
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 1024))
    specs = cache_specs(cache, mesh, 1)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) > 0  # well-formed for a state-only (SSM) cache
