"""Sharding rules + an end-to-end multi-device dry-run (subprocess: the
device-count override must not leak into other tests)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import lm

SRC = str(Path(__file__).resolve().parents[1] / "src")


class _FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted."""
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


@pytest.mark.slow
def test_param_specs_respect_divisibility_all_archs():
    from repro.distributed.sharding import param_specs
    mesh = _FakeMesh()
    for arch in list_configs():
        cfg = get_config(arch)
        shapes = lm.param_shapes(cfg)
        specs = param_specs(shapes, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(shapes),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))):
            assert len(spec) <= len(leaf.shape), (arch, path)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert dim % n == 0, (arch, path, dim, ax)


def test_opt_state_moments_widen_over_pod():
    from repro.distributed.sharding import opt_state_specs, param_specs
    from repro.optim.adamw import adamw
    mesh = _FakeMesh()
    cfg = get_config("qwen3-4b")
    shapes = lm.param_shapes(cfg)
    pspecs = param_specs(shapes, mesh)
    opt = adamw(1e-4)
    oshapes = jax.eval_shape(opt.init, shapes)
    ospecs = opt_state_specs(oshapes, mesh, pspecs)
    # at least one moment leaf picked up the "pod" axis (ZeRO-1)
    axes_used = set()
    for s in jax.tree_util.tree_leaves(
            ospecs.mu, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)):
        for a in tuple(s):
            if isinstance(a, tuple):
                axes_used.update(a)
            elif a:
                axes_used.add(a)
    assert "pod" in axes_used


@pytest.mark.slow
def test_multi_device_dryrun_cell():
    """Real multi-device lower+compile for one cell on a small mesh, in a
    subprocess with a forced host device count."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = (
            lambda multi_pod=False: jax.make_mesh((2, 2, 2),
                                                  ("pod", "data", "model"))
            if multi_pod else jax.make_mesh((4, 2), ("data", "model")))
        from repro.launch.dryrun import run_cell
        r1 = run_cell("whisper-base", "train_4k", "pod1")
        r2 = run_cell("whisper-base", "decode_32k", "pod2")
        print(json.dumps({"pod1": r1["status"], "pod2": r2["status"],
                          "coll": r1["hlo"]["collective_bytes_total"] > 0}))
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["pod1"] == "ok" and res["pod2"] == "ok"
    assert res["coll"]  # the mesh actually communicates


def test_cache_specs_long_context_batch1():
    """batch-1 long-context decode shards the cache sequence dim on data."""
    from repro.distributed.sharding import cache_specs
    cfg = get_config("xlstm-1.3b")
    mesh = _FakeMesh()
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 1024))
    specs = cache_specs(cache, mesh, 1)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) > 0  # well-formed for a state-only (SSM) cache
