"""The docs drift gate (tools/docs_check.py) passes on the tree and
actually detects drift (so ``make docs-check`` keeps meaning something)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from tools import docs_check


def test_docs_check_passes_on_tree():
    assert docs_check.run_checks() == []


def test_makefile_targets_include_documented_ones():
    targets = docs_check._makefile_targets()
    assert {"test-fast", "test-all", "docs-check",
            "bench-check"} <= targets


def test_module_resolution():
    assert docs_check._module_exists("repro.launch.rl_train")
    assert docs_check._module_exists("benchmarks.run")
    assert not docs_check._module_exists("repro.launch.no_such_module")


def test_symbol_refs_resolve():
    """The ARCHITECTURE dispatch table's `file.py::symbol` cells resolve
    to real top-level symbols, and the checker actually reads them."""
    p = docs_check._resolve_doc_path("kernels/aip_step.py")
    assert p is not None
    names = docs_check._top_level_names(p)
    assert {"aip_rollout_multi", "fnn_rollout", "aip_rollout",
            "aip_step"} <= names
    assert "no_such_symbol" not in names
    assert docs_check._resolve_doc_path("kernels/no_such_file.py") is None


def test_symbol_checker_detects_drift(tmp_path, monkeypatch):
    """A doc quoting a dead `file.py::symbol` trips the gate."""
    doc = tmp_path / "README.md"
    doc.write_text("see `kernels/aip_step.py::definitely_not_a_symbol`\n")
    (tmp_path / "docs").mkdir()
    monkeypatch.setattr(docs_check, "DOC_FILES", ("README.md",))
    real_repo = docs_check.REPO
    monkeypatch.setattr(docs_check, "REPO", tmp_path)
    monkeypatch.setattr(
        docs_check, "_resolve_doc_path",
        lambda rel, _r=real_repo: next(
            (p for root in docs_check._SYMBOL_ROOTS
             if (p := _r / root / rel).is_file()), None))
    errs = docs_check.stale_symbol_refs()
    assert len(errs) == 1 and "definitely_not_a_symbol" in errs[0]


def test_required_snippets_detects_drift(monkeypatch):
    """A doc that stops quoting a required snippet (the train-throughput
    entry point, the policy_rollout dispatch cells) trips the gate."""
    errs = docs_check.missing_required_snippets()
    assert errs == []          # the tree currently quotes all of them
    monkeypatch.setattr(
        docs_check, "REQUIRED_SNIPPETS",
        {"README.md": ("python -m benchmarks.no_such_bench",)})
    errs = docs_check.missing_required_snippets()
    assert len(errs) == 1 and "no_such_bench" in errs[0]


def test_required_snippets_cover_the_new_tier():
    """The required list itself keeps the training-loop contract pinned:
    entry point + all three policy_rollout dispatch cells."""
    need = {"python -m benchmarks.train_throughput",
            "kernels/ops.py::policy_rollout",
            "kernels/aip_step.py::policy_rollout",
            "kernels/ref.py::policy_rollout_ref"}
    listed = {s for snips in docs_check.REQUIRED_SNIPPETS.values()
              for s in snips}
    assert need <= listed


def test_snippet_extraction_ignores_prose():
    text = ("Adapters make the two worlds interoperate.\n"
            "Run `make test-fast` or:\n```sh\nmake bench-check\n```\n")
    snippets = docs_check._code_snippets(text)
    assert "test-fast" in snippets and "bench-check" in snippets
    assert "two worlds" not in snippets
