"""The docs drift gate (tools/docs_check.py) passes on the tree and
actually detects drift (so ``make docs-check`` keeps meaning something)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from tools import docs_check


def test_docs_check_passes_on_tree():
    assert docs_check.run_checks() == []


def test_makefile_targets_include_documented_ones():
    targets = docs_check._makefile_targets()
    assert {"test-fast", "test-all", "docs-check",
            "bench-check"} <= targets


def test_module_resolution():
    assert docs_check._module_exists("repro.launch.rl_train")
    assert docs_check._module_exists("benchmarks.run")
    assert not docs_check._module_exists("repro.launch.no_such_module")


def test_snippet_extraction_ignores_prose():
    text = ("Adapters make the two worlds interoperate.\n"
            "Run `make test-fast` or:\n```sh\nmake bench-check\n```\n")
    snippets = docs_check._code_snippets(text)
    assert "test-fast" in snippets and "bench-check" in snippets
    assert "two worlds" not in snippets
