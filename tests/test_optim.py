"""Optimizer + gradient compression tests (unit + hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.optim.adamw import (adamw, clip_by_global_norm, cosine_schedule,
                               global_norm)
from repro.optim import grad_compress as gc

SET = dict(deadline=None, max_examples=15)


def test_adamw_converges_on_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    st_ = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, st_, _ = opt.update(g, st_, params)
    assert float(loss(params)) < 1e-3


def test_adamw_preserves_structure_and_dtype():
    opt = adamw(1e-3)
    params = {"a": jnp.ones((3, 4), jnp.bfloat16),
              "b": {"c": jnp.zeros((2,), jnp.float32)}}
    st_ = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    p2, st2, m = opt.update(g, st_, params)
    assert jax.tree_util.tree_structure(p2) == \
        jax.tree_util.tree_structure(params)
    assert p2["a"].dtype == jnp.bfloat16
    assert st2.mu["a"].dtype == jnp.float32  # moments always fp32
    assert bool(jnp.isfinite(m["grad_norm"]))


@given(scale=st.floats(0.1, 100.0))
@settings(**SET)
def test_clip_bounds_global_norm(scale):
    tree = {"w": jnp.full((8, 8), scale)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-3
    assert float(lr(jnp.int32(5))) < 1e-3


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 1000))
@settings(**SET)
def test_compress_error_bounded_by_half_step(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1024,)) * 3.0
    q, scale = gc.compress(x, block=256)
    y = gc.decompress(q, scale, x.shape, x.dtype)
    # per-block quantisation step = scale; error <= scale/2 elementwise
    step = jnp.repeat(scale, 256)[:1024]
    assert bool((jnp.abs(x - y) <= step / 2 + 1e-6).all())


def test_error_feedback_removes_bias():
    """With error feedback, the running sum of decompressed grads tracks the
    running sum of true grads (bias does not accumulate)."""
    key = jax.random.PRNGKey(0)
    err = jnp.zeros((512,))
    true_sum = jnp.zeros((512,))
    approx_sum = jnp.zeros((512,))
    for i in range(50):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (512,)) * 0.1 + 0.05
        q, scale, err = gc.compress_with_feedback(g, err, block=128)
        approx_sum = approx_sum + gc.decompress(q, scale, g.shape,
                                                jnp.float32)
        true_sum = true_sum + g
    # residual error is bounded by one quantisation step, NOT growing ~ O(T)
    resid = float(jnp.abs(true_sum - approx_sum).max())
    assert resid < 0.05, resid


def test_compression_ratio():
    r = gc.compression_ratio((1024, 1024), jnp.float32, block=256)
    assert 3.5 < r < 4.0
