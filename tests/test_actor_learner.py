"""Disaggregated actor/learner trainer + fault injection (PR 7).

Pins down the fault-tolerance contract: the deterministic fleet is a pure
function of its seed; a run killed mid-training and resumed from a
committed checkpoint replays the BITWISE identical remaining trajectory
(the uninterrupted same-seed run is the oracle — the PR's acceptance
test); stale batches are dropped, never averaged in; killed workers
restart on their own deterministic RNG streams; fleet resizes keep the
learner state; and the full integrated RL driver round-trips through the
checkpoint — including onto a forced 8-device CPU mesh (subprocess)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt
from repro.core import engine, influence
from repro.distributed import actor_learner as al
from repro.distributed import fault_injection as fi
from repro.envs.traffic import TrafficConfig, make_batched_local_traffic_env
from repro.rl import ppo

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        jnp.array_equal(x, y) for x, y in zip(la, lb))


@pytest.fixture(scope="module")
def tiny_env():
    """A small unified-IALS engine (the fleet's intended workload)."""
    bls = make_batched_local_traffic_env(TrafficConfig())
    acfg = influence.AIPConfig(kind="fnn", d_in=bls.spec.dset_dim,
                               n_out=bls.spec.n_influence, hidden=8,
                               stack=2)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    return engine.make_unified_ials(bls, params, acfg)


@pytest.fixture(scope="module")
def tiny_cfg(tiny_env):
    return ppo.PPOConfig(obs_dim=tiny_env.spec.obs_dim,
                         n_actions=tiny_env.spec.n_actions,
                         frame_stack=2, n_envs=4, rollout_len=7,
                         episode_len=5, hidden=16, epochs=2)


def _fleet(deterministic=True, **kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("max_staleness", 2)
    kw.setdefault("seed", 5)
    return al.FleetConfig(deterministic=deterministic, **kw)


# ---------------------------------------------------------------------------
# determinism + the bitwise kill-and-resume acceptance test
# ---------------------------------------------------------------------------

def test_deterministic_fleet_is_seed_pure(tiny_env, tiny_cfg):
    """Two same-seed runs are bitwise identical end to end — the property
    the resume guarantee is built on."""
    outs = []
    for _ in range(2):
        tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
        state, info = tr.run(tr.init_state(), 4)
        outs.append((state, info))
    (s1, i1), (s2, i2) = outs
    assert _trees_equal(s1.params, s2.params)
    assert _trees_equal(s1.opt_state, s2.opt_state)
    assert int(s1.version) == int(s2.version) == 4
    assert [h["loss"] for h in i1["history"]] == \
           [h["loss"] for h in i2["history"]]


def test_kill_and_resume_bitwise(tiny_env, tiny_cfg, tmp_path):
    """THE acceptance test: run k updates, checkpoint, 'die', restore in
    a fresh trainer, run the remaining j — final params are bitwise equal
    to the uninterrupted k+j run's (not allclose: equal)."""
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    oracle, _ = tr.run(tr.init_state(), 5)

    tr1 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    mid, _ = tr1.run(tr1.init_state(), 2)
    ckpt.save(tmp_path, int(mid.version), mid,
              metadata=tr1.save_metadata(mid))

    tr2 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    restored, extra, start = al.resume_fleet(tmp_path, tr2)
    assert extra is None and start == 2
    assert _trees_equal(restored, mid)           # exact round-trip
    final, _ = tr2.run(restored, 3)
    assert int(final.version) == 5
    assert _trees_equal(final.params, oracle.params)
    assert _trees_equal(final.opt_state, oracle.opt_state)
    for w_f, w_o in zip(final.workers, oracle.workers):
        assert int(w_f.rng_position) == int(w_o.rng_position)
        assert _trees_equal(w_f.rs, w_o.rs)


def test_resume_fleet_without_checkpoint(tmp_path, tiny_env, tiny_cfg):
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    state, extra, start = al.resume_fleet(tmp_path / "none", tr)
    assert state is None and extra is None and start == 0


# ---------------------------------------------------------------------------
# staleness drop policy
# ---------------------------------------------------------------------------

def test_stale_batches_dropped_not_applied(tiny_env, tiny_cfg):
    """A batch delayed past max_staleness is counted + recorded as
    dropped, the learner still reaches the target version, and the
    history row carries the offending staleness."""
    inj = fi.FaultInjector(fi.FaultPlan.of(
        fi.DelayBatch(worker_id=0, at_tick=0, ticks=4)))
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg,
                                _fleet(max_staleness=1), injector=inj)
    state, info = tr.run(tr.init_state(), 4)
    assert int(state.version) == 4
    assert info["delayed"] == 1
    dropped = [h for h in info["history"] if h["dropped"]]
    assert len(dropped) == 1 and dropped[0]["staleness"] > 1
    assert info["dropped"] == 1
    applied = [h for h in info["history"] if not h["dropped"]]
    assert all(h["staleness"] <= 1 for h in applied)


def test_within_staleness_batches_applied(tiny_env, tiny_cfg):
    """The same delay under a generous bound is applied, not dropped —
    the drop policy is the bound, nothing implicit."""
    inj = fi.FaultInjector(fi.FaultPlan.of(
        fi.DelayBatch(worker_id=0, at_tick=0, ticks=2)))
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg,
                                _fleet(max_staleness=4), injector=inj)
    state, info = tr.run(tr.init_state(), 4)
    assert int(state.version) == 4
    assert info["dropped"] == 0 and info["delayed"] == 1


# ---------------------------------------------------------------------------
# worker kill / restart
# ---------------------------------------------------------------------------

def test_worker_kill_restarts_on_fresh_stream(tiny_env, tiny_cfg):
    """A killed worker loses its rollout state (restart count bumps, its
    env state re-initializes from the restart stream) but the fleet keeps
    training; the run differs from the fault-free one (the fault is
    real), deterministically (two faulted runs agree)."""
    def run_with(plan):
        inj = fi.FaultInjector(plan) if plan else None
        tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet(),
                                    injector=inj)
        state, info = tr.run(tr.init_state(), 4)
        return state, info, inj

    plan = fi.FaultPlan.of(fi.KillWorker(worker_id=1, at_tick=1))
    clean, _, _ = run_with(None)
    s1, i1, inj1 = run_with(plan)
    s2, _, _ = run_with(plan)
    inj1.assert_exhausted()               # the plan actually fired
    assert inj1.kills_applied == 1
    assert i1["kills"] == 1
    assert int(s1.workers[1].restarts) == 1
    assert int(s1.workers[0].restarts) == 0
    assert int(s1.version) == 4
    assert _trees_equal(s1.params, s2.params)        # faulted, replayable
    assert not _trees_equal(s1.params, clean.params)  # fault changed it


def test_fault_injector_fires_once():
    inj = fi.FaultInjector(fi.FaultPlan.of(
        fi.KillWorker(worker_id=0, at_tick=3)))
    assert not inj.should_kill(3, 1)      # wrong worker
    assert not inj.should_kill(2, 0)      # wrong tick
    with pytest.raises(AssertionError):
        inj.assert_exhausted()            # not yet fired: loud, not vacuous
    assert inj.should_kill(3, 0)
    assert not inj.should_kill(3, 0)      # consumed
    inj.assert_exhausted()
    assert inj.kills_applied == 1


# ---------------------------------------------------------------------------
# async (free-running threads) mode
# ---------------------------------------------------------------------------

def test_async_fleet_trains_and_joins(tiny_env, tiny_cfg):
    """Throughput mode liveness: reaches the target version, producers
    outlive nothing (threads joined), every applied batch respected the
    staleness bound, and worker states were collected back."""
    import threading
    before = threading.active_count()
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg,
                                _fleet(deterministic=False,
                                       max_staleness=8))
    state, info = tr.run(tr.init_state(), 3)
    assert threading.active_count() == before
    assert int(state.version) == 3
    assert info["produced"] >= info["updates"]
    applied = [h for h in info["history"] if not h["dropped"]]
    assert all(h["staleness"] <= 8 for h in applied)
    assert all(jnp.isfinite(h["loss"]) for h in applied)
    assert sum(int(w.rng_position) for w in state.workers) \
        >= info["produced"]


# ---------------------------------------------------------------------------
# elastic fleet resize on resume
# ---------------------------------------------------------------------------

def test_fleet_resize_keeps_learner_state(tiny_env, tiny_cfg, tmp_path):
    """Resume with a different worker count: learner (params, opt state,
    version) survives bitwise; surviving workers keep their exact RNG
    stream positions; new workers start fresh at position 0."""
    tr2 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet(n_workers=2))
    state, _ = tr2.run(tr2.init_state(), 4)
    ckpt.save(tmp_path, 4, state, metadata=tr2.save_metadata(state))

    tr3 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet(n_workers=3))
    grown, _, start = al.resume_fleet(tmp_path, tr3)
    assert start == 4 and len(grown.workers) == 3
    assert _trees_equal(grown.params, state.params)
    assert _trees_equal(grown.opt_state, state.opt_state)
    for w_old, w_new in zip(state.workers, grown.workers[:2]):
        assert int(w_new.rng_position) == int(w_old.rng_position)
    assert int(grown.workers[2].rng_position) == 0

    tr1 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet(n_workers=1))
    shrunk, _, _ = al.resume_fleet(tmp_path, tr1)
    assert len(shrunk.workers) == 1
    assert _trees_equal(shrunk.params, state.params)


# ---------------------------------------------------------------------------
# RL-state checkpoint round-trip (params + opt + AIP + RNG positions)
# ---------------------------------------------------------------------------

def test_rl_state_roundtrip_with_sim_params(tiny_env, tiny_cfg, tmp_path):
    """The composite tree the driver checkpoints — fleet state + the
    simulator's AIP params — round-trips bitwise, and read_metadata
    surfaces the counters without touching arrays."""
    tr = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    state, _ = tr.run(tr.init_state(), 2)
    acfg = influence.AIPConfig(kind="fnn", d_in=3, n_out=2, hidden=8,
                               stack=2)
    sim = influence.init_aip(acfg, jax.random.PRNGKey(7))
    ckpt.save(tmp_path, 2, {"fleet": state, "extra": sim},
              metadata=tr.save_metadata(state))

    tr2 = al.ActorLearnerTrainer(tiny_env, tiny_cfg, _fleet())
    restored, sim_back, start = al.resume_fleet(
        tmp_path, tr2,
        extra_template=influence.init_aip(acfg, jax.random.PRNGKey(0)))
    assert start == 2
    assert _trees_equal(sim_back, sim)
    assert _trees_equal(restored, state)

    meta = ckpt.read_metadata(tmp_path)
    assert meta["n_workers"] == 2 and meta["version"] == 2
    assert meta["rng_positions"] == [int(w.rng_position)
                                     for w in state.workers]


# ---------------------------------------------------------------------------
# the full driver, killed and resumed onto a forced 8-device mesh
# ---------------------------------------------------------------------------

def test_rl_train_resume_bitwise_on_8_device_mesh(tmp_path):
    """End-to-end: the integrated rl_train driver checkpoints its full RL
    state, and a resumed run — restoring onto a forced 8-device CPU mesh
    — finishes with params bitwise equal to the uninterrupted same-seed
    run (final_params_md5 is the oracle)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import json, sys
        import jax
        from repro.launch import rl_train

        assert len(jax.devices()) == 8
        ckdir = sys.argv[1]
        base = ["--domain", "traffic", "--simulator", "ials",
                "--iterations", "3", "--eval-every", "100",
                "--n-envs", "8", "--rollout-len", "8",
                "--episode-len", "16", "--collect-episodes", "2",
                "--aip-epochs", "1", "--seed", "4"]
        full = rl_train.main(base)
        part = rl_train.main(base[:5] + ["1"] + base[6:]
                             + ["--ckpt-dir", ckdir, "--save-every", "1"])
        res = rl_train.main(base + ["--ckpt-dir", ckdir,
                                    "--save-every", "1"])
        print(json.dumps({
            "full": full["final_params_md5"],
            "resumed": res["final_params_md5"],
            "resumed_from": res["resumed_from"]}))
    """)
    out = subprocess.run([sys.executable, "-c", script,
                          str(tmp_path / "ck")],
                         capture_output=True, text=True, timeout=1200,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["resumed_from"] == 1
    assert res["resumed"] == res["full"], res
