"""Checkpoint atomicity, roundtrip, keep-N, auto-resume, fault tolerance."""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt
from repro.distributed.fault_tolerance import (StragglerDetector,
                                               TrainingGuard, elastic_plan)


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 16)),
                       "b": jnp.zeros((16,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip_exact(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 10, tree)
    got, step, meta = ckpt.restore(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        assert bool((a == b).all())


def test_uncommitted_checkpoints_ignored(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 1, tree)
    # simulate a torn save: directory without COMMITTED
    torn = tmp_path / "step_000000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1


def test_keep_n_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.all_steps(tmp_path) == [4, 5]


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 16))}}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad)


def test_guard_resume(tmp_path):
    guard = TrainingGuard(tmp_path, save_every=2,
                          install_signal_handler=False)
    state, start = guard.resume_or(lambda: _tree())
    assert start == 0
    guard.maybe_save(2, state)
    guard2 = TrainingGuard(tmp_path, install_signal_handler=False)
    state2, start2 = guard2.resume_or(lambda: _tree(seed=99))
    assert start2 == 2
    # restored values are the SAVED ones, not the fresh init
    assert bool((state2["params"]["w"] == state["params"]["w"]).all())


def test_guard_preemption_flush(tmp_path):
    guard = TrainingGuard(tmp_path, save_every=1000,
                          install_signal_handler=False)
    guard.preempted = True          # as the SIGTERM handler would set
    assert guard.maybe_save(3, _tree())
    assert ckpt.latest_step(tmp_path) == 3


def test_straggler_detector_fires_on_sustained_slowdown():
    det = StragglerDetector(threshold=2.0, patience=3, warmup=5)
    fired = []
    for step in range(30):
        t = 1.0 if step < 20 else 5.0
        if det.update(step, t):
            fired.append(step)
    assert fired and fired[0] >= 20


def test_straggler_detector_ignores_blips():
    det = StragglerDetector(threshold=2.0, patience=3, warmup=5)
    for step in range(50):
        t = 5.0 if step % 10 == 0 else 1.0  # isolated blips
        assert not det.update(step, t)


def test_elastic_plan_shrinks_data_axis():
    p = elastic_plan(15, 16, model_parallel=16, global_batch=240)
    assert p.mesh_shape[-1] == 16
    data = p.mesh_shape[0]
    assert data * 16 <= 15 * 16
    assert 240 % data == 0


def test_elastic_plan_raises_when_too_small():
    with pytest.raises(ValueError):
        elastic_plan(1, 4, model_parallel=16, global_batch=64)
