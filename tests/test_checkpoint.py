"""Checkpoint atomicity, roundtrip, keep-N, auto-resume, fault tolerance."""
import os
import signal
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt
from repro.distributed import fault_injection as fi
from repro.distributed.fault_tolerance import (StragglerDetector,
                                               TrainingGuard, elastic_plan)


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 16)),
                       "b": jnp.zeros((16,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip_exact(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 10, tree)
    got, step, meta = ckpt.restore(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        assert bool((a == b).all())


def test_uncommitted_checkpoints_ignored(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 1, tree)
    # simulate a torn save: directory without COMMITTED
    torn = tmp_path / "step_000000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1


def test_keep_n_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.all_steps(tmp_path) == [4, 5]


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 16))}}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad)


def test_guard_resume(tmp_path):
    guard = TrainingGuard(tmp_path, save_every=2,
                          install_signal_handler=False)
    state, start = guard.resume_or(lambda: _tree())
    assert start == 0
    guard.maybe_save(2, state)
    guard2 = TrainingGuard(tmp_path, install_signal_handler=False)
    state2, start2 = guard2.resume_or(lambda: _tree(seed=99))
    assert start2 == 2
    # restored values are the SAVED ones, not the fresh init
    assert bool((state2["params"]["w"] == state["params"]["w"]).all())


def test_guard_preemption_flush(tmp_path):
    guard = TrainingGuard(tmp_path, save_every=1000,
                          install_signal_handler=False)
    guard.preempted = True          # as the SIGTERM handler would set
    assert guard.maybe_save(3, _tree())
    assert ckpt.latest_step(tmp_path) == 3


def test_guard_clears_preempted_after_flush(tmp_path):
    """A successful forced save answers the signal exactly once — the
    flag clears, so later steps do not re-save forever."""
    guard = TrainingGuard(tmp_path, save_every=1000,
                          install_signal_handler=False)
    guard.preempted = True
    assert guard.maybe_save(3, _tree())
    assert not guard.preempted
    assert not guard.maybe_save(4, _tree())     # no longer forced
    assert ckpt.latest_step(tmp_path) == 3


def test_guard_sigterm_chains_and_uninstalls(tmp_path):
    """Stacked guards both see SIGTERM (the newer handler chains the
    displaced one), and uninstall() restores exactly what it displaced."""
    orig = signal.getsignal(signal.SIGTERM)
    g1 = TrainingGuard(tmp_path / "a")
    g2 = TrainingGuard(tmp_path / "b")
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        assert g2.preempted and g1.preempted    # chained, not swallowed
        g1.preempted = g2.preempted = False
        g2.uninstall()
        os.kill(os.getpid(), signal.SIGTERM)
        assert g1.preempted and not g2.preempted
    finally:
        g2.uninstall()                          # idempotent
        g1.uninstall()
    assert signal.getsignal(signal.SIGTERM) == orig


@pytest.mark.parametrize("tear", ["tmp-only", "no-commit", "truncated",
                                  "torn-meta"])
def test_torn_saves_never_loaded_and_swept(tmp_path, tear):
    """The COMMITTED contract under every torn-save layout a crash can
    leave: the torn step is invisible to latest_step, restore falls back
    to the previous committed checkpoint, and the next successful save
    sweeps the debris."""
    tree = _tree()
    ckpt.save(tmp_path, 1, tree)
    torn = fi.torn_save(tmp_path, 2, _tree(seed=9), tear=tear)
    assert torn.exists()
    assert ckpt.latest_step(tmp_path) == 1
    got, step, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 1
    assert bool((got["params"]["w"] == tree["params"]["w"]).all())
    ckpt.save(tmp_path, 3, tree)                # sweeps the debris
    assert not torn.exists()
    assert ckpt.all_steps(tmp_path) == [1, 3]


def test_read_metadata_without_arrays(tmp_path):
    ckpt.save(tmp_path, 5, _tree(), metadata={"rng_position": 12,
                                              "n_workers": 3})
    (tmp_path / "step_000000005" / "arrays.npz").unlink()  # prove no read
    meta = ckpt.read_metadata(tmp_path)
    assert meta == {"rng_position": 12, "n_workers": 3}
    with pytest.raises(FileNotFoundError):
        ckpt.read_metadata(tmp_path / "empty")


def test_read_metadata_explicit_step_rejects_torn_layouts(tmp_path):
    """Explicit-step metadata reads must refuse torn layouts instead of
    decoding partial bytes: a missing COMMITTED sentinel (any tear) is a
    FileNotFoundError, and the ``torn-meta`` tear — the kill landed
    inside the metadata write itself — never reaches msgpack garbage."""
    ckpt.save(tmp_path, 1, _tree(), metadata={"it": 1})
    fi.torn_save(tmp_path, 2, _tree(seed=9), tear="torn-meta",
                 metadata={"it": 2})
    # step=None resume path: the torn step is invisible, not an error
    assert ckpt.read_metadata(tmp_path) == {"it": 1}
    with pytest.raises(FileNotFoundError):
        ckpt.read_metadata(tmp_path, step=2)
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: _tree()), step=2)


def test_read_metadata_raises_on_corrupt_committed_meta(tmp_path):
    """Bitrot inside a COMMITTED checkpoint (truncated or overwritten
    meta.msgpack) raises a ValueError naming the file — never returns a
    garbage dict for resume counters."""
    ckpt.save(tmp_path, 3, _tree(), metadata={"it": 3})
    mp = tmp_path / "step_000000003" / "meta.msgpack"
    raw = mp.read_bytes()
    mp.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match="meta.msgpack"):
        ckpt.read_metadata(tmp_path, step=3)
    mp.write_bytes(b"\xc3")              # valid msgpack, not a meta dict
    with pytest.raises(ValueError, match="meta.msgpack"):
        ckpt.read_metadata(tmp_path, step=3)


def test_restore_subtree_roundtrip_and_mismatch(tmp_path):
    """``restore_subtree`` pulls one subtree by path: exact values for a
    shape-correct template, a clear error for a wrong prefix, and the
    usual shape check per leaf."""
    tree = _tree()
    ckpt.save(tmp_path, 4, tree, metadata={"mode": "integrated"})
    template = jax.eval_shape(lambda: tree["params"])
    got, step, meta = ckpt.restore_subtree(tmp_path, template,
                                           "['params']")
    assert step == 4 and meta == {"mode": "integrated"}
    assert bool((got["w"] == tree["params"]["w"]).all())
    assert got["b"].dtype == tree["params"]["b"].dtype
    with pytest.raises(ValueError, match="no leaf"):
        ckpt.restore_subtree(tmp_path, template, "['policy']")
    bad = {"w": jnp.zeros((4, 4))}
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore_subtree(tmp_path, bad, "['params']")


def test_straggler_detector_fires_on_sustained_slowdown():
    det = StragglerDetector(threshold=2.0, patience=3, warmup=5)
    fired = []
    for step in range(30):
        t = 1.0 if step < 20 else 5.0
        if det.update(step, t):
            fired.append(step)
    assert fired and fired[0] >= 20


def test_straggler_detector_ignores_blips():
    det = StragglerDetector(threshold=2.0, patience=3, warmup=5)
    for step in range(50):
        t = 5.0 if step % 10 == 0 else 1.0  # isolated blips
        assert not det.update(step, t)


def test_elastic_plan_shrinks_data_axis():
    p = elastic_plan(15, 16, model_parallel=16, global_batch=240)
    assert p.mesh_shape[-1] == 16
    data = p.mesh_shape[0]
    assert data * 16 <= 15 * 16
    assert 240 % data == 0


def test_elastic_plan_raises_when_too_small():
    with pytest.raises(ValueError):
        elastic_plan(1, 4, model_parallel=16, global_batch=64)
