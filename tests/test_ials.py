"""IALS composition invariants (Algorithm 2)."""
import jax
import jax.numpy as jnp

from repro.core import ials, influence
from repro.envs.traffic import make_local_traffic_env
from repro.envs.warehouse import make_local_warehouse_env


def _roll(env, key, T=64):
    s = env.reset(key)
    us = []
    for t in range(T):
        key, ka, ks = jax.random.split(key, 3)
        a = jax.random.randint(ka, (), 0, env.spec.n_actions)
        s, obs, r, info = jax.jit(env.step)(s, a, ks)
        us.append(info["u"])
    return jnp.stack(us)


def test_fixed_marginal_rate_honored():
    ls = make_local_traffic_env()
    cfg = influence.AIPConfig(kind="fnn", d_in=ls.spec.dset_dim,
                              n_out=4, hidden=8, stack=1)
    params = influence.init_aip(cfg, jax.random.PRNGKey(0))
    for p in (0.1, 0.5):
        env = ials.make_ials(ls, params, cfg, fixed_marginal=p)
        us = _roll(env, jax.random.PRNGKey(1), T=256)
        rate = float(us.mean())
        assert abs(rate - p) < 0.08, (p, rate)


def test_aip_state_threads_through_rollout():
    ls = make_local_warehouse_env()
    cfg = influence.AIPConfig(kind="gru", d_in=ls.spec.dset_dim,
                              n_out=12, hidden=16)
    params = influence.init_aip(cfg, jax.random.PRNGKey(0))
    env = ials.make_ials(ls, params, cfg)
    key = jax.random.PRNGKey(2)
    s = env.reset(key)
    h0 = s.aip_state
    s, *_ = env.step(s, jnp.int32(1), key)
    assert float(jnp.abs(s.aip_state - h0).max()) > 0  # GRU state evolved


def test_ials_obs_matches_local_env():
    ls = make_local_traffic_env()
    cfg = influence.AIPConfig(kind="fnn", d_in=ls.spec.dset_dim,
                              n_out=4, hidden=8, stack=1)
    params = influence.init_aip(cfg, jax.random.PRNGKey(0))
    env = ials.make_ials(ls, params, cfg)
    s = env.reset(jax.random.PRNGKey(3))
    assert env.observe(s).shape == (ls.spec.obs_dim,)
    assert env.spec.n_actions == ls.spec.n_actions


def test_ials_vmaps():
    """The whole IALS step vmaps over a batch of simulators (the scaling
    property the framework relies on)."""
    ls = make_local_traffic_env()
    cfg = influence.AIPConfig(kind="gru", d_in=ls.spec.dset_dim,
                              n_out=4, hidden=8)
    params = influence.init_aip(cfg, jax.random.PRNGKey(0))
    env = ials.make_ials(ls, params, cfg)
    keys = jax.random.split(jax.random.PRNGKey(4), 32)
    states = jax.vmap(env.reset)(keys)
    acts = jnp.zeros((32,), jnp.int32)
    s2, obs, r, info = jax.jit(jax.vmap(env.step))(states, acts, keys)
    assert obs.shape == (32, ls.spec.obs_dim)
    assert r.shape == (32,)
