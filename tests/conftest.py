import os

# Tests run single-device CPU (the dry-run alone forces 512 host devices,
# and only in its own subprocess — see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Identical programs re-jitted from fresh closures (every test builds its own
# env/step fns) hit this cache instead of recompiling — cuts the tier-1 wall
# clock severalfold, both within a run and across runs.
jax.config.update("jax_compilation_cache_dir", "/tmp/repro-jax-test-cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


@pytest.fixture(scope="session", autouse=True)
def _force_cpu():
    """Belt-and-braces: some modules re-touch jax config at import time."""
    jax.config.update("jax_platform_name", "cpu")
    yield


@pytest.fixture(scope="session")
def small_sizes():
    """Default scale for new tests: keep jit times in the tens of ms."""
    return dict(n_envs=4, rollout_len=8, ep_len=16, n_episodes=4,
                hidden=16, epochs=2)
