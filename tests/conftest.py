import os

# Tests run single-device CPU (the dry-run alone forces 512 host devices,
# and only in its own subprocess — see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
