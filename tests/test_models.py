"""Per-architecture smoke tests (reduced configs) + decode consistency.

Each assigned arch: one forward/train step on CPU asserting output shapes
and no NaNs (the brief's smoke requirement), plus the strongest correctness
invariant we have: prefill+decode_step == full forward, per family.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs, reduced
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim.adamw import adamw

# every arch jit-compiles a full model: minutes in aggregate -> tier-2
pytestmark = pytest.mark.slow

ARCHS = list_configs()


def _inputs(cfg, key, B=2, T=16, labels=True):
    out = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
    if labels:
        out["labels"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        out["vision"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, T = 2, 16
    inputs = _inputs(cfg, key, B, T)
    h, aux, _ = lm.forward(params, cfg, inputs)
    assert h.shape == (B, T, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    loss, metrics = lm.loss_fn(params, cfg, inputs)
    assert bool(jnp.isfinite(loss))
    assert metrics["ce"] > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    opt = adamw(1e-3)
    step = jax.jit(steps_lib.make_train_step(cfg, opt, 2))
    inputs = _inputs(cfg, key, B=4, T=16)
    p2, o2, m = step(params, opt.init(params), inputs)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x[0] - x[1]).sum()),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, p2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.n_routed_experts:  # dropless everywhere for exact equality
        cfg = cfg.with_overrides(
            capacity_factor=cfg.n_routed_experts / cfg.moe_top_k)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    B, T, ML = 2, 12, 16
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    extra = {k: v for k, v in _inputs(cfg, key, B, T, labels=False).items()
             if k not in ("tokens",)}
    lg0, cache = lm.prefill(params, cfg, {"tokens": toks[:, :T], **extra}, ML)
    lg1, _ = lm.decode_step(params, cfg, cache, toks[:, T], jnp.int32(T))
    h, _, _ = lm.forward(params, cfg, {"tokens": toks, **extra})
    ref1 = lm.logits(params, cfg, h[:, -1])
    ref0 = lm.logits(params, cfg, h[:, T - 1])
    assert float(jnp.abs(lg0 - ref0).max()) < 2e-3
    assert float(jnp.abs(lg1 - ref1).max()) < 2e-3


def test_count_params_moe_active():
    c = lm.count_params(get_config("deepseek-v3-671b"))
    assert 6.5e11 < c["total"] < 7.0e11        # 671B
    assert 3.4e10 < c["active"] < 4.0e10       # paper: 37B activated


def test_layer_plans_cover_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch)
        pro, pattern, n_groups = cfg.layer_plan()
        assert len(pro) + len(pattern) * n_groups == cfg.n_layers, arch
