"""Bucketed multi-slot serving (PR 9): shape buckets + cross-policy.

Pins the bucketed extension of the serving contract
(docs/ARCHITECTURE.md §8):

* **Smallest admissible bucket.** Admission tags every request with the
  smallest bucket shape covering its region burst; every dispatch runs
  in the smallest bucket shape admitting its popped batch — property-
  tested, together with the inherited no-drop / exact-miss / EDF /
  FIFO-in-class guarantees (the bucketed pop order is bitwise the
  single-slot pop order: buckets partition *shapes*, never the queue).
* **Calibration optimality.** ``calibrate_buckets`` is an exact
  partition DP, so its expected padded-lane waste is monotonically
  non-increasing in the bucket budget, the largest candidate is always
  chosen, and hand-checkable bimodal cases give the obvious optimum.
* **Cross-policy bitwise parity.** A lane of an N-policy server is
  bitwise-identical to the single-policy server of its own checkpoint
  at the same slot shape — for both domains x both AIP backbones, on
  the production dispatch route AND the forced interpret-mode Pallas
  kernel; packed-vs-dense parity and pad-lane zeroing hold exactly as
  in the single-policy matrix.
* **Staging discipline.** ``_pack`` reuses one preallocated buffer pair
  per slot shape — no per-dispatch allocation — and never re-pads the
  tail: leftover lanes from the previous dispatch are proven harmless
  (bitwise) by the kernel-boundary mask.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.core import engine, influence
from repro.launch import policy_serve
from repro.envs.traffic import TrafficConfig, make_batched_local_traffic_env
from repro.envs.warehouse import (WarehouseConfig,
                                  make_batched_local_warehouse_env)
from repro.rl import ppo
from repro.serving import (BIMODAL_SIZES, BIMODAL_WEIGHTS,
                           BucketedSlotScheduler, PolicyServer, Request,
                           SlotScheduler, TraceConfig, burst_sizes,
                           calibrate_buckets, expected_padded_waste,
                           flood_trace, synthetic_trace)

S = 8                                    # the test slot shape
N_POL = 2                                # checkpoints per multi server
FRAME_STACK = {"traffic": 1, "warehouse": 8}    # as rl_train.build_domain
_cache = {}


def _bls(domain):
    if domain == "traffic":
        return make_batched_local_traffic_env(TrafficConfig())
    return make_batched_local_warehouse_env(WarehouseConfig())


def _frames(domain, kind):
    """(S, frame_dim) f32 frames from a short unified-engine rollout with
    the given AIP backbone — real serving inputs (see test_serving.py)."""
    key = ("frames", domain, kind)
    if key not in _cache:
        bls = _bls(domain)
        acfg = influence.AIPConfig(kind=kind, d_in=bls.spec.dset_dim,
                                   n_out=bls.spec.n_influence, hidden=8,
                                   stack=2)
        aip = influence.init_aip(acfg, jax.random.PRNGKey(0))
        env = engine.make_unified_ials(bls, aip, acfg, n_agents=1,
                                       use_horizon_kernel=False)
        state = env.reset(jax.random.PRNGKey(1), S)
        k = jax.random.PRNGKey(2)
        for _ in range(2):
            k, ka, ks = jax.random.split(k, 3)
            a = jax.random.randint(ka, (S,), 0, bls.spec.n_actions)
            state, _, _, _ = env.step(state, a, ks)
        obs = np.asarray(env.observe(state), np.float32)
        _cache[key] = np.tile(obs, (1, FRAME_STACK[domain]))
    return _cache[key]


def _policies(domain):
    """N_POL independently initialised checkpoints of one domain's
    policy net — the cross-policy family."""
    key = ("policies", domain)
    if key not in _cache:
        bls = _bls(domain)
        pcfg = ppo.PPOConfig(obs_dim=bls.spec.obs_dim,
                             n_actions=bls.spec.n_actions,
                             frame_stack=FRAME_STACK[domain], hidden=16)
        _cache[key] = (pcfg, [ppo.init_policy(pcfg, jax.random.PRNGKey(i))
                              for i in range(N_POL)])
    return _cache[key]


def _server(domain, route, policy=None):
    """Multi-policy server when ``policy is None``; otherwise the
    single-policy reference server of checkpoint ``policy``. Shared per
    key so each jitted slot program compiles once."""
    key = ("server", domain, route, policy)
    if key not in _cache:
        pcfg, params = _policies(domain)
        p = params if policy is None else params[policy]
        _cache[key] = PolicyServer(p, obs_dim=pcfg.obs_dim,
                                   n_actions=pcfg.n_actions,
                                   frame_stack=FRAME_STACK[domain],
                                   slot=S, route=route)
    return _cache[key]


# ------------------------------------------------- bucketed scheduler

def _sized_trace(seed, n=60, sizes=(1, 2, 4, 8)):
    """Adversarial trace with tied arrivals, a zero-slack class, and
    region burst sizes spanning the bucket range."""
    rng = np.random.default_rng(seed)
    classes = (0.0, 0.004, 0.02)
    arrivals = np.sort(np.round(rng.uniform(0.0, 0.05, n), 3))
    frame = np.zeros(4, np.float32)
    return [Request(rid=rid, region=int(rng.integers(0, 5)),
                    klass=(k := int(rng.integers(0, len(classes)))),
                    arrival=float(t), deadline=float(t) + classes[k],
                    frame=frame, size=int(rng.choice(sizes)),
                    policy=rid % N_POL)
            for rid, t in enumerate(arrivals)]


def _drive_bucketed(trace, buckets, service_s=0.003):
    """The server's replay loop, scheduler only -> (sched, dispatches as
    (shape, batch) in pop order)."""
    sched = BucketedSlotScheduler(buckets)
    pops, now, i = [], 0.0, 0
    while i < len(trace) or sched.pending:
        while i < len(trace) and trace[i].arrival <= now:
            sched.admit(trace[i])
            i += 1
        if not sched.pending:
            now = trace[i].arrival
            continue
        shape, batch = sched.next_dispatch()
        now += service_s
        sched.complete(batch, now)
        pops.append((shape, batch))
    return sched, pops


@given(size=st.integers(1, 300),
       buckets=st.sampled_from([(8,), (2, 8), (2, 4, 8), (16, 64, 256)]))
def test_bucket_for_is_smallest_admissible(size, buckets):
    """``bucket_for`` returns the smallest shape >= size; oversize
    bursts ride the largest shape (split across dispatches)."""
    b = BucketedSlotScheduler(buckets).bucket_for(size)
    admissible = [s for s in buckets if s >= size]
    assert b == (min(admissible) if admissible else max(buckets))


@given(seed=st.integers(0, 3),
       buckets=st.sampled_from([(1, 3, 8), (2, 8), (8,)]))
def test_bucketed_no_drops_right_sizing_and_exact_accounting(seed, buckets):
    """Guarantees 1+4+5 together: every admitted request dispatches
    exactly once, each dispatch runs in the smallest bucket admitting
    its batch, and both per-bucket counters equal independent recounts."""
    trace = _sized_trace(seed)
    sched, pops = _drive_bucketed(trace, buckets)
    served_rids = sorted(r.rid for _, b in pops for r in b)
    assert served_rids == list(range(len(trace)))     # exactly once each
    assert sched.served == sched.admitted == len(trace)
    disp_recount = {b: 0 for b in buckets}
    for shape, batch in pops:
        assert 1 <= len(batch) <= shape
        admissible = [s for s in buckets if s >= len(batch)]
        assert shape == min(admissible)               # right-sized
        disp_recount[shape] += 1
    assert sched.dispatches_by_bucket == disp_recount
    adm_recount = {b: 0 for b in buckets}
    for r in trace:
        adm_recount[sched.bucket_for(r.size)] += 1
    assert sched.admitted_by_bucket == adm_recount
    misses = sum(t > d for (_, _, _, d, t) in sched.completions)
    assert sched.deadline_misses == misses > 0        # klass 0: zero slack


@given(seed=st.integers(0, 3))
def test_bucketed_pop_order_is_single_slot_pop_order(seed):
    """Buckets partition shapes, never the queue: the bucketed pop order
    is bitwise the plain scheduler's at slot = max bucket, so EDF and
    FIFO-in-class carry over unchanged."""
    trace = _sized_trace(seed)
    _, pops_b = _drive_bucketed(trace, (2, 4, 8))
    sched = SlotScheduler(8)
    pops_s, now, i = [], 0.0, 0
    while i < len(trace) or sched.pending:
        while i < len(trace) and trace[i].arrival <= now:
            sched.admit(trace[i])
            i += 1
        if not sched.pending:
            now = trace[i].arrival
            continue
        batch = sched.next_batch()
        now += 0.003
        sched.complete(batch, now)
        pops_s.append(batch)
    assert [[r.rid for r in b] for _, b in pops_b] == \
        [[r.rid for r in b] for b in pops_s]


def test_bucketed_rejects_degenerate_buckets():
    with pytest.raises(ValueError):
        BucketedSlotScheduler(())
    with pytest.raises(ValueError):
        BucketedSlotScheduler((0, 8))


# ------------------------------------------------------- calibration

def _bimodal_cfg(seed=11, frame_dim=4, **kw):
    return TraceConfig(n_regions=24, mean_rps=2000.0, horizon_s=0.4,
                       frame_dim=frame_dim, seed=seed,
                       region_sizes=BIMODAL_SIZES,
                       region_size_weights=BIMODAL_WEIGHTS, **kw)


@given(seed=st.integers(0, 2))
def test_calibration_waste_monotone_in_bucket_budget(seed):
    """Adding a bucket to the budget never increases the optimal
    expected waste (the DP is exact), shapes stay in [min, max], the
    budget is respected, and every burst is admissible."""
    trace = synthetic_trace(_bimodal_cfg(seed=seed))
    sizes = burst_sizes(trace)
    prev = None
    for k in range(1, 5):
        buckets = calibrate_buckets(trace, max_buckets=k, min_slot=2,
                                    max_slot=64)
        assert 1 <= len(buckets) <= k
        assert all(2 <= b <= 64 for b in buckets)
        assert buckets == tuple(sorted(set(buckets)))
        waste = expected_padded_waste(sizes, buckets, max_slot=64)
        if prev is not None:
            assert waste <= prev
        prev = waste


def test_calibration_exact_on_hand_bimodal_case():
    """9 unit bursts + 1 burst of 64: with budget 2 the exact optimum is
    {1, 64} (waste 0); with budget 1 it is the forced {64}."""
    frame = np.zeros(2, np.float32)
    trace = []
    rid = 0
    for j in range(9):
        trace.append(Request(rid=rid, region=j, klass=0, arrival=0.01 * j,
                             deadline=1.0, frame=frame, size=1))
        rid += 1
    for lane in range(64):
        trace.append(Request(rid=rid, region=100, klass=0, arrival=0.5,
                             deadline=1.0, frame=frame, size=64))
        rid += 1
    assert sorted(burst_sizes(trace)) == [1] * 9 + [64]
    assert calibrate_buckets(trace, max_buckets=2, min_slot=1,
                             max_slot=64) == (1, 64)
    assert calibrate_buckets(trace, max_buckets=1, min_slot=1,
                             max_slot=64) == (64,)
    assert expected_padded_waste([1] * 9 + [64], (1, 64)) == 0
    assert expected_padded_waste([1] * 9 + [64], (64,)) == 9 * 63


def test_expected_padded_waste_splits_oversize_bursts():
    """A burst above max_slot decomposes into full chunks + remainder —
    the same model calibration uses — so a 600 burst at buckets (256,)
    wastes only the remainder chunk's padding."""
    assert expected_padded_waste([600], (256,), max_slot=256) == 256 - 88
    assert expected_padded_waste([600], (128, 256), max_slot=256) == \
        128 - 88
    assert expected_padded_waste([256], (256,), max_slot=256) == 0


def test_calibrate_rejects_bad_args_and_handles_empty():
    with pytest.raises(ValueError):
        calibrate_buckets([], max_buckets=0)
    with pytest.raises(ValueError):
        calibrate_buckets([], min_slot=64, max_slot=16)
    assert calibrate_buckets([], max_buckets=3, min_slot=16) == (16,)


# ---------------------------------------------------- bimodal traces

def test_bimodal_trace_sizes_weights_and_policies():
    """Bimodal configs draw burst sizes from the weighted size set, tag
    every request with its burst size and region-family checkpoint, and
    stay deterministic; bad weight vectors raise."""
    cfg = _bimodal_cfg(n_policies=3)
    a, b = synthetic_trace(cfg), synthetic_trace(cfg)
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert (ra.size, ra.policy) == (rb.size, rb.policy)
        assert ra.size in BIMODAL_SIZES
        assert ra.policy == ra.region % 3
    by_burst = {}
    for r in a:
        by_burst[(r.region, r.arrival)] = by_burst.get(
            (r.region, r.arrival), 0) + 1
    for (region, arrival), k in by_burst.items():
        assert k in BIMODAL_SIZES
    drawn = {r.size for r in a}
    assert 1 in drawn and max(drawn) >= 4     # both modes actually drawn
    with pytest.raises(ValueError):
        synthetic_trace(TraceConfig(region_sizes=(1, 2),
                                    region_size_weights=(1.0,)))


# ------------------------------------------------ cross-policy parity

@pytest.mark.parametrize("route", ["auto", "interpret"])
@pytest.mark.parametrize("kind", ["gru", "fnn"])
@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_multi_policy_lane_matches_its_own_single_server(domain, kind,
                                                         route):
    """Every lane of an N-policy dispatch == the single-policy server of
    that lane's checkpoint at the same slot shape, bitwise (actions,
    logits, v) — both domains x both backbones x both dispatch routes."""
    frames = _frames(domain, kind)
    pidx = np.arange(S, dtype=np.int32) % N_POL
    srv = _server(domain, route)
    a, lg, v = srv.forward_slot(frames, S, pidx)
    singles = {n: _server(domain, route, policy=n).forward_slot(frames, S)
               for n in range(N_POL)}
    for i in range(S):
        sa, slg, sv = singles[int(pidx[i])]
        assert jnp.array_equal(lg[i], slg[i]), i
        assert jnp.array_equal(v[i], sv[i]), i
        assert int(a[i]) == int(sa[i]), i


@pytest.mark.parametrize("route", ["auto", "interpret"])
def test_multi_policy_packed_vs_dense_and_pad_zeroing(route):
    """Packed-vs-dense parity with NaN pad lanes + a pad/unroutable
    checkpoint index: real lanes bitwise-match an all-copies dense
    dispatch with the same per-lane checkpoint; pad lanes and lanes
    whose index routes to no checkpoint come back exactly zero."""
    frames = _frames("traffic", "gru").copy()
    srv = _server("traffic", route)
    n_valid = 5
    frames[n_valid:] = np.nan
    pidx = np.array([0, 1, 0, 1, 1, 7, 7, 7], np.int32)   # pad idx junk
    a, lg, v = srv.forward_slot(frames, n_valid, pidx)
    for i in range(n_valid):
        dense = srv.forward_slot(np.tile(frames[i], (S, 1)), S,
                                 np.full(S, pidx[i], np.int32))
        assert jnp.array_equal(lg[i], dense[1][i]), i
        assert jnp.array_equal(v[i], dense[2][i]), i
        assert int(a[i]) == int(dense[0][i]), i
    assert not jnp.any(lg[n_valid:]) and not jnp.any(v[n_valid:])
    assert not jnp.any(a[n_valid:])
    # unroutable REAL lane: no checkpoint selected -> exact zeros too
    pidx2 = np.array([0, N_POL + 3] + [0] * (S - 2), np.int32)
    _, lg2, v2 = srv.forward_slot(frames, 2, pidx2)
    assert not jnp.any(lg2[1]) and v2[1] == 0.0


def test_multi_policy_xla_route_matches_training_net():
    """The multi-policy xla route is the training net verbatim per
    checkpoint (where-selected) — logits/actions bitwise vs the fused
    routes' single-policy contract check stays per-route, so here we
    pin the xla multi server against its own single-policy xla servers."""
    frames = _frames("traffic", "gru")
    pidx = np.arange(S, dtype=np.int32) % N_POL
    a, lg, v = _server("traffic", "xla").forward_slot(frames, S, pidx)
    for i in range(S):
        sa, slg, sv = _server("traffic", "xla",
                              policy=int(pidx[i])).forward_slot(frames, S)
        assert jnp.array_equal(lg[i], slg[i]) and jnp.array_equal(
            v[i], sv[i]) and int(a[i]) == int(sa[i])


def test_stack_policy_weights_abi():
    """The stacked ABI: one leading policy axis per flat leaf, each
    slice bitwise the per-checkpoint flat weights."""
    _, params = _policies("traffic")
    stacked = ppo.stack_policy_weights(params)
    flats = [ppo.flat_policy_weights(p) for p in params]
    assert len(stacked) == len(flats[0])
    for j, w in enumerate(stacked):
        assert w.shape == (N_POL,) + flats[0][j].shape
        for n in range(N_POL):
            assert jnp.array_equal(w[n], flats[n][j])


# ------------------------------------------- multi-slot server + stats

def test_staging_buffers_reused_and_tail_never_repadded():
    """One staging buffer pair per shape, reused across dispatches (no
    per-dispatch allocation); the tail keeps the previous dispatch's
    lanes, and the kernel-boundary mask makes that garbage harmless:
    outputs bitwise-match a freshly zero-padded dispatch."""
    srv = _server("traffic", "auto")
    frames = _frames("traffic", "gru")
    reqs = [Request(rid=i, region=0, klass=0, arrival=0.0, deadline=1.0,
                    frame=frames[i], policy=i % N_POL) for i in range(S)]
    f_full, p_full = srv._pack(reqs, S)
    f_again, p_again = srv._pack(reqs[:3], S)
    assert f_again is f_full and p_again is p_full    # same buffers
    assert np.array_equal(f_full[3:], frames[3:])     # leftover tail kept
    f_other, _ = srv._pack(reqs[:2], 4)
    assert f_other is not f_full and f_other.shape == (4, srv.frame_dim)
    dirty = srv.forward_slot(f_full, 3, p_full)
    clean = np.zeros_like(f_full)
    clean[:3] = frames[:3]
    ref = srv.forward_slot(clean, 3, p_full)
    for d, r in zip(dirty, ref):
        assert jnp.array_equal(d, r)


def test_multi_slot_server_warmup_and_scheduler_choice():
    """A bucket-set server compiles one program per shape up front
    (``warmup``), keeps ``slot`` = max shape for the single-slot API,
    and its default scheduler is the matching bucketed one."""
    pcfg, params = _policies("traffic")
    srv = PolicyServer(params[0], obs_dim=pcfg.obs_dim,
                       n_actions=pcfg.n_actions, slot=(2, 4, 8),
                       route="auto")
    assert srv.slots == (2, 4, 8) and srv.slot == 8
    assert isinstance(srv.make_scheduler(), BucketedSlotScheduler)
    srv.warmup()
    assert srv._warmed >= {2, 4, 8}
    single = PolicyServer(params[0], obs_dim=pcfg.obs_dim,
                          n_actions=pcfg.n_actions, slot=8)
    assert not isinstance(single.make_scheduler(), BucketedSlotScheduler)
    with pytest.raises(ValueError):
        PolicyServer(params[0], obs_dim=pcfg.obs_dim,
                     n_actions=pcfg.n_actions, slot=(0, 8))


def test_bucketed_virtual_replay_stats_exact_and_less_waste():
    """Virtual replay of one bimodal trace on a bucketed vs a single-slot
    server: the stats counters equal ground-truth recounts (dispatch
    totals, real lanes = served, histogram mass), replays are
    deterministic, and the bucketed padded-lane fraction is strictly
    lower while serving the identical request set with zero drops."""
    pcfg, params = _policies("traffic")
    cfg = _bimodal_cfg(n_policies=N_POL, frame_dim=pcfg.obs_dim)
    trace = synthetic_trace(cfg)
    kw = dict(obs_dim=pcfg.obs_dim, n_actions=pcfg.n_actions)
    srv_b = PolicyServer(params, slot=(2, 8, 64), **kw)
    srv_s = PolicyServer(params, slot=64, **kw)
    rep_b = srv_b.serve(trace, mode="virtual", service_time_s=0.002)
    rep_s = srv_s.serve(trace, mode="virtual", service_time_s=0.002)
    for rep in (rep_b, rep_s):
        assert rep.served == rep.requests == len(trace)
        st_ = rep.stats
        assert sum(st_.dispatches_by_slot.values()) == rep.dispatches
        assert st_.real_lanes == rep.served
        assert rep.mean_occupancy * rep.dispatches == pytest.approx(
            rep.served)
        for shape, hist in st_.occupancy_hist_by_slot.items():
            assert sum(hist) == st_.dispatches_by_slot[shape]
            assert len(hist) == 8
        total = st_.total_lanes
        assert st_.padded_lane_frac == pytest.approx(
            (total - st_.real_lanes) / total)
    assert set(rep_b.stats.dispatches_by_slot) <= {2, 8, 64}
    assert set(rep_s.stats.dispatches_by_slot) == {64}
    assert rep_b.stats.padded_lane_frac < rep_s.stats.padded_lane_frac
    rep_b2 = srv_b.serve(trace, mode="virtual", service_time_s=0.002)
    assert rep_b2.summary() == rep_b.summary()
    for key in ("padded_lane_frac", "dispatches_by_slot",
                "mean_occupancy_by_slot", "occupancy_hist_by_slot"):
        assert key in rep_b.summary()


# ------------------------------------------------ adversarial traces

def test_adversarial_all_max_size_bursts():
    """Every burst at exactly the largest bucket: dispatches run only at
    the max shape, fully occupied, zero drops, exact accounting — the
    degenerate workload where bucketing must not cost anything."""
    frame = np.zeros(4, np.float32)
    trace = [Request(rid=r * 8 + lane, region=r, klass=0,
                     arrival=0.001 * r, deadline=0.001 * r + 1.0,
                     frame=frame, size=8)
             for r in range(6) for lane in range(8)]
    sched, pops = _drive_bucketed(trace, (2, 4, 8), service_s=0.0005)
    assert all(shape == 8 for shape, _ in pops)
    assert all(len(b) == 8 for _, b in pops)          # fully occupied
    assert sched.served == len(trace) and sched.deadline_misses == 0
    assert sched.dispatches_by_bucket == {2: 0, 4: 0, 8: len(pops)}


def test_adversarial_bursts_exceeding_largest_bucket():
    """A burst bigger than the largest compiled shape is admitted at the
    largest bucket and split across consecutive dispatches — no drops,
    every request exactly once, and no dispatch exceeds its shape."""
    frame = np.zeros(4, np.float32)
    trace = [Request(rid=lane, region=0, klass=0, arrival=0.0,
                     deadline=1.0, frame=frame, size=20)
             for lane in range(20)]
    sched = BucketedSlotScheduler((2, 4, 8))
    assert sched.bucket_for(20) == 8                  # clamped to max
    sched2, pops = _drive_bucketed(trace, (2, 4, 8))
    assert sorted(r.rid for _, b in pops for r in b) == list(range(20))
    assert [len(b) for _, b in pops] == [8, 8, 4]     # split, in order
    assert [s for s, _ in pops] == [8, 8, 4]
    assert sched2.served == 20 and sched2.deadline_misses == 0
    assert sched2.admitted_by_bucket == {2: 0, 4: 0, 8: 20}


def test_adversarial_flood_overload_keeps_pop_order_and_exact_misses():
    """Interleaved deadline classes under a 4x flood window pushing the
    replay past 1x load: the drop-free contract holds (every admitted
    request dispatches exactly once), misses equal an independent
    recount against absolute deadlines, the zero-slack class misses
    while the loosest class's extra copies spread across dispatches,
    and the bucketed pop order is still bitwise the single-slot pop
    order on the identical flooded trace."""
    base = _sized_trace(7)
    trace = flood_trace(base, at_s=0.01, duration_s=0.03, multiplier=4)
    assert len(trace) > len(base)                     # window was hit
    # service chosen so offered load in the flood window exceeds 1x
    sched, pops = _drive_bucketed(trace, (2, 4, 8), service_s=0.004)
    assert sorted(r.rid for _, b in pops for r in b) == \
        list(range(len(trace)))
    misses = sum(t > d for (_, _, _, d, t) in sched.completions)
    assert sched.deadline_misses == misses
    by_class = {}
    for (_, k, _, d, t) in sched.completions:
        by_class[k] = by_class.get(k, 0) + (t > d)
    assert sched.misses_by_class == {k: v for k, v in by_class.items()
                                     if v}
    assert sched.misses_by_class.get(0, 0) > 0        # zero-slack class
    sched_s = SlotScheduler(8)
    pops_s, now, i = [], 0.0, 0
    while i < len(trace) or sched_s.pending:
        while i < len(trace) and trace[i].arrival <= now:
            sched_s.admit(trace[i])
            i += 1
        if not sched_s.pending:
            now = trace[i].arrival
            continue
        batch = sched_s.next_batch()
        now += 0.004
        sched_s.complete(batch, now)
        pops_s.append(batch)
    assert [[r.rid for r in b] for _, b in pops] == \
        [[r.rid for r in b] for b in pops_s]


def test_set_coarse_changes_shapes_only_never_the_queue():
    """Brownout's coarse collapse dispatches every batch at the largest
    shape but pops the identical batches in the identical order with
    identical miss accounting — shapes are policy, the queue is not."""
    trace = _sized_trace(5)
    _, pops_fine = _drive_bucketed(trace, (2, 4, 8))
    sched = BucketedSlotScheduler((2, 4, 8))
    sched.set_coarse(True)
    pops, now, i = [], 0.0, 0
    while i < len(trace) or sched.pending:
        while i < len(trace) and trace[i].arrival <= now:
            sched.admit(trace[i])
            i += 1
        if not sched.pending:
            now = trace[i].arrival
            continue
        shape, batch = sched.next_dispatch()
        now += 0.003
        sched.complete(batch, now)
        pops.append((shape, batch))
    assert all(shape == 8 for shape, _ in pops)       # coarse: max shape
    assert [[r.rid for r in b] for _, b in pops] == \
        [[r.rid for r in b] for _, b in pops_fine]
    sched.set_coarse(False)
    assert not sched.coarse


# -------------------------------------------------------------- driver

def test_policy_serve_driver_bucketed_cross_policy(tmp_path):
    """The launch driver serves a bimodal wall-clock trace through a
    calibrated bucketed multi-policy server to completion, and the JSON
    report carries the waste observability."""
    out = tmp_path / "serve.json"
    res = policy_serve.main([
        "--domain", "traffic", "--slot", "16", "--calibrate", "2",
        "--bimodal", "--n-policies", "2", "--regions", "6",
        "--rps", "400", "--duration-s", "0.05", "--out", str(out)])
    assert res["served"] == res["requests"] > 0
    assert res["calibrated"] and isinstance(res["slot"], list)
    assert res["n_policies"] == 2
    assert 0.0 <= res["padded_lane_frac"] < 1.0
    assert sum(res["dispatches_by_slot"].values()) == res["dispatches"]
    assert json.loads(out.read_text()) == res

    res2 = policy_serve.main([
        "--domain", "traffic", "--buckets", "4,16", "--regions", "4",
        "--rps", "400", "--duration-s", "0.05"])
    assert res2["slot"] == [4, 16]
    assert set(res2["dispatches_by_slot"]) <= {"4", "16"}
