"""Multi-agent batched IALS: GS<->LS consistency, shapes, determinism,
F-IALS branches — the Distributed-IALS construction's correctness suite."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import collect, ials, influence, multi_ials
from repro.envs.traffic import (TrafficConfig, make_local_traffic_env,
                                make_multi_traffic_env, make_traffic_env,
                                local_traffic_state)
from repro.envs.warehouse import (WarehouseConfig, make_local_warehouse_env,
                                  make_multi_warehouse_env,
                                  local_warehouse_state)

AGENTS4 = jnp.array([[0, 0], [1, 3], [2, 2], [4, 1]])


def _gs_rollout(gs, key, T, n_actions):
    """-> (initial state, (T,) or (T, A) actions, stacked step outputs)."""
    k0, key = jax.random.split(key)
    s0 = gs.reset(k0)
    a_shape = (T, gs.spec.n_agents) if gs.spec.n_agents > 1 else (T,)
    acts = jax.random.randint(key, a_shape, 0, n_actions)

    def step(carry, xs):
        s = carry
        a, k = xs
        s, obs, r, info = gs.step(s, a, k)
        return s, {"obs": obs, "r": r, "u": info["u"]}

    _, traj = jax.lax.scan(step, s0, (acts, jax.random.split(key, T)))
    return s0, acts, traj


def _ls_replay(ls, s_loc, acts, us):
    """Replay recorded (a_t, u_t) through a local simulator."""
    def step(carry, xs):
        s = carry
        a, u = xs
        s, obs, r, _ = ls.step(s, a, u, jax.random.PRNGKey(0))
        return s, {"obs": obs, "r": r}

    _, traj = jax.lax.scan(step, s_loc, (acts, us))
    return traj


# ---------------------------------------------------------------------------
# GS <-> LS consistency: the true u_t drives the LS onto the GS trajectory
# ---------------------------------------------------------------------------

def test_traffic_ls_replay_matches_gs():
    """With the 8-bit (ext_influence) u_t, replaying a GS rollout's true
    influence sources through the LS reproduces the agent's observations and
    rewards exactly — the defining property of the IALS construction."""
    cfg = TrafficConfig(ext_influence=True)
    gs = make_traffic_env(cfg)
    ls = make_local_traffic_env(cfg)
    key = jax.random.PRNGKey(0)
    s0, acts, traj = _gs_rollout(gs, key, T=24, n_actions=2)
    ai, aj = cfg.agent
    s_loc = local_traffic_state(s0, ai, aj)
    replay = _ls_replay(ls, s_loc, acts, traj["u"])
    assert jnp.array_equal(replay["obs"], traj["obs"])
    assert jnp.allclose(replay["r"], traj["r"], atol=1e-6)


def test_traffic_multi_ls_replay_matches_gs_per_agent():
    """Same exactness for every agent of a multi-agent GS rollout."""
    cfg = TrafficConfig(ext_influence=True)
    gs = make_multi_traffic_env(cfg, AGENTS4)
    ls = make_local_traffic_env(cfg)
    key = jax.random.PRNGKey(1)
    s0, acts, traj = _gs_rollout(gs, key, T=20, n_actions=2)

    def replay_agent(i, j, a_seq, u_seq):
        return _ls_replay(ls, local_traffic_state(s0, i, j), a_seq, u_seq)

    replay = jax.vmap(replay_agent)(
        AGENTS4[:, 0], AGENTS4[:, 1],
        jnp.moveaxis(acts, 1, 0), jnp.moveaxis(traj["u"], 1, 0))
    assert jnp.array_equal(replay["obs"],
                           jnp.moveaxis(traj["obs"], 1, 0))
    assert jnp.allclose(replay["r"], jnp.moveaxis(traj["r"], 1, 0),
                        atol=1e-6)


def test_warehouse_ls_replay_matches_gs():
    """Warehouse replay is exact modulo item spawns (independent noise in
    both simulators), so test with spawning disabled."""
    cfg = WarehouseConfig(p_item=0.0)
    gs = make_multi_warehouse_env(cfg, AGENTS4)
    ls = make_local_warehouse_env(cfg)
    key = jax.random.PRNGKey(2)
    s0, acts, traj = _gs_rollout(gs, key, T=16, n_actions=5)

    def replay_agent(i, j, a_seq, u_seq):
        return _ls_replay(ls, local_warehouse_state(s0, i, j), a_seq, u_seq)

    replay = jax.vmap(replay_agent)(
        AGENTS4[:, 0], AGENTS4[:, 1],
        jnp.moveaxis(acts, 1, 0), jnp.moveaxis(traj["u"], 1, 0))
    assert jnp.array_equal(replay["obs"],
                           jnp.moveaxis(traj["obs"], 1, 0))
    assert jnp.allclose(replay["r"], jnp.moveaxis(traj["r"], 1, 0),
                        atol=1e-6)


# ---------------------------------------------------------------------------
# Multi-agent GS invariants
# ---------------------------------------------------------------------------

def test_multi_gs_shapes_and_single_agent_equivalence():
    cfg = TrafficConfig()
    multi = make_multi_traffic_env(cfg, jnp.array([cfg.agent]))
    single = make_traffic_env(cfg)
    key = jax.random.PRNGKey(3)
    sm, ss = multi.reset(key), single.reset(key)
    am = jnp.zeros((1,), jnp.int32)
    sm2, om, rm, im = multi.step(sm, am, key)
    ss2, os_, rs_, is_ = single.step(ss, jnp.int32(0), key)
    assert om.shape == (1, single.spec.obs_dim)
    # the single-agent env is the squeezed 1-agent multi env
    assert jnp.array_equal(om[0], os_)
    assert float(rm[0]) == float(rs_)
    assert jnp.array_equal(im["u"][0], is_["u"])


def test_multi_warehouse_gs_shapes():
    cfg = WarehouseConfig()
    env = make_multi_warehouse_env(cfg, AGENTS4)
    key = jax.random.PRNGKey(4)
    s = env.reset(key)
    s2, obs, r, info = jax.jit(env.step)(s, jnp.zeros((4,), jnp.int32), key)
    assert obs.shape == (4, env.spec.obs_dim)
    assert r.shape == (4,)
    assert info["u"].shape == (4, 12)
    assert info["dset"].shape == (4, 24)
    assert env.spec.n_agents == 4


# ---------------------------------------------------------------------------
# multi_ials: shapes, determinism, batched == loop
# ---------------------------------------------------------------------------

def _traffic_multi_ials(A=4, **kw):
    ls = make_local_traffic_env()
    acfg = influence.AIPConfig(kind="gru", d_in=ls.spec.dset_dim,
                               n_out=ls.spec.n_influence, hidden=8)
    params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(0), A))
    return ls, acfg, params, multi_ials.make_multi_ials(
        ls, params, acfg, A, **kw)


def test_multi_ials_shapes_and_determinism():
    ls, acfg, params, env = _traffic_multi_ials()
    key = jax.random.PRNGKey(5)
    s = env.reset(key)
    acts = jnp.zeros((4,), jnp.int32)
    s2, obs, r, info = jax.jit(env.step)(s, acts, key)
    assert obs.shape == (4, ls.spec.obs_dim)
    assert r.shape == (4,)
    assert info["u"].shape == (4, ls.spec.n_influence)
    assert info["u_probs"].shape == (4, ls.spec.n_influence)
    assert env.observe(s2).shape == (4, ls.spec.obs_dim)
    # same key -> identical transition
    s3, obs3, r3, _ = jax.jit(env.step)(s, acts, key)
    assert jnp.array_equal(obs, obs3) and jnp.array_equal(r, r3)


def test_multi_ials_agent_i_matches_single_ials():
    """Agent i of the batched construction == a single IALS built from the
    same AIP, stepped with the same key."""
    ls, acfg, params, env = _traffic_multi_ials()
    key = jax.random.PRNGKey(6)
    s = env.reset(key)
    acts = jnp.array([0, 1, 0, 1], jnp.int32)
    keys = jax.random.split(key, 4)
    s2, obs, r, info = env.step(s, acts, key)
    for i in (0, 2):
        p_i = jax.tree_util.tree_map(lambda l: l[i], params)
        single = ials.make_ials(ls, p_i, acfg)
        s_i = ials.IALSState(
            ls_state=jax.tree_util.tree_map(lambda l: l[i], s.ls_state),
            aip_state=s.aip_state[i])
        _, obs_i, r_i, info_i = single.step(s_i, acts[i], keys[i])
        assert jnp.array_equal(obs_i, obs[i])
        assert jnp.array_equal(info_i["u"], info["u"][i])


def test_multi_ials_vmaps_over_env_batch():
    """The A-agent IALS itself vmaps over an env batch (PPO's layout)."""
    _, _, _, env = _traffic_multi_ials()
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    states = jax.vmap(env.reset)(keys)
    acts = jnp.zeros((8, 4), jnp.int32)
    s2, obs, r, info = jax.jit(jax.vmap(env.step))(states, acts, keys)
    assert obs.shape == (8, 4, env.spec.obs_dim)
    assert r.shape == (8, 4)


# ---------------------------------------------------------------------------
# F-IALS branches (fixed marginal / fixed per-head vector)
# ---------------------------------------------------------------------------

def _u_rate(env, key, A, T=192):
    s = env.reset(key)

    def step(carry, k):
        s = carry
        s, _, _, info = env.step(s, jnp.zeros((A,), jnp.int32), k)
        return s, info["u"]

    _, us = jax.lax.scan(step, s, jax.random.split(key, T))
    return us


def test_f_ials_fixed_marginal_scalar():
    _, _, _, env = _traffic_multi_ials(fixed_marginal=0.3)
    us = _u_rate(env, jax.random.PRNGKey(8), A=4)
    assert abs(float(us.mean()) - 0.3) < 0.05


def test_f_ials_fixed_marginal_vec_per_agent():
    """(A, M) per-agent marginals: each agent's LS sees its own rate."""
    marg = jnp.stack([jnp.full((4,), p) for p in (0.05, 0.2, 0.5, 0.8)])
    _, _, _, env = _traffic_multi_ials(fixed_marginal_vec=marg)
    us = _u_rate(env, jax.random.PRNGKey(9), A=4)   # (T, A, M)
    rates = us.mean(axis=(0, 2))
    assert jnp.all(jnp.abs(rates - jnp.array([0.05, 0.2, 0.5, 0.8])) < 0.07)


def test_single_ials_fixed_marginal_vec_branch():
    """core/ials.py fixed_marginal_vec branch: per-head probabilities."""
    ls = make_local_traffic_env()
    acfg = influence.AIPConfig(kind="fnn", d_in=ls.spec.dset_dim,
                               n_out=4, hidden=8, stack=1)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    vec = jnp.array([0.0, 1.0, 0.0, 1.0])
    env = ials.make_ials(ls, params, acfg, fixed_marginal_vec=vec)
    key = jax.random.PRNGKey(10)
    s = env.reset(key)
    for t in range(8):
        key, k = jax.random.split(key)
        s, _, _, info = jax.jit(env.step)(s, jnp.int32(0), k)
        assert jnp.array_equal(info["u_probs"], vec)
        assert jnp.array_equal(info["u"], vec)   # p in {0,1} is deterministic


# ---------------------------------------------------------------------------
# Batched AIP training
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_aip_batched_matches_loop():
    """vmapped batched fit == fitting each agent's AIP separately."""
    key = jax.random.PRNGKey(11)
    A, N, T, D, M = 3, 8, 12, 6, 2
    d = jax.random.bernoulli(key, 0.5, (A, N, T, D)).astype(jnp.float32)
    u = d[..., :M]
    acfg = influence.AIPConfig(kind="fnn", d_in=D, n_out=M, hidden=8,
                               stack=1)
    keys = jax.random.split(jax.random.PRNGKey(12), A)
    bp, bm = influence.train_aip_batched(acfg, d, u, keys, epochs=3)
    assert len(bm["final_loss_per_agent"]) == A
    for i in range(A):
        sp, sm = influence.train_aip(acfg, d[i], u[i], keys[i], epochs=3)
        assert abs(sm["final_loss"] - bm["final_loss_per_agent"][i]) < 1e-4
        for bl, sl in zip(jax.tree_util.tree_leaves(bp),
                          jax.tree_util.tree_leaves(sp)):
            assert jnp.allclose(bl[i], sl, atol=1e-5)
