"""Fused AIP-step kernel: parity vs the ref.py oracle (logits exact with
shared rational gates, Bernoulli draws bit-identical given the same counter
bits and distributionally correct over many bits), plus the rational
activation contracts the kernel relies on."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import influence
from repro.kernels import ops, ref
from repro.kernels.aip_step import aip_step as aip_step_kernel
from repro.nn.act import fast_sigmoid, fast_tanh, uniform_from_bits


def _weights(key, D, H, M, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return (jax.random.normal(ks[0], (D, 3 * H), dtype) * 0.2,
            jax.random.normal(ks[1], (H, 3 * H), dtype) * 0.2,
            jax.random.normal(ks[2], (3 * H,), dtype) * 0.1,
            jax.random.normal(ks[3], (H, M), dtype) * 0.2,
            jax.random.normal(ks[4], (M,), dtype) * 0.1)


@pytest.mark.parametrize("B,D,H,M", [
    (4, 24, 32, 12),
    (16, 40, 64, 4),
    (1, 8, 16, 1),
])
def test_aip_step_kernel_matches_oracle(B, D, H, M):
    key = jax.random.PRNGKey(0)
    wx, wh, b, hw, hb = _weights(key, D, H, M)
    d = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    h = jax.random.normal(jax.random.PRNGKey(2), (B, H)) * 0.5
    bits = jax.random.bits(jax.random.PRNGKey(3), (B, M), jnp.uint32)
    h2k, lgk, uk = aip_step_kernel(d, h, wx, wh, b, hw, hb, bits,
                                   interpret=True)
    h2r, lgr, ur = ref.aip_step_ref(d, h, wx, wh, b, hw, hb, bits)
    assert float(jnp.abs(h2k - h2r).max()) < 1e-5
    assert float(jnp.abs(lgk - lgr).max()) < 1e-5
    # same bits -> bit-identical Bernoulli draws
    assert jnp.array_equal(uk, ur)
    assert set(jnp.unique(uk).tolist()) <= {0.0, 1.0}


def test_aip_step_matches_influence_step():
    """The fused op computes exactly the AIP the training loop fits:
    oracle logits == influence.step logits on the same GRU params."""
    cfg = influence.AIPConfig(kind="gru", d_in=10, n_out=5, hidden=24)
    params = influence.init_aip(cfg, jax.random.PRNGKey(4))
    d = jax.random.normal(jax.random.PRNGKey(5), (7, 10))
    h = jnp.zeros((7, 24))
    bits = jax.random.bits(jax.random.PRNGKey(6), (7, 5), jnp.uint32)
    logits, h2 = influence.step(params, cfg, h, d)
    h2o, lgo, _ = ops.aip_step(
        d, h, params["gru"]["wx"], params["gru"]["wh"], params["gru"]["b"],
        params["head"]["w"], params["head"]["b"], bits)
    assert float(jnp.abs(logits - lgo).max()) < 1e-5
    assert float(jnp.abs(h2 - h2o).max()) < 1e-5


def test_bernoulli_draws_distribution():
    """Over many independent bits the threshold-compare realises
    Bernoulli(sigmoid(logits)) per head."""
    cfg = influence.AIPConfig(kind="gru", d_in=6, n_out=3, hidden=16)
    params = influence.init_aip(cfg, jax.random.PRNGKey(7))
    d = jax.random.normal(jax.random.PRNGKey(8), (4, 6))
    h = jnp.zeros((4, 16))
    logits, _ = influence.step(params, cfg, h, d)
    probs = fast_sigmoid(logits)                      # (4, 3)
    n = 4000
    bits = jax.random.bits(jax.random.PRNGKey(9), (n, 4, 3), jnp.uint32)
    us = jax.vmap(lambda bt: ops.aip_step(
        d, h, params["gru"]["wx"], params["gru"]["wh"], params["gru"]["b"],
        params["head"]["w"], params["head"]["b"], bt)[2])(bits)
    rate = us.mean(axis=0)
    assert float(jnp.abs(rate - probs).max()) < 0.03


def test_uniform_from_bits_range_and_mean():
    bits = jax.random.bits(jax.random.PRNGKey(10), (100_000,), jnp.uint32)
    u = uniform_from_bits(bits)
    assert float(u.min()) >= 0.0 and float(u.max()) < 1.0
    assert abs(float(u.mean()) - 0.5) < 0.01


def test_fast_activations_accuracy():
    x = jnp.linspace(-12.0, 12.0, 20001)
    assert float(jnp.abs(fast_tanh(x) - jnp.tanh(x)).max()) < 3e-3
    assert float(jnp.abs(fast_sigmoid(x) - jax.nn.sigmoid(x)).max()) < 3e-4
    # saturation and symmetry
    assert float(fast_tanh(jnp.float32(20.0))) == pytest.approx(1.0, abs=1e-5)
    assert float(fast_sigmoid(jnp.float32(-20.0))) == pytest.approx(
        0.0, abs=1e-5)


def test_gru_kernel_interpret_autodetect():
    """gru.gru_sequence's interpret default resolves from the backend
    (not hard-coded True) and still matches the oracle."""
    from repro.kernels.gru import gru_sequence
    key = jax.random.PRNGKey(11)
    wx, wh, b, _, _ = _weights(key, 12, 16, 1)
    x = jax.random.normal(jax.random.PRNGKey(12), (3, 9, 12))
    h0 = jnp.zeros((3, 16))
    hs, hT = gru_sequence(x, wx, wh, b, h0)          # interpret=None -> auto
    hs_r, hT_r = ref.gru_sequence_ref(x, wx, wh, b, h0)
    assert float(jnp.abs(hs - hs_r).max()) < 1e-5
    assert float(jnp.abs(hT - hT_r).max()) < 1e-5
