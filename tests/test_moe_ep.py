"""shard_map expert-parallel MoE == GSPMD reference (multi-device subprocess;
both 1-D and 2-D expert sharding, forward AND gradients)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.mark.slow
def test_single_device_fallback_matches_gspmd():
    """Without a mesh, moe_apply_ep must be exactly moe_apply."""
    from repro.nn import moe as moe_lib
    from repro.nn.moe_ep import moe_apply_ep
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(key, 16, 32, 4, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    a, _ = moe_lib.moe_apply(p, x, top_k=2)
    b, _ = moe_apply_ep(p, x, top_k=2)
    assert float(jnp.abs(a - b).max()) == 0.0


@pytest.mark.slow
def test_ep_matches_gspmd_on_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.nn import moe as moe_lib
        from repro.nn.moe_ep import moe_apply_ep
        from repro.distributed.act_sharding import use_mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        key = jax.random.PRNGKey(0)
        d, E, k, dff = 32, 8, 2, 64
        p = moe_lib.moe_init(key, d, dff, E, 1)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, d))
        cf = E / k  # dropless -> exact equality expected
        res = {}
        with mesh, use_mesh(mesh):
            ref, _ = jax.jit(lambda p, x: moe_lib.moe_apply(
                p, x, top_k=k, capacity_factor=cf))(p, x)
            gr = jax.jit(jax.grad(lambda p, x: moe_lib.moe_apply(
                p, x, top_k=k, capacity_factor=cf)[0].sum()))(p, x)
            for ax in ("model", "data_model"):
                out, _ = jax.jit(lambda p, x: moe_apply_ep(
                    p, x, top_k=k, capacity_factor=cf,
                    expert_axes=ax))(p, x)
                ge = jax.jit(jax.grad(lambda p, x: moe_apply_ep(
                    p, x, top_k=k, capacity_factor=cf,
                    expert_axes=ax)[0].sum()))(p, x)
                errs = jax.tree_util.tree_map(
                    lambda a, b: float(jnp.abs(a - b).max()), gr, ge)
                res[ax] = {"fwd": float(jnp.abs(out - ref).max()),
                           "grad": max(jax.tree_util.tree_leaves(errs))}
        print(json.dumps(res))
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for ax in ("model", "data_model"):
        assert res[ax]["fwd"] < 1e-5, res
        assert res[ax]["grad"] < 1e-4, res
