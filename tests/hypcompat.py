"""Pure-pytest fallback for ``hypothesis`` (not installed in this image).

Test modules guard their import:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypcompat import given, settings, st

When hypothesis is missing, ``@given`` degrades to a deterministic
``pytest.mark.parametrize`` over the strategy's bounds, so every property's
core assertion still runs as a plain pytest case; ``settings`` becomes a
no-op.
"""
from __future__ import annotations

import itertools

import pytest


class _Strategy:
    def __init__(self, examples):
        # dedupe, preserving order (e.g. integers(0, 1) -> [0, 1])
        seen, out = set(), []
        for e in examples:
            if e not in seen:
                seen.add(e)
                out.append(e)
        self.examples = out


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=0):
        return _Strategy([min_value, max_value])

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy([min_value, max_value])

    @staticmethod
    def booleans():
        return _Strategy([False, True])

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements))


st = _Strategies()


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco


def given(**kwargs):
    names = list(kwargs)
    grids = [kwargs[n].examples for n in names]
    rows = list(itertools.product(*grids))

    def deco(fn):
        if len(names) == 1:
            return pytest.mark.parametrize(
                names[0], [r[0] for r in rows])(fn)
        return pytest.mark.parametrize(",".join(names), rows)(fn)

    return deco
