"""Algorithm 1 dataset collection: shapes, dset_full / custom-policy paths,
empirical marginal, and the multi-agent (N, T, A, ...) layout."""
import jax
import jax.numpy as jnp

from repro.core import collect
from repro.envs.traffic import TrafficConfig, make_traffic_env, \
    make_multi_traffic_env
from repro.envs.warehouse import make_warehouse_env

AGENTS = jnp.array([[0, 0], [2, 2], [4, 4]])


def test_collect_shapes_traffic():
    env = make_traffic_env()
    data = collect.collect_dataset(env, jax.random.PRNGKey(0),
                                   n_episodes=3, ep_len=7)
    assert data["d"].shape == (3, 7, env.spec.dset_dim)
    assert data["u"].shape == (3, 7, env.spec.n_influence)
    assert data["reward"].shape == (3, 7)


def test_collect_dset_full_path():
    env = make_warehouse_env()
    data = collect.collect_dataset(env, jax.random.PRNGKey(1),
                                   n_episodes=2, ep_len=5,
                                   dset_key="dset_full")
    assert data["d"].shape == (2, 5, env.spec.dset_full_dim)


def test_collect_custom_policy_is_used():
    env = make_traffic_env()

    def always_zero(key, obs):
        return jnp.int32(0)

    def always_one(key, obs):
        return jnp.int32(1)

    d0 = collect.collect_dataset(env, jax.random.PRNGKey(2), n_episodes=2,
                                 ep_len=6, policy=always_zero)
    d1 = collect.collect_dataset(env, jax.random.PRNGKey(2), n_episodes=2,
                                 ep_len=6, policy=always_one)
    # constant opposite phases -> different local dynamics, same PRNG keys
    assert not jnp.array_equal(d0["d"], d1["d"])


def test_collect_multi_agent_layout_and_per_agent():
    env = make_multi_traffic_env(TrafficConfig(), AGENTS)
    data = collect.collect_dataset(env, jax.random.PRNGKey(3),
                                   n_episodes=4, ep_len=6)
    assert data["d"].shape == (4, 6, 3, env.spec.dset_dim)
    assert data["u"].shape == (4, 6, 3, env.spec.n_influence)
    assert data["reward"].shape == (4, 6, 3)
    pa = collect.per_agent(data)
    assert pa["d"].shape == (3, 4, 6, env.spec.dset_dim)
    assert jnp.array_equal(pa["u"][1], data["u"][:, :, 1])


def test_empirical_marginal():
    us = jnp.zeros((2, 5, 4)).at[:, :, 1].set(1.0)
    m = collect.empirical_marginal(us)
    assert m.shape == (4,)
    assert jnp.array_equal(m, jnp.array([0.0, 1.0, 0.0, 0.0]))
    # per-agent layout (A, N, T, M) needs the explicit flag
    us_a = jnp.stack([us, 1.0 - us])
    m_a = collect.empirical_marginal(us_a, per_agent=True)
    assert m_a.shape == (2, 4)
    assert jnp.array_equal(m_a[0], m) and jnp.array_equal(m_a[1], 1.0 - m)


def test_collect_u_rate_sane_traffic():
    env = make_traffic_env()
    data = collect.collect_dataset(env, jax.random.PRNGKey(4),
                                   n_episodes=4, ep_len=32)
    rate = float(data["u"].mean())
    assert 0.0 < rate < 0.6     # influence events occur but are sparse
