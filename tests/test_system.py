"""End-to-end behaviour tests: the paper's pipeline produces its claims.

These are integration tests over the REAL components (GS -> Algorithm 1 ->
AIP -> IALS -> PPO), at CPU-budget scale. Statistical assertions use
generous margins; the full-strength versions live in benchmarks/.
"""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import collect, influence, ials

# full GS collections + AIP fits + PPO iterations: minutes -> tier-2
pytestmark = pytest.mark.slow
from repro.envs.traffic import make_traffic_env, make_local_traffic_env
from repro.envs.warehouse import make_warehouse_env, make_local_warehouse_env
from repro.rl import ppo


@pytest.fixture(scope="module")
def traffic_pipeline():
    key = jax.random.PRNGKey(0)
    gs = make_traffic_env()
    ls = make_local_traffic_env()
    data = collect.collect_dataset(gs, key, n_episodes=24, ep_len=96)
    acfg = influence.AIPConfig(kind="fnn", d_in=gs.spec.dset_dim,
                               n_out=gs.spec.n_influence, hidden=64, stack=8)
    aip, metrics = influence.train_aip(acfg, data["d"], data["u"],
                                       jax.random.PRNGKey(1), epochs=8)
    return gs, ls, data, acfg, aip, metrics


def test_algorithm1_collects_influence_pairs(traffic_pipeline):
    gs, ls, data, *_ = traffic_pipeline
    assert data["d"].shape[-1] == gs.spec.dset_dim
    assert data["u"].shape[-1] == gs.spec.n_influence
    rate = float(data["u"].mean())
    assert 0.01 < rate < 0.5     # influence events occur but are sparse


def test_trained_aip_beats_untrained(traffic_pipeline):
    gs, ls, data, acfg, aip, metrics = traffic_pipeline
    untrained = influence.init_aip(acfg, jax.random.PRNGKey(99))
    xe_tr = float(influence.xent_loss(aip, acfg, data["d"], data["u"]))
    xe_un = float(influence.xent_loss(untrained, acfg,
                                      data["d"], data["u"]))
    assert xe_tr < xe_un * 0.75  # Fig. 3 bottom: clear gap


def test_ials_faster_than_gs(traffic_pipeline):
    """Fig. 3 middle: the IALS simulates faster than the GS (25x fewer
    intersections -> less work per step)."""
    gs, ls, data, acfg, aip, _ = traffic_pipeline
    sim = ials.make_ials(ls, aip, acfg)
    from jax import lax

    def make_roll(env):
        def run(key):
            keys = jax.random.split(key, 8)
            st = jax.vmap(env.reset)(keys)

            def step(c, k):
                a = jax.random.randint(k, (8,), 0, 2)
                st, o, r, _ = jax.vmap(env.step)(c, a,
                                                 jax.random.split(k, 8))
                return st, r
            st, rs = lax.scan(step, st, jax.random.split(key, 64))
            return rs.sum()
        return jax.jit(run)

    key = jax.random.PRNGKey(5)
    t = {}
    for name, env in (("gs", gs), ("ials", sim)):
        fn = make_roll(env)
        jax.block_until_ready(fn(key))
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(key)
        jax.block_until_ready(out)
        t[name] = time.perf_counter() - t0
    assert t["ials"] < t["gs"], t


def test_ppo_on_ials_evaluates_on_gs(traffic_pipeline):
    gs, ls, data, acfg, aip, _ = traffic_pipeline
    sim = ials.make_ials(ls, aip, acfg)
    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim, n_actions=2, n_envs=8,
                         rollout_len=64, episode_len=96)
    key = jax.random.PRNGKey(7)
    params = ppo.init_policy(pcfg, key)
    opt, it_fn = ppo.make_train_iteration(sim, pcfg)
    ost = opt.init(params)
    rs = ppo.init_rollout_state(sim, pcfg, key)
    for _ in range(3):
        key, k = jax.random.split(key)
        params, ost, rs, m = it_fn(params, ost, rs, k)
    r = ppo.evaluate(gs, pcfg, params, key, n_episodes=4, ep_len=64)
    assert 0.0 <= r <= 1.0
    assert jnp.isfinite(jnp.asarray(m["loss"]))


def test_warehouse_pipeline_end_to_end():
    key = jax.random.PRNGKey(1)
    gs = make_warehouse_env()
    ls = make_local_warehouse_env()
    data = collect.collect_dataset(gs, key, n_episodes=12, ep_len=64)
    acfg = influence.AIPConfig(kind="gru", d_in=gs.spec.dset_dim,
                               n_out=gs.spec.n_influence, hidden=32)
    aip, m = influence.train_aip(acfg, data["d"], data["u"],
                                 jax.random.PRNGKey(2), epochs=4)
    sim = ials.make_ials(ls, aip, acfg)
    pcfg = ppo.PPOConfig(obs_dim=gs.spec.obs_dim, n_actions=5,
                         frame_stack=8, n_envs=4, rollout_len=32,
                         episode_len=64)
    params = ppo.init_policy(pcfg, key)
    opt, it_fn = ppo.make_train_iteration(sim, pcfg)
    params, ost, rs, metrics = it_fn(params, opt.init(params),
                                     ppo.init_rollout_state(sim, pcfg, key),
                                     key)
    assert jnp.isfinite(jnp.asarray(metrics["loss"]))


def test_f_ials_marginal_mode():
    """App. E: the F-IALS drives the LS with a fixed marginal."""
    ls = make_local_traffic_env()
    acfg = influence.AIPConfig(kind="fnn", d_in=ls.spec.dset_dim, n_out=4,
                               hidden=8, stack=1)
    aip = influence.init_aip(acfg, jax.random.PRNGKey(0))
    sim = ials.make_ials(ls, aip, acfg, fixed_marginal=0.1)
    key = jax.random.PRNGKey(4)
    s = sim.reset(key)
    us = []
    for _ in range(128):
        key, k = jax.random.split(key)
        s, o, r, info = jax.jit(sim.step)(s, jnp.int32(0), k)
        us.append(info["u"])
    rate = float(jnp.stack(us).mean())
    assert abs(rate - 0.1) < 0.06
