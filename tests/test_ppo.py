"""PPO: GAE correctness vs hand computation; learning on a trivial task."""
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.api import Env, EnvSpec
from repro.rl import ppo


def test_gae_matches_manual():
    T, N = 4, 1
    batch = {
        "v": jnp.array([[1.0], [2.0], [3.0], [4.0]]),
        "r": jnp.array([[1.0], [1.0], [1.0], [1.0]]),
        "done": jnp.zeros((T, N)),
    }
    v_last = jnp.array([5.0])
    gamma, lam = 0.9, 0.8
    adv, ret = ppo.gae(batch, v_last, gamma, lam)
    # manual backward recursion
    v = np.array([1, 2, 3, 4, 5.0])
    a = np.zeros(5)
    for t in reversed(range(4)):
        delta = 1.0 + gamma * v[t + 1] - v[t]
        a[t] = delta + gamma * lam * a[t + 1]
    np.testing.assert_allclose(np.asarray(adv[:, 0]), a[:4], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ret[:, 0]), a[:4] + v[:4],
                               rtol=1e-5)


def test_gae_respects_done():
    batch = {"v": jnp.ones((3, 1)), "r": jnp.ones((3, 1)),
             "done": jnp.array([[0.0], [1.0], [0.0]])}
    adv, _ = ppo.gae(batch, jnp.array([10.0]), 0.99, 0.95)
    # t=1 terminates: its advantage ignores everything after
    assert abs(float(adv[1, 0]) - (1.0 - 1.0)) < 1e-6


class _BanditState(NamedTuple):
    t: jax.Array


def _make_bandit():
    """Action 1 pays 1.0, action 0 pays 0.0 — PPO must find it."""
    spec = EnvSpec(name="bandit", obs_dim=2, n_actions=2, n_influence=1,
                   dset_dim=1, dset_full_dim=1)

    def reset(key):
        return _BanditState(t=jnp.int32(0))

    def observe(s):
        return jnp.ones((2,))

    def step(s, a, key):
        r = a.astype(jnp.float32)
        s2 = _BanditState(t=s.t + 1)
        return s2, observe(s2), r, {}

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def test_ppo_learns_bandit():
    # gamma/lam at 0.9: a bandit has no long-horizon credit assignment, and
    # with gamma 0.99 the GAE advantage of one step is swamped by ~32 steps
    # of discounted future-action reward noise (variance, not a PPO bug).
    env = _make_bandit()
    cfg = ppo.PPOConfig(obs_dim=2, n_actions=2, n_envs=8, rollout_len=32,
                        episode_len=32, hidden=32, lr=1e-2,
                        entropy_coef=0.0, gamma=0.9, lam=0.9)
    key = jax.random.PRNGKey(0)
    params = ppo.init_policy(cfg, key)
    opt, it_fn = ppo.make_train_iteration(env, cfg)
    ost = opt.init(params)
    rs = ppo.init_rollout_state(env, cfg, key)
    rewards = []
    for i in range(15):
        key, k = jax.random.split(key)
        params, ost, rs, m = it_fn(params, ost, rs, k)
        rewards.append(float(m["mean_reward"]))
    assert rewards[-1] > 0.9, rewards


def test_ppo_fast_gates_training_equivalence():
    """The rational-gate policy net (fast_gates=True, the default — the
    path test_ppo_learns_bandit already covers) is training-equivalent
    to the exact-tanh net: PPO with exact tanh reaches the same reward
    threshold on the bandit, and the two forward passes agree to the
    gates' documented accuracy on the same params."""
    env = _make_bandit()
    cfg = ppo.PPOConfig(obs_dim=2, n_actions=2, n_envs=8, rollout_len=32,
                        episode_len=32, hidden=32, lr=1e-2,
                        entropy_coef=0.0, gamma=0.9, lam=0.9,
                        fast_gates=False)
    key = jax.random.PRNGKey(0)
    params = ppo.init_policy(cfg, key)
    opt, it_fn = ppo.make_train_iteration(env, cfg)
    ost = opt.init(params)
    rs = ppo.init_rollout_state(env, cfg, key)
    rewards = []
    for i in range(15):
        key, k = jax.random.split(key)
        params, ost, rs, m = it_fn(params, ost, rs, k)
        rewards.append(float(m["mean_reward"]))
    assert rewards[-1] > 0.9, rewards

    x = jax.random.normal(jax.random.PRNGKey(1), (64, 2))
    lg_f, v_f = ppo.policy_forward(params, x, fast_gates=True)
    lg_e, v_e = ppo.policy_forward(params, x, fast_gates=False)
    assert float(jnp.abs(lg_f - lg_e).max()) < 1e-2
    assert float(jnp.abs(v_f - v_e).max()) < 1e-2


def test_frame_stack_rollout_shapes():
    env = _make_bandit()
    cfg = ppo.PPOConfig(obs_dim=2, n_actions=2, frame_stack=4, n_envs=3,
                        rollout_len=8, episode_len=5)
    key = jax.random.PRNGKey(1)
    params = ppo.init_policy(cfg, key)
    rs = ppo.init_rollout_state(env, cfg, key)
    rs, batch, v_last = ppo.rollout(env, cfg, params, rs, key)
    assert batch["x"].shape == (8, 3, 2 * 4)
    assert v_last.shape == (3,)
    # periodic reset happened (episode_len=5 < rollout_len=8)
    assert float(batch["done"].sum()) > 0
