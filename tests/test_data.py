"""Data pipeline: determinism, host sharding, file source."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.data.pipeline import DataConfig, TokenPipeline, write_token_file

SET = dict(deadline=None, max_examples=10)


def _cfg(**kw):
    base = dict(seq_len=16, global_batch=8, vocab_size=100, seed=3)
    base.update(kw)
    return DataConfig(**base)


@given(step=st.integers(0, 1000))
@settings(**SET)
def test_batches_deterministic(step):
    p = TokenPipeline(_cfg())
    a = p.get_batch(step)
    b = p.get_batch(step)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])


def test_different_steps_differ():
    p = TokenPipeline(_cfg())
    assert not np.array_equal(p.get_batch(0)["tokens"],
                              p.get_batch(1)["tokens"])


def test_host_shards_differ_and_shape():
    p = TokenPipeline(_cfg())
    a = p.get_batch(5, host_id=0, n_hosts=2)
    b = p.get_batch(5, host_id=1, n_hosts=2)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_tokens_in_vocab():
    p = TokenPipeline(_cfg(vocab_size=37))
    b = p.get_batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 37


def test_file_source_labels_are_shifted(tmp_path):
    path = tmp_path / "toks.bin"
    write_token_file(path, np.arange(10_000) % 50)
    p = TokenPipeline(_cfg(source="file", path=str(path), vocab_size=50))
    b = p.get_batch(0)
    # contiguous stream: labels == tokens shifted by one
    assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_iterator_resumes_at_step(tmp_path):
    p = TokenPipeline(_cfg())
    it = p.iterator(start_step=7)
    first = next(it)
    assert np.array_equal(first["tokens"], p.get_batch(7)["tokens"])
    it.close()


def test_iterator_joins_producer_on_close():
    """Closing the iterator must release the producer thread even while
    it is blocked on a full prefetch queue (the pre-fix leak: a plain
    ``q.put`` never observes the stop flag)."""
    import threading
    before = threading.active_count()
    it = TokenPipeline(_cfg()).iterator(prefetch=1)
    next(it)                   # producer running, queue refilling
    it.close()                 # GeneratorExit -> finally: drain + join
    assert threading.active_count() == before
