"""AIP correctness: learns exact rules; Theorem-1 mechanics (memory need)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import influence


def _synthetic_memoryless(key, N=256, T=16, D=8, M=2):
    """u_t = deterministic function of d_t (no history needed)."""
    d = jax.random.bernoulli(key, 0.5, (N, T, D)).astype(jnp.float32)
    u = jnp.stack([d[..., 0], 1.0 - d[..., 1]], axis=-1)
    return d, u


def _synthetic_memoryful(key, N=256, T=16, D=4, lag=3):
    """u_t = d_{t-lag}[0] — requires >= lag steps of memory."""
    d = jax.random.bernoulli(key, 0.5, (N, T, D)).astype(jnp.float32)
    u = jnp.roll(d[..., :1], lag, axis=1)
    u = u.at[:, :lag].set(0.0)
    return d, u


def test_fnn_aip_learns_memoryless_rule():
    key = jax.random.PRNGKey(0)
    d, u = _synthetic_memoryless(key)
    cfg = influence.AIPConfig(kind="fnn", d_in=8, n_out=2, hidden=32,
                              stack=1)
    params, m = influence.train_aip(cfg, d, u, key, epochs=30, lr=1e-2)
    acc = float(influence.accuracy(params, cfg, d, u))
    assert acc > 0.97, acc


@pytest.mark.slow
def test_gru_aip_learns_memoryful_rule_fnn_cannot():
    key = jax.random.PRNGKey(1)
    d, u = _synthetic_memoryful(key)
    gru_cfg = influence.AIPConfig(kind="gru", d_in=4, n_out=1, hidden=32)
    fnn_cfg = influence.AIPConfig(kind="fnn", d_in=4, n_out=1, hidden=32,
                                  stack=1)
    gru, mg = influence.train_aip(gru_cfg, d, u, key, epochs=40, lr=5e-3)
    fnn, mf = influence.train_aip(fnn_cfg, d, u, key, epochs=40, lr=5e-3)
    acc_gru = float(influence.accuracy(gru, gru_cfg, d, u))
    acc_fnn = float(influence.accuracy(fnn, fnn_cfg, d, u))
    # GRU (memoryful AIP) learns the lag rule; memoryless FNN is near chance
    assert acc_gru > 0.9, acc_gru
    assert acc_fnn < 0.8, acc_fnn


@pytest.mark.slow
def test_fnn_stack_k_matches_theorem1_window():
    """A k-stacked FNN AIP suffices when the dependence is k steps
    (Theorem 1: AIP memory need == agent/window memory)."""
    key = jax.random.PRNGKey(2)
    d, u = _synthetic_memoryful(key, lag=3)
    cfg = influence.AIPConfig(kind="fnn", d_in=4, n_out=1, hidden=32,
                              stack=4)   # k=4 >= lag
    params, _ = influence.train_aip(cfg, d, u, key, epochs=40, lr=5e-3)
    acc = float(influence.accuracy(params, cfg, d, u))
    assert acc > 0.9, acc


def test_train_window_truncation():
    key = jax.random.PRNGKey(3)
    d, u = _synthetic_memoryless(key, N=64, T=32)
    cfg = influence.AIPConfig(kind="gru", d_in=8, n_out=2, hidden=16)
    params, m = influence.train_aip(cfg, d, u, key, epochs=5, window=8)
    assert jnp.isfinite(jnp.asarray(m["final_loss"]))


def test_xent_decreases_with_training():
    key = jax.random.PRNGKey(4)
    d, u = _synthetic_memoryless(key, N=128)
    cfg = influence.AIPConfig(kind="fnn", d_in=8, n_out=2, hidden=32,
                              stack=1)
    params0 = influence.init_aip(cfg, key)
    xe0 = float(influence.xent_loss(params0, cfg, d, u))
    params, _ = influence.train_aip(cfg, d, u, key, epochs=10, lr=1e-2)
    xe1 = float(influence.xent_loss(params, cfg, d, u))
    assert xe1 < xe0 * 0.5


def test_step_sequence_consistency():
    """apply_sequence == iterated step (the IALS uses step)."""
    key = jax.random.PRNGKey(5)
    cfg = influence.AIPConfig(kind="gru", d_in=6, n_out=3, hidden=16)
    params = influence.init_aip(cfg, key)
    d = jax.random.normal(key, (2, 9, 6))
    seq = influence.apply_sequence(params, cfg, d)
    st = influence.init_state(cfg, (2,))
    outs = []
    for t in range(9):
        lg, st = influence.step(params, cfg, st, d[:, t])
        outs.append(lg)
    stepped = jnp.stack(outs, 1)
    assert float(jnp.abs(seq - stepped).max()) < 1e-6
