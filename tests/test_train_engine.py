"""The fused actor-in-the-loop training engine (PR 5).

Pins down: bulk-Gumbel action sampling == keyed ``jax.random.categorical``
bitwise (property test) with a distributional fallback where bitwise
equality is not derivable; the PPO rollout's three dispatch paths
(hoisted deterministic scan / keyed per-tick scan / fully-keyed legacy)
produce bit-identical batches; the engine's ``policy_rollout`` route
(forced ops -> stacked oracle on CPU, and the real Pallas kernel in
interpret mode) reproduces the scan for both domains x backbones x
multiplicities; GAE's associative scan matches the sequential recursion;
the batched greedy evaluator matches the historical episodic path; and
``train_aip`` donation invalidates exactly what it documents."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.core import engine, influence
from repro.envs.api import Env, EnvSpec
from repro.envs.traffic import (TrafficConfig,
                                make_batched_local_traffic_env)
from repro.envs.warehouse import (WarehouseConfig,
                                  make_batched_local_warehouse_env)
from repro.rl import ppo


def _trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        jnp.array_equal(x, y) for x, y in zip(la, lb))


def _bls(domain):
    if domain == "traffic":
        return make_batched_local_traffic_env(TrafficConfig())
    return make_batched_local_warehouse_env(WarehouseConfig())


def _engine_pair(domain, kind, A):
    """-> (forced-ops engine, scan engine) sharing params."""
    bls = _bls(domain)
    acfg = influence.AIPConfig(kind=kind, d_in=bls.spec.dset_dim,
                               n_out=bls.spec.n_influence, hidden=8,
                               stack=2)
    if A == 1:
        params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    else:
        params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
            jax.random.split(jax.random.PRNGKey(0), A))
    env_k = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=True)
    env_s = engine.make_unified_ials(bls, params, acfg, n_agents=A,
                                     use_horizon_kernel=False)
    return bls, env_k, env_s


def _ppo_cfg(bls, A, **kw):
    kw.setdefault("frame_stack", 2)
    kw.setdefault("n_envs", 4)
    kw.setdefault("rollout_len", 7)
    kw.setdefault("episode_len", 5)      # < rollout_len: resets exercised
    kw.setdefault("hidden", 16)
    return ppo.PPOConfig(obs_dim=bls.spec.obs_dim,
                         n_actions=bls.spec.n_actions, n_agents=A, **kw)


def _assert_batches_match(batch_a, batch_b, rs_a, rs_b, v_a, v_b):
    """Bitwise on every leaf except the value stream ``v``: the fused
    routes compute both policy heads as one GEMM (see
    kernels/aip_step.py::_policy_cell), which can move ``v`` by 1 ulp
    across program shapes — the one documented allclose leaf."""
    for k in batch_a:
        if k == "v":
            assert jnp.allclose(batch_a[k], batch_b[k], atol=1e-6), k
        else:
            assert jnp.array_equal(batch_a[k], batch_b[k]), k
    assert _trees_equal(rs_a, rs_b)
    assert jnp.allclose(v_a, v_b, atol=1e-6)


# ---------------------------------------------------------------------------
# bulk-Gumbel action sampling == jax.random.categorical (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=24, deadline=None)
@given(seed=st.integers(0, 5), b=st.integers(1, 9),
       n_act=st.sampled_from([2, 5]), agents=st.integers(1, 3),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_bulk_gumbel_matches_categorical_bitwise(seed, b, n_act, agents,
                                                 dtype):
    """argmax(logits + gumbel(key)) is BITWISE jax.random.categorical's
    draw on the same key — jax derives categorical exactly that way and
    float addition commutes — across batch shapes, agent axes, action
    counts, and logit dtypes; and the bulk (vmapped-over-keys) draw
    equals the per-key draws."""
    dt = jnp.dtype(dtype)
    shape = (b, agents, n_act) if agents > 1 else (b, n_act)
    logits = jax.random.normal(jax.random.PRNGKey(seed + 100), shape,
                               dt) * 3
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    want = jnp.stack([jax.random.categorical(k, logits) for k in keys])
    gum = ppo.bulk_gumbel(keys, shape, dt)
    got = ppo.gumbel_argmax(logits[None], gum)
    assert jnp.array_equal(got, want)


def test_gumbel_from_foreign_stream_matches_distribution():
    """The fallback claim where bitwise equality is NOT derivable: Gumbel
    noise from a different derivation (inverse-CDF on counter-bit
    uniforms, the kernel-style stream) still samples softmax(logits) —
    empirical action frequencies match to sampling error."""
    from repro.nn.act import uniform_from_bits

    logits = jnp.array([1.0, 0.0, -1.0, 0.5])
    n = 40000
    bits = jax.random.bits(jax.random.PRNGKey(3), (n, 4), jnp.uint32)
    u = jnp.clip(uniform_from_bits(bits), 1e-7, 1.0 - 1e-7)
    g = -jnp.log(-jnp.log(u))
    a = ppo.gumbel_argmax(logits[None], g)
    freq = jnp.bincount(a, length=4) / n
    want = jax.nn.softmax(logits)
    assert float(jnp.abs(freq - want).max()) < 0.02


# ---------------------------------------------------------------------------
# the three PPO rollout paths are bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("domain,kind,A", [
    ("warehouse", "gru", 1), ("warehouse", "gru", 3),
    ("traffic", "fnn", 3),
])
def test_hoisted_rollout_matches_keyed_and_legacy(domain, kind, A):
    """hoisted deterministic scan (the default) == keyed per-tick path
    (hoist_rollout_noise=False, the PR-4 program, preserved exactly) ==
    fully-keyed legacy (no whole-horizon pair at all), bitwise on every
    leaf — episode resets included."""
    import dataclasses

    bls, _, env = _engine_pair(domain, kind, A)
    cfg = _ppo_cfg(bls, A)
    cfg_keyed = dataclasses.replace(cfg, hoist_rollout_noise=False)
    legacy = env._replace(step_det=None, noise_fn=None, rollout=None)
    key = jax.random.PRNGKey(11)
    pol = ppo.init_policy(cfg, key)
    rs0 = ppo.init_rollout_state(env, cfg, key)
    out_h = ppo.rollout(env, cfg, pol, rs0, key)
    out_k = ppo.rollout(env, cfg_keyed, pol, rs0, key)
    out_l = ppo.rollout(legacy, cfg, pol, rs0, key)
    for other in (out_k, out_l):
        assert _trees_equal(out_h[1], other[1])
        assert _trees_equal(out_h[0], other[0])
        assert jnp.array_equal(out_h[2], other[2])
    assert float(out_h[1]["done"].sum()) > 0      # resets really fired


# ---------------------------------------------------------------------------
# engine policy_rollout route (forced ops -> oracle) == scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("domain,kind,A", [
    (d, k, A) for d in ("traffic", "warehouse")
    for k in ("gru", "fnn") for A in (1, 3)])
def test_policy_rollout_route_matches_scan(domain, kind, A):
    """The engine's whole-acting-loop dispatch (use_horizon_kernel=True
    -> kernels.ops.policy_rollout -> the stacked oracle on CPU) produces
    the scan path's batch: bitwise everywhere except the documented
    1-ulp ``v`` leaf. Covers all backbone x multiplicity x domain
    combos, resets included."""
    bls, env_k, env_s = _engine_pair(domain, kind, A)
    assert env_k.policy_rollout is not None
    assert env_s.policy_rollout is None
    cfg = _ppo_cfg(bls, A)
    key = jax.random.PRNGKey(5)
    pol = ppo.init_policy(cfg, key)
    rs0 = ppo.init_rollout_state(env_s, cfg, key)
    rs_a, batch_a, v_a = ppo.rollout(env_k, cfg, pol, rs0, key)
    rs_b, batch_b, v_b = ppo.rollout(env_s, cfg, pol, rs0, key)
    _assert_batches_match(batch_a, batch_b, rs_a, rs_b, v_a, v_b)


@pytest.mark.parametrize("domain,kind", [
    ("warehouse", "gru"), ("warehouse", "fnn"),
    ("traffic", "gru"), ("traffic", "fnn"),
])
def test_interpret_policy_kernel_matches_oracle(domain, kind,
                                                monkeypatch):
    """The actual Pallas policy_rollout kernel (interpret mode: the real
    (A·B-blocks, T) grid, per-agent weight indexing, frame-stack VMEM
    scratch, streamed resets) reproduces the ops oracle route bitwise on
    EVERY leaf — stacked weights included (A=2). Eager-to-eager, like
    the other interpret parity tests."""
    from repro.kernels import ops

    orig = ops.policy_rollout

    def forced(*args, **kw):
        kw["interpret"] = True
        return orig(*args, **kw)

    A = 2
    bls, env_k, _ = _engine_pair(domain, kind, A)
    cfg = _ppo_cfg(bls, A, rollout_len=6, episode_len=4)
    key = jax.random.PRNGKey(9)
    pol = ppo.init_policy(cfg, key)
    rs0 = ppo.init_rollout_state(env_k, cfg, key)
    rs_o, batch_o, v_o = ppo.rollout(env_k, cfg, pol, rs0, key)
    monkeypatch.setattr(ops, "policy_rollout", forced)
    rs_k, batch_k, v_k = ppo.rollout(env_k, cfg, pol, rs0, key)
    assert _trees_equal(batch_o, batch_k)
    assert _trees_equal(rs_o, rs_k)
    assert jnp.array_equal(v_o, v_k)


def test_train_iteration_on_policy_rollout_route():
    """A full donated train_iteration runs end-to-end on the fused
    actor-in-the-loop route and stays numerically in step with the scan
    route (params allclose — ``v`` is the 1-ulp leaf, so bitwise is not
    claimed)."""
    bls, env_k, env_s = _engine_pair("warehouse", "gru", 1)
    cfg = _ppo_cfg(bls, 1, rollout_len=8, episode_len=6)
    key = jax.random.PRNGKey(2)
    outs = {}
    for name, env in (("ops", env_k), ("scan", env_s)):
        pol = ppo.init_policy(cfg, key)
        opt, it_fn = ppo.make_train_iteration(env, cfg)
        ost = opt.init(pol)
        rs = ppo.init_rollout_state(env, cfg, key)
        pol, ost, rs, m = it_fn(pol, ost, rs, key)
        outs[name] = (pol, m)
        assert bool(jnp.isfinite(m["loss"]))
    la = jax.tree_util.tree_leaves(outs["ops"][0])
    lb = jax.tree_util.tree_leaves(outs["scan"][0])
    assert all(jnp.allclose(a, b, atol=1e-5) for a, b in zip(la, lb))


def test_policy_rollout_gating():
    """The slot is set only when the fused route is active: never for
    F-IALS (no AIP to fuse), never off-TPU by default."""
    bls = _bls("traffic")
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=4, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    assert engine.make_unified_ials(bls, params, acfg).policy_rollout \
        is None                                  # CPU default: the scan
    assert engine.make_unified_ials(
        bls, params, acfg, use_horizon_kernel=True,
        fixed_marginal=0.3).policy_rollout is None   # F-IALS
    assert engine.make_unified_ials(
        bls, params, acfg,
        use_horizon_kernel=True).policy_rollout is not None


# ---------------------------------------------------------------------------
# obs_fn: the kernel-safe observe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_obs_fn_matches_observe(domain):
    bls = _bls(domain)
    state = bls.reset(jax.random.PRNGKey(4), 6)
    assert jnp.array_equal(bls.obs_fn(state), bls.observe(state))


# ---------------------------------------------------------------------------
# GAE: associative scan == sequential recursion
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 3), t=st.integers(2, 17))
def test_gae_associative_matches_sequential(seed, t):
    import numpy as np

    B = 3
    v = jax.random.normal(jax.random.PRNGKey(seed), (t, B))
    r = jax.random.normal(jax.random.PRNGKey(seed + 50), (t, B))
    done = (jax.random.uniform(jax.random.PRNGKey(seed + 99), (t, B))
            < 0.3).astype(jnp.float32)
    v_last = jax.random.normal(jax.random.PRNGKey(seed + 7), (B,))
    gamma, lam = 0.97, 0.9
    adv, ret = ppo.gae({"v": v, "r": r, "done": done}, v_last, gamma,
                       lam)
    vv, rr, dd = (np.asarray(x) for x in (v, r, done))
    acc, vn = np.zeros((B,)), np.asarray(v_last)
    want = np.zeros((t, B))
    for i in reversed(range(t)):
        nonterm = 1.0 - dd[i]
        delta = rr[i] + gamma * vn * nonterm - vv[i]
        acc = delta + gamma * lam * nonterm * acc
        want[i] = acc
        vn = vv[i]
    assert np.allclose(np.asarray(adv), want, atol=1e-5)
    assert np.allclose(np.asarray(ret), want + vv, atol=1e-5)


# ---------------------------------------------------------------------------
# evaluate on the batched whole-horizon path
# ---------------------------------------------------------------------------

def _evaluate_episodic_reference(env, cfg, params, key, *, n_episodes,
                                 ep_len):
    """The pre-PR-5 evaluate, verbatim: vmap over episodes of a scalar
    per-tick keyed scan — the equivalence reference."""
    from jax import lax
    ash = cfg.agent_shape

    def episode(key):
        k0, key = jax.random.split(key)
        state = env.reset(k0)
        frames = jnp.zeros(ash + (cfg.frame_stack, cfg.obs_dim))
        frames = frames.at[..., -1, :].set(env.observe(state))

        def step(carry, k):
            state, frames = carry
            x = (frames.reshape(ash + (-1,)) if ash
                 else frames.reshape(1, -1))
            logits, _ = ppo.policy_forward(params, x,
                                           fast_gates=cfg.fast_gates)
            a = (jnp.argmax(logits, -1) if ash else jnp.argmax(logits[0]))
            state, obs, r, _ = env.step(state, a, k)
            frames = jnp.concatenate(
                [frames[..., 1:, :], obs[..., None, :]], axis=-2)
            return (state, frames), r

        _, rs = lax.scan(step, (state, frames),
                         jax.random.split(key, ep_len))
        return rs.mean(axis=0)

    keys = jax.random.split(key, n_episodes)
    return jax.jit(jax.vmap(episode))(keys).mean(axis=0)


def _deterministic_env():
    """Key-independent dynamics AND key-independent reset, so the
    batched and episodic evaluators must agree exactly: reward depends
    only on the (deterministic) state/action sequence."""
    spec = EnvSpec(name="det", obs_dim=3, n_actions=3, n_influence=1,
                   dset_dim=1, dset_full_dim=1)

    def reset(key):
        return jnp.int32(1)

    def observe(s):
        return jnp.stack([s, s * 2, -s]).astype(jnp.float32)

    def step(s, a, key):
        s2 = (s + 1) % 7
        r = (a.astype(jnp.int32) + s).astype(jnp.float32)
        return s2, observe(s2), r, {}

    return Env(spec=spec, reset=reset, step=step, observe=observe)


def test_evaluate_matches_episodic_reference_on_deterministic_env():
    env = _deterministic_env()
    cfg = ppo.PPOConfig(obs_dim=3, n_actions=3, frame_stack=2, hidden=8)
    params = ppo.init_policy(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    got = ppo.evaluate(env, cfg, params, key, n_episodes=5, ep_len=9)
    want = _evaluate_episodic_reference(env, cfg, params, key,
                                        n_episodes=5, ep_len=9)
    assert abs(got - float(want.mean())) < 1e-6


def test_evaluate_on_engine_and_per_agent_shapes():
    """The batched evaluator consumes a native BatchedEnv (the fused
    IALS engine) directly — previously impossible — and the per-agent
    multi path keeps its (A,) contract."""
    bls, _, env = _engine_pair("warehouse", "gru", 3)
    cfg = _ppo_cfg(bls, 3)
    params = ppo.init_policy(cfg, jax.random.PRNGKey(0))
    per = ppo.evaluate(env, cfg, params, jax.random.PRNGKey(1),
                       n_episodes=4, ep_len=6, per_agent=True)
    assert per.shape == (3,)
    assert bool(jnp.all(jnp.isfinite(per)))
    mean = ppo.evaluate(env, cfg, params, jax.random.PRNGKey(1),
                        n_episodes=4, ep_len=6)
    assert abs(mean - float(per.mean())) < 1e-6


def test_evaluator_cache_reuses_jitted_fn():
    """Periodic evaluation must not re-trace: the cached evaluator is
    the same object across calls for the same (env, cfg, sizes)."""
    env = _deterministic_env()
    cfg = ppo.PPOConfig(obs_dim=3, n_actions=3, hidden=8)
    f1 = ppo.make_evaluator(env, cfg, n_episodes=3, ep_len=4)
    f2 = ppo.make_evaluator(env, cfg, n_episodes=3, ep_len=4)
    assert f1 is f2
    f3 = ppo.make_evaluator(env, cfg, n_episodes=4, ep_len=4)
    assert f3 is not f1


# ---------------------------------------------------------------------------
# train_aip donation
# ---------------------------------------------------------------------------

def test_train_aip_donation_contract():
    """donate=True invalidates exactly the (dsets, us) buffers and fits
    identical params; donate=False leaves the caller's arrays alive."""
    acfg = influence.AIPConfig(kind="gru", d_in=4, n_out=2, hidden=8)
    key = jax.random.PRNGKey(0)

    def data():
        d = jax.random.normal(jax.random.PRNGKey(1), (6, 10, 4))
        u = jax.random.bernoulli(jax.random.PRNGKey(2), 0.4,
                                 (6, 10, 2)).astype(jnp.float32)
        return d, u

    d0, u0 = data()
    p_keep, _ = influence.train_aip(acfg, d0, u0, key, epochs=2)
    _ = d0 + 0, u0 + 0                       # still alive

    d1, u1 = data()
    p_don, _ = influence.train_aip(acfg, d1, u1, key, epochs=2,
                                   donate=True)
    assert d1.is_deleted() and u1.is_deleted()
    assert _trees_equal(p_keep, p_don)
