"""HLO analyzer: loop-trip-count calibration + collective accounting."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.hlo_analysis import analyze, parse_hlo, roofline


def test_cost_analysis_counts_loop_body_once_but_we_correct_it():
    """The calibration that motivates the whole analyzer: XLA's
    cost_analysis reports one loop iteration; our analyzer multiplies by
    the while trip count extracted from the loop condition."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):        # older jax wraps it in a 1-elem list
        ca = ca[0]
    one_iter = 2 * 128 * 256 * 256
    assert abs(ca["flops"] - one_iter) / one_iter < 0.01   # body-once
    ours = analyze(comp.as_text())["flops"]
    assert abs(ours - 10 * one_iter) / (10 * one_iter) < 0.01  # corrected


def test_nested_loops_multiply():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = lax.scan(inner, h, None, length=5)
            return h2, None
        h, _ = lax.scan(outer, x, None, length=3)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    ours = analyze(comp.as_text())["flops"]
    want = 15 * 2 * 64 * 64 * 64
    assert abs(ours - want) / want < 0.05


_FAKE_HLO = """
HloModule test

ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ag = f32[256,64]{1,0} all-gather(%p0), channel_id=1, dimensions={0}
  %slice.1 = f32[128,64]{1,0} slice(%ag), slice={[0:128], [0:64]}
  %ar = f32[128,64]{1,0} all-reduce(%slice.1), channel_id=2, to_apply=%add
  ROOT %out = f32[128,64]{1,0} copy(%ar)
}
"""


def test_collective_bytes_from_operands():
    res = analyze(_FAKE_HLO)
    p0_bytes = 128 * 64 * 4
    # all-gather counts its operand once; all-reduce counts 2x (ring)
    assert res["collective_bytes"]["all-gather"] == p0_bytes
    assert res["collective_bytes"]["all-reduce"] == 2 * p0_bytes
    assert res["collective_counts"]["all-gather"] == 1


def test_roofline_terms_and_bottleneck():
    analysis = {"flops": 197e12, "hbm_bytes": 819e9 * 2,
                "collective_bytes_total": 50e9 * 0.5,
                "collective_bytes": {}, "collective_counts": {}}
    r = roofline(analysis, n_chips=4, model_flops=4 * 197e12)
    assert abs(r["t_compute_s"] - 1.0) < 1e-6
    assert abs(r["t_memory_s"] - 2.0) < 1e-6
    assert abs(r["t_collective_s"] - 0.5) < 1e-6
    assert r["bottleneck"] == "memory"
    assert abs(r["mfu_upper_bound"] - 0.5) < 1e-6


def test_flops_breakdown_partitions_total():
    """flops_dot + flops_elementwise == flops, with the matmuls dominant
    and custom_call_count zero on a pure-XLA program."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    res = analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert res["flops"] == res["flops_dot"] + res["flops_elementwise"]
    dot_iter = 2 * 128 * 256 * 256
    assert abs(res["flops_dot"] - 10 * dot_iter) / (10 * dot_iter) < 0.01
    assert 0 < res["flops_elementwise"] < res["flops_dot"]
    assert res["custom_call_count"] == 0


def test_parse_hlo_computations():
    comps = parse_hlo(_FAKE_HLO)
    assert "main" in comps
    assert comps["main"].is_entry
    kinds = [op.kind for op in comps["main"].ops]
    assert "all-gather" in kinds and "all-reduce" in kinds
