"""Overload hardening (PR 10): admission control, brownout, hot reload,
serving chaos.

Pins the overload contract (docs/ARCHITECTURE.md §8):

* **Counted sheds, never silent.** The admission gates (bounded queue,
  brownout, deadline feasibility) reject at the door and every
  rejection lands in ``ServeStats`` with a reason and a deadline class;
  the drop-free scheduler below never sheds.
* **Brownout degrades, never collapses.** Hysteresis (enter/exit
  thresholds + hold) prevents flapping; level k sheds the k loosest
  learned deadline classes and the tightest class is never shed by
  brownout; at max level a bucketed scheduler collapses to its coarsest
  shape and recovery undoes it.
* **Graceful degradation beats collapse.** At 2x capacity on the
  deterministic virtual clock, the admitted-and-served in-SLO volume
  with admission control strictly beats the no-admission server, whose
  unbounded queue misses nearly everything.
* **Hot reload is gated and atomic.** A valid candidate swaps in with
  zero recompiles and the live server becomes bitwise the candidate's
  own fresh server; ABI mismatches, NaN/huge-poisoned payloads
  (``CorruptCheckpoint``), and torn on-disk checkpoints are rejected
  with the server still serving bitwise-identical outputs on the old
  weights — the acceptance test of the PR.
* **Chaos plans are deterministic and must exhaust.** ``SlowDispatch``
  / ``RequestFlood`` / ``CorruptCheckpoint`` fire at their planned
  dispatch/reload coordinates, replays are bit-identical, and
  ``assert_exhausted`` fails loudly when a planned event never fired.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.distributed.fault_injection import (CorruptCheckpoint, FaultPlan,
                                               FaultInjector, RequestFlood,
                                               SlowDispatch, corrupt_tree,
                                               parse_serve_faults, torn_save)
from repro.launch import policy_serve
from repro.rl import ppo
from repro.serving import (AdmissionController, BrownoutController,
                           BucketedSlotScheduler, DispatchLatencyModel,
                           OverloadConfig, PolicyServer, Request, ServeStats,
                           SlotScheduler, TraceConfig, flood_trace,
                           synthetic_trace)

S = 8                       # test slot shape
OBS, ACT = 6, 4
SVC = 0.002                 # virtual service time -> capacity = S/SVC rps
_cache = {}


def _pcfg(hidden=16):
    return ppo.PPOConfig(obs_dim=OBS, n_actions=ACT, frame_stack=1,
                         hidden=hidden)


def _params(seed=0, hidden=16):
    key = ("params", seed, hidden)
    if key not in _cache:
        _cache[key] = ppo.init_policy(_pcfg(hidden),
                                      jax.random.PRNGKey(seed))
    return _cache[key]


def _server(slot=S, seed=0):
    pcfg = _pcfg()
    return PolicyServer(_params(seed), obs_dim=pcfg.obs_dim,
                        n_actions=pcfg.n_actions, slot=slot)


def _trace(rps, horizon_s=0.3, seed=3, classes=(0.01, 0.05, 0.25)):
    return synthetic_trace(TraceConfig(
        n_regions=16, region_sizes=(1, 2, 4), mean_rps=rps,
        horizon_s=horizon_s, classes_s=classes, frame_dim=OBS, seed=seed))


def _probe(srv):
    """Bitwise fingerprint of the serving weights on the pinned probe."""
    return [np.asarray(x) for x in
            srv.forward_slot(srv._probe_frames, srv.slots[0],
                             srv._probe_pidx(srv.slots[0]))]


# --------------------------------------------------- admission gates

def test_overload_config_validation():
    with pytest.raises(ValueError):
        OverloadConfig(queue_cap=0)
    with pytest.raises(ValueError):
        OverloadConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError):
        OverloadConfig(brownout_enter_s=0.01, brownout_exit_s=0.02)
    with pytest.raises(ValueError):
        OverloadConfig(brownout_hold=0)
    with pytest.raises(ValueError):
        OverloadConfig(max_level=0)


def test_latency_model_ewma_and_fallbacks():
    """Exact EWMA per shape; unseen shapes borrow the nearest observed
    shape's estimate, and a cold model estimates the default."""
    m = DispatchLatencyModel(alpha=0.5, default_s=0.123)
    assert m.estimate(64) == 0.123
    m.observe(8, 0.010)
    assert m.estimate(8) == 0.010
    m.observe(8, 0.020)
    assert m.estimate(8) == pytest.approx(0.5 * 0.010 + 0.5 * 0.020)
    assert m.estimate(7) == m.estimate(8)      # nearest observed shape
    m.observe(64, 0.100)
    assert m.estimate(60) == 0.100
    assert m.estimate(9) == m.estimate(8)


def test_queue_cap_bounds_pending_and_counts_rejections():
    """With only the bounded-queue gate on, pending never exceeds the
    cap and every overflow is a counted queue_full shed of its class."""
    cfg = OverloadConfig(queue_cap=4, feasibility=False, brownout=False)
    adm = AdmissionController(cfg)
    sched = SlotScheduler(S)
    stats = ServeStats()
    frame = np.zeros(OBS, np.float32)
    reqs = [Request(rid=i, region=0, klass=i % 2, arrival=0.0,
                    deadline=1.0, frame=frame) for i in range(10)]
    admitted = [adm.admit(r, 0.0, sched, stats) for r in reqs]
    assert admitted == [True] * 4 + [False] * 6
    assert sched.pending == 4
    assert stats.rejected == 6
    assert stats.rejected_by_reason == {"queue_full": 6}
    assert stats.shed_by_class == {0: 3, 1: 3}
    assert stats.summary()["rejected"] == 6


def test_feasibility_rejects_guaranteed_misses_at_the_door():
    """A request whose earliest possible completion (queue drained in
    full slots at the EWMA estimate) is past its deadline is shed as
    infeasible; the same request with slack is admitted."""
    cfg = OverloadConfig(default_latency_s=0.01, brownout=False)
    adm = AdmissionController(cfg)
    sched = SlotScheduler(S)
    stats = ServeStats()
    frame = np.zeros(OBS, np.float32)
    # empty queue: eta = now + 1 * 0.01 = 0.01
    tight = Request(rid=0, region=0, klass=0, arrival=0.0, deadline=0.005,
                    frame=frame)
    loose = Request(rid=1, region=0, klass=1, arrival=0.0, deadline=0.05,
                    frame=frame)
    assert not adm.admit(tight, 0.0, sched, stats)
    assert stats.rejected_by_reason == {"infeasible": 1}
    assert adm.admit(loose, 0.0, sched, stats)
    # pile up a backlog: 3 full slots pending -> eta = (24//8 + 1)*0.01
    for i in range(23):
        sched.admit(dataclasses.replace(loose, rid=10 + i))
    late = dataclasses.replace(loose, rid=99, deadline=0.03)
    assert not adm.admit(late, 0.0, sched, stats)
    ok = dataclasses.replace(loose, rid=100, deadline=0.05)
    assert adm.admit(ok, 0.0, sched, stats)
    assert stats.rejected == 2 and stats.shed_by_class == {0: 1, 1: 1}


def test_brownout_hysteresis_state_machine():
    """Enter after ``hold`` consecutive over-threshold observations,
    exit after ``hold`` under the (lower) exit threshold; the band
    between them holds the level and resets both streaks."""
    cfg = OverloadConfig(brownout_enter_s=1.0, brownout_exit_s=0.5,
                         brownout_hold=2, max_level=2)
    b = BrownoutController(cfg)
    assert b.observe(2.0) == 0          # streak 1 of 2
    assert b.observe(0.7) == 0          # band: streak reset
    assert b.observe(2.0) == 0
    assert b.observe(2.0) == 1          # entered
    assert b.entries == 1
    assert b.observe(2.0) == 1 and b.observe(2.0) == 2   # level 2
    assert b.observe(5.0) == 2          # capped at max_level
    assert b.observe(0.4) == 2
    assert b.observe(0.7) == 2          # band resets the under-streak
    assert b.observe(0.4) == 2 and b.observe(0.4) == 1   # exited
    assert b.exits == 1
    assert b.observe(0.0) == 1 and b.observe(0.0) == 0
    assert (b.entries, b.exits) == (2, 2)


def test_brownout_sheds_loosest_classes_never_tightest():
    """Driven through a 2x-overload virtual replay with feasibility off
    and the queue unbounded: every shed is a brownout shed of a
    *looser* learned class — the tightest class is never shed — and the
    controller actually cycled."""
    srv = _server()
    adm = AdmissionController(OverloadConfig(
        queue_cap=10**6, feasibility=False, default_latency_s=SVC,
        brownout_enter_s=10 * SVC, brownout_exit_s=4 * SVC,
        brownout_hold=2, max_level=2, coarse_in_brownout=False))
    trace = _trace(rps=2 * S / SVC)
    rep = srv.serve(trace, mode="virtual", service_time_s=SVC,
                    admission=adm)
    st = rep.stats
    assert st.rejected > 0
    assert set(st.rejected_by_reason) == {"brownout"}
    assert 0 not in st.shed_by_class            # tightest class protected
    assert set(st.shed_by_class) <= {1, 2}
    assert adm.brownout.entries >= 1
    assert rep.served + st.rejected == len(trace)


def test_brownout_max_level_collapses_buckets_and_recovers():
    """At max level the admission controller flips a bucketed scheduler
    coarse (every dispatch at the largest shape); when the backlog
    drains the level falls and the bucket set comes back."""
    adm = AdmissionController(OverloadConfig(
        queue_cap=10**6, feasibility=False, default_latency_s=SVC,
        brownout_enter_s=2 * SVC, brownout_exit_s=1 * SVC,
        brownout_hold=1, max_level=1))
    sched = BucketedSlotScheduler((2, S))
    stats = ServeStats()
    frame = np.zeros(OBS, np.float32)
    reqs = [Request(rid=i, region=0, klass=i % 2, arrival=0.0,
                    deadline=1.0, frame=frame) for i in range(4 * S)]
    for r in reqs:
        adm.admit(r, 0.0, sched, stats)
    assert adm.brownout.level == 1 and sched.coarse
    shape, batch = sched.next_dispatch()
    assert shape == S                           # coarse: largest shape
    sched.complete(batch, SVC)
    while sched.pending:                        # drain -> recovery
        _, b = sched.next_dispatch()
        sched.complete(b, SVC)
        adm.observe_dispatch(S, SVC, sched)
    assert adm.brownout.level == 0 and not sched.coarse


def test_graceful_degradation_beats_collapse_at_2x():
    """The PR's A/B: one 2x-capacity trace on the deterministic virtual
    clock. Without admission the unbounded queue collapses every class
    (nearly everything misses); with admission the shed is explicit and
    the in-SLO served volume is strictly, substantially higher. The
    admission replay is also bit-deterministic."""
    trace = _trace(rps=2 * S / SVC)

    rep_naive = _server().serve(trace, mode="virtual", service_time_s=SVC)
    assert rep_naive.served == len(trace)       # drop-free: serves all...
    in_slo_naive = rep_naive.served - rep_naive.deadline_misses
    assert rep_naive.deadline_misses > len(trace) // 2   # ...mostly late

    def run():
        adm = AdmissionController(OverloadConfig(default_latency_s=SVC))
        return _server().serve(trace, mode="virtual", service_time_s=SVC,
                               admission=adm)
    rep = run()
    in_slo = rep.served - rep.deadline_misses
    assert rep.stats.rejected > 0
    assert rep.served + rep.stats.rejected == len(trace)
    assert in_slo > 2 * max(in_slo_naive, 1)
    assert rep.deadline_misses < rep_naive.deadline_misses
    assert run().summary() == rep.summary()     # deterministic replay


# ------------------------------------------------------- hot reload

def test_reload_swaps_atomically_and_matches_fresh_server():
    """A valid candidate passes the gate: the live server's probe
    outputs become bitwise the candidate's own fresh server's, the
    version bumps, and no new program compiles (same shapes)."""
    srv = _server(seed=0)
    before = _probe(srv)
    new = _params(seed=7)
    assert srv.reload(new)
    assert (srv.policy_version, srv.reloads, srv.reload_rejected) == \
        (1, 1, 0)
    after = _probe(srv)
    fresh = _probe(_server(seed=7))
    for a, f in zip(after, fresh):
        assert np.array_equal(a, f)
    assert not all(np.array_equal(a, b) for a, b in zip(before, after))
    assert srv.reload_log[-1] == ("ok", "v1")


def test_reload_rejects_abi_mismatch_and_rolls_back():
    """Wrong-shape weights (different hidden width) and malformed
    candidates are rejected at the ABI gate; the serving weights stay
    bitwise-identical."""
    srv = _server()
    before = _probe(srv)
    assert not srv.reload(_params(seed=1, hidden=32))
    assert not srv.reload([_params(seed=1)])    # single/multi mismatch
    assert not srv.reload({"nonsense": np.zeros(3)})
    assert srv.reload_rejected == 3 and srv.policy_version == 0
    for a, b in zip(before, _probe(srv)):
        assert np.array_equal(a, b)
    assert all(tag == "rejected" for tag, _ in srv.reload_log)


@pytest.mark.parametrize("mode", ["nan", "huge"])
def test_reload_rejects_poisoned_payload_via_canary(mode):
    """NaN- and huge-poisoned payloads (bit rot, torn writes) die at the
    canary's finite check; the server keeps serving on the old
    weights."""
    srv = _server()
    before = _probe(srv)
    assert not srv.reload(corrupt_tree(_params(seed=7), mode=mode))
    assert srv.reload_rejected == 1
    for a, b in zip(before, _probe(srv)):
        assert np.array_equal(a, b)
    assert "canary" in srv.reload_log[-1][1]


def test_corrupt_checkpoint_reload_rejected_in_flight():
    """The PR's acceptance test: a ``CorruptCheckpoint`` fault poisons
    the hot-reload attempt *during* a serve; the reload gate rejects it,
    the replay completes, the stats count it, the plan exhausts, and the
    server still serves bitwise-identical outputs on the old weights.
    A clean reload of the same candidate afterwards is accepted."""
    srv = _server()
    before = _probe(srv)
    trace = _trace(rps=0.5 * S / SVC, horizon_s=0.1)
    inj = FaultInjector(FaultPlan.of(CorruptCheckpoint(at_reload=0,
                                                       mode="nan")))
    rep = srv.serve(trace, mode="virtual", service_time_s=SVC,
                    faults=inj, reload_at=(2,), reload_params=_params(7))
    inj.assert_exhausted()
    assert rep.stats.reload_rejected == 1 and rep.stats.reloads == 0
    assert srv.policy_version == 0
    assert rep.served == len(trace)             # kept serving throughout
    for a, b in zip(before, _probe(srv)):
        assert np.array_equal(a, b)
    # same candidate, no fault in the path: accepted
    rep2 = srv.serve(trace, mode="virtual", service_time_s=SVC,
                     reload_at=(2,), reload_params=_params(7))
    assert rep2.stats.reloads == 1 and srv.policy_version == 1


def test_reload_from_checkpoint_good_and_torn(tmp_path):
    """``reload_from_checkpoint`` accepts a committed checkpoint's
    policy subtree and rejects every torn layout ``torn_save`` builds —
    a torn checkpoint can never swap in."""
    srv = _server()
    good = tmp_path / "good"
    ckpt.save(good, 3, {"policy": _params(seed=7)})
    assert srv.reload_from_checkpoint(good)
    for a, b in zip(_probe(srv), _probe(_server(seed=7))):
        assert np.array_equal(a, b)
    before = _probe(srv)
    for tear in ("tmp-only", "no-commit", "truncated", "torn-meta"):
        torn = tmp_path / f"torn_{tear}"
        torn_save(torn, 1, {"policy": _params(seed=2)}, tear=tear)
        assert not srv.reload_from_checkpoint(torn), tear
        assert "restore" in srv.reload_log[-1][1]
    assert srv.reload_rejected == 4
    for a, b in zip(before, _probe(srv)):
        assert np.array_equal(a, b)
    with pytest.raises(ValueError):
        _multi = PolicyServer([_params(0), _params(1)], obs_dim=OBS,
                              n_actions=ACT, slot=S)
        _multi.reload_from_checkpoint(good)


# ------------------------------------------- chaos events + lifecycle

def test_flood_trace_duplicates_window_and_keeps_order():
    frame = np.zeros(OBS, np.float32)
    trace = [Request(rid=i, region=0, klass=0, arrival=0.1 * i,
                     deadline=0.1 * i + 1.0, frame=frame)
             for i in range(4)]
    out = flood_trace(trace, at_s=0.1, duration_s=0.2, multiplier=3)
    assert len(out) == 2 + 2 * 3                # middle two tripled
    assert [r.rid for r in out] == list(range(len(out)))   # dense rids
    assert [r.arrival for r in out] == sorted(r.arrival for r in out)
    assert sum(r.arrival == 0.1 for r in out) == 3
    assert flood_trace(trace, 0.0, 1.0, 1) == [
        dataclasses.replace(r, rid=i) for i, r in enumerate(trace)]
    with pytest.raises(ValueError):
        flood_trace(trace, 0.0, 1.0, 0)


def test_parse_serve_faults_and_injector_seams():
    """The plan syntax round-trips; each serving seam fires its event
    exactly once; ``assert_exhausted`` raises while events are pending
    and passes once the plan ran."""
    plan = parse_serve_faults(
        "slow:5:0.05, flood:0.5:0.2:4, corrupt:1:huge, corrupt:0")
    assert plan.events == (SlowDispatch(5, 0.05),
                           RequestFlood(0.5, 0.2, 4),
                           CorruptCheckpoint(1, "huge"),
                           CorruptCheckpoint(0, "nan"))
    for bad in ("slow:1", "flood:0.5:0.2", "corrupt:x", "nonsense:1"):
        with pytest.raises(ValueError):
            parse_serve_faults(bad)

    inj = FaultInjector(plan)
    with pytest.raises(AssertionError):
        inj.assert_exhausted()
    assert inj.dispatch_delay_s(4) == 0.0
    assert inj.dispatch_delay_s(5) == 0.05
    assert inj.dispatch_delay_s(5) == 0.0       # at most once
    assert inj.take_floods() == [RequestFlood(0.5, 0.2, 4)]
    assert inj.take_floods() == []
    p = _params(0)
    assert inj.corrupt_params(7, p) is p        # untargeted: untouched
    nan_leaf = jax.tree_util.tree_leaves(inj.corrupt_params(0, p))[0]
    assert np.isnan(np.asarray(nan_leaf)).all()
    huge = inj.corrupt_params(1, p)
    assert np.asarray(jax.tree_util.tree_leaves(huge)[0]).max() >= 1e29
    inj.assert_exhausted()
    assert inj.applied_counts() == {"SlowDispatch": 1, "RequestFlood": 1,
                                    "CorruptCheckpoint": 2}
    with pytest.raises(ValueError):
        corrupt_tree(p, mode="bogus")


def test_slow_dispatch_and_flood_shift_the_virtual_clock():
    """A ``SlowDispatch`` adds exactly ``extra_s`` to the fault run's
    completion clock; a ``RequestFlood`` grows the request count by
    exactly the duplicated window; both replays stay deterministic."""
    trace = _trace(rps=0.5 * S / SVC, horizon_s=0.1)
    base = _server().serve(trace, mode="virtual", service_time_s=SVC)

    inj = FaultInjector(FaultPlan.of(SlowDispatch(0, 0.5)))
    slow = _server().serve(trace, mode="virtual", service_time_s=SVC,
                           faults=inj)
    inj.assert_exhausted()
    assert slow.served == base.served
    assert max(slow.latencies_s) >= 0.5         # someone ate the stall

    t0, t1 = trace[0].arrival, trace[0].arrival + 0.05
    n_window = sum(t0 <= r.arrival < t1 for r in trace)
    inj2 = FaultInjector(FaultPlan.of(RequestFlood(t0, t1 - t0, 3)))
    flood = _server().serve(trace, mode="virtual", service_time_s=SVC,
                            faults=inj2)
    inj2.assert_exhausted()
    assert flood.requests == len(trace) + 2 * n_window
    assert flood.served == flood.requests


def test_lifecycle_and_standalone_drain():
    """warming -> serving -> draining -> drained across a replay; the
    standalone ``drain`` completes a scheduler's backlog with no new
    admissions and snapshots the final state."""
    srv = _server()
    assert srv.state == "warming"
    rep = srv.serve(_trace(rps=200, horizon_s=0.05), mode="virtual",
                    service_time_s=SVC)
    assert srv.state == "drained"
    assert rep.stats.final_state == "drained"

    srv2 = _server()
    sched = SlotScheduler(S)
    frame = np.zeros(OBS, np.float32)
    for i in range(3 * S):
        sched.admit(Request(rid=i, region=0, klass=0, arrival=0.0,
                            deadline=1.0, frame=frame))
    srv2.warmup()
    stats, done = srv2.drain(sched, service_time_s=SVC)
    assert srv2.state == "drained" and stats.final_state == "drained"
    assert sched.pending == 0 and sched.served == 3 * S
    assert stats.dispatches == 3 and done == pytest.approx(3 * SVC)


# ------------------------------------------------ zero-dispatch audit

def test_serve_stats_zero_dispatch_edges():
    """Every ratio in ``ServeStats`` is total-guarded: a fresh instance,
    a rejection-only instance, an empty-trace replay, and a fully-shed
    replay all produce clean zero summaries — no division errors."""
    st = ServeStats()
    s = st.summary()
    assert s["padded_lane_frac"] == 0.0 and st.dispatches == 0
    assert s["rejected"] == 0 and s["shed_by_class"] == {}
    assert (s["reloads"], s["reload_rejected"]) == (0, 0)
    st.record_rejection("infeasible", 2)
    assert st.padded_lane_frac == 0.0 and st.rejected == 1

    srv = _server()
    rep = srv.serve([], mode="virtual", service_time_s=SVC)
    assert (rep.requests, rep.served, rep.dispatches) == (0, 0, 0)
    assert rep.qps == 0.0 and rep.mean_occupancy == 0.0
    assert rep.summary()["mean_occupancy_by_slot"] == {}

    # zero-slack trace + cold nonzero latency estimate: everything shed
    trace = _trace(rps=1000, horizon_s=0.05, classes=(0.0, 0.0, 0.0))
    adm = AdmissionController(OverloadConfig(default_latency_s=SVC,
                                             brownout=False))
    rep2 = _server().serve(trace, mode="virtual", service_time_s=SVC,
                           admission=adm)
    assert rep2.served == 0 and rep2.stats.rejected == len(trace) > 0
    assert rep2.stats.rejected_by_reason == {"infeasible": len(trace)}
    assert rep2.qps == 0.0 and rep2.stats.final_state == "drained"


# -------------------------------------------------------------- driver

def test_policy_serve_driver_chaos_flags(tmp_path):
    """The driver wires --admission/--faults/--reload-at/--virtual end
    to end: the corrupt reload is rejected, sheds are counted, the plan
    exhausts (applied counts land in the JSON), and the run drains."""
    res = policy_serve.main([
        "--domain", "traffic", "--slot", "16", "--regions", "8",
        "--rps", "4000", "--duration-s", "0.1", "--virtual",
        "--service-time-s", "0.002", "--admission",
        "--faults", "slow:2:0.05,flood:0.02:0.05:3,corrupt:0:nan",
        "--reload-at", "1",
        "--out", str(tmp_path / "chaos.json")])
    assert res["final_state"] == "drained"
    assert res["reload_rejected"] == 1 and res["policy_version"] == 0
    assert res["faults_applied"] == {"SlowDispatch": 1, "RequestFlood": 1,
                                     "CorruptCheckpoint": 1}
    assert res["served"] + res["rejected"] == res["requests"]
    assert res["reload_log"][-1][0] == "rejected"

    with pytest.raises(ValueError):
        policy_serve.main(["--faults", "bogus:1", "--virtual",
                           "--duration-s", "0.01", "--regions", "2"])
