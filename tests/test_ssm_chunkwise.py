"""Chunkwise-parallel mLSTM (§Perf hillclimb #1) == recurrent reference."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.nn.ssm import (mlstm_apply, mlstm_init, mlstm_init_state,
                          mlstm_step, mamba_apply, mamba_init,
                          mamba_init_state, mamba_step)

SET = dict(deadline=None, max_examples=8)


@pytest.mark.parametrize("T,chunk", [(48, 8), (32, 16), (17, 5), (64, 64)])
def test_chunkwise_matches_recurrent(T, chunk):
    key = jax.random.PRNGKey(0)
    p = mlstm_init(key, 32, 4)
    x = jax.random.normal(key, (2, T, 32)) * 0.5
    y_rec = mlstm_apply(p, x, 4, chunk=chunk, chunkwise=False)
    y_chk = mlstm_apply(p, x, 4, chunk=chunk, chunkwise=True)
    assert float(jnp.abs(y_rec - y_chk).max()) < 1e-5


def test_chunkwise_state_handoff_matches():
    """Prefill(chunkwise) -> decode_step continues the exact recurrence."""
    key = jax.random.PRNGKey(1)
    p = mlstm_init(key, 32, 4)
    x = jax.random.normal(key, (2, 24, 32)) * 0.5
    y, st = mlstm_apply(p, x, 4, chunk=8, chunkwise=True, return_state=True)
    y2, st2 = mlstm_apply(p, x, 4, chunk=8, chunkwise=False,
                          return_state=True)
    assert float(jnp.abs(st.C - st2.C).max()) < 1e-6
    assert float(jnp.abs(st.n - st2.n).max()) < 1e-6
    assert float(jnp.abs(st.m - st2.m).max()) < 1e-6


@pytest.mark.slow
@given(scale=st.floats(0.1, 6.0), seed=st.integers(0, 100))
@settings(**SET)
def test_chunkwise_stable_under_extreme_gates(scale, seed):
    """The max-stabiliser keeps exp-gates finite for large inputs."""
    key = jax.random.PRNGKey(seed)
    p = mlstm_init(key, 16, 2)
    x = jax.random.normal(key, (1, 32, 16)) * scale
    y = mlstm_apply(p, x, 2, chunk=8, chunkwise=True)
    assert bool(jnp.isfinite(y).all())
    g = jax.grad(lambda p: mlstm_apply(p, x, 2, chunk=8).sum())(p)
    assert all(bool(jnp.isfinite(l).all())
               for l in jax.tree_util.tree_leaves(g))


@pytest.mark.slow
@given(seed=st.integers(0, 200))
@settings(**SET)
def test_mamba_full_matches_step(seed):
    key = jax.random.PRNGKey(seed)
    p = mamba_init(key, 16)
    x = jax.random.normal(key, (1, 12, 16)) * 0.5
    y_full = mamba_apply(p, x, chunk=4)
    st = mamba_init_state(1, 32, 4, 16)
    ys = []
    for t in range(12):
        y, st = mamba_step(p, st, x[:, t])
        ys.append(y)
    assert float(jnp.abs(y_full - jnp.stack(ys, 1)).max()) < 1e-5
