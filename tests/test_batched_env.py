"""BatchedEnv protocol: adapter round-trips vs the scalar Env, native
batched LS == vmapped scalar LS, the fused batched IALS engine's
invariants, and GS<->LS exact replay driven through the batched engine."""
import jax
import jax.numpy as jnp

from repro.core import collect, ials, influence, multi_ials
from repro.envs.api import BatchedEnv, as_batched, batch_env, \
    batch_local_env, unbatch_env
from repro.envs.traffic import (TrafficConfig, local_traffic_state,
                                make_batched_local_traffic_env,
                                make_local_traffic_env,
                                make_multi_traffic_env, make_traffic_env)
from repro.envs.warehouse import (WarehouseConfig,
                                  local_warehouse_state,
                                  make_batched_local_warehouse_env,
                                  make_local_warehouse_env,
                                  make_multi_warehouse_env)

AGENTS4 = jnp.array([[0, 0], [1, 3], [2, 2], [4, 1]])


# ---------------------------------------------------------------------------
# adapters: scalar Env <-> BatchedEnv round-trips
# ---------------------------------------------------------------------------

def test_batch_env_adapter_matches_vmap_of_scalar():
    """batch_env(e).step == the historical split-keys-then-vmap rollout."""
    env = make_traffic_env()
    benv = batch_env(env)
    key = jax.random.PRNGKey(0)
    B = 6
    state = benv.reset(key, B)
    want_state = jax.vmap(env.reset)(jax.random.split(key, B))
    for l1, l2 in zip(jax.tree_util.tree_leaves(state),
                      jax.tree_util.tree_leaves(want_state)):
        assert jnp.array_equal(l1, l2)
    a = jnp.zeros((B,), jnp.int32)
    k2 = jax.random.PRNGKey(1)
    s2, obs, r, info = benv.step(state, a, k2)
    ws2, wobs, wr, winfo = jax.vmap(env.step)(
        want_state, a, jax.random.split(k2, B))
    assert jnp.array_equal(obs, wobs)
    assert jnp.array_equal(r, wr)
    assert jnp.array_equal(info["u"], winfo["u"])
    assert jnp.array_equal(benv.observe(s2), jax.vmap(env.observe)(ws2))


def test_unbatch_env_round_trip():
    """unbatch(batch(e)) behaves like e for the same keys."""
    env = make_traffic_env()
    rt = unbatch_env(batch_env(env), "traffic-rt")
    key = jax.random.PRNGKey(2)
    s = rt.reset(key)
    assert rt.observe(s).shape == (env.spec.obs_dim,)
    s2, obs, r, info = rt.step(s, jnp.int32(1), key)
    assert obs.shape == (env.spec.obs_dim,)
    assert jnp.ndim(r) == 0
    assert info["u"].shape == (env.spec.n_influence,)
    assert rt.spec.name == "traffic-rt"


def test_as_batched_identity_and_lift():
    env = make_traffic_env()
    benv = batch_env(env)
    assert as_batched(benv) is benv
    assert isinstance(as_batched(env), BatchedEnv)


# ---------------------------------------------------------------------------
# native batched LS == vmapped scalar LS
# ---------------------------------------------------------------------------

def test_batched_traffic_ls_matches_scalar():
    """The traffic LS draws no randomness in step, so the native batched
    implementation must match the vmapped scalar one exactly."""
    cfg = TrafficConfig(ext_influence=True)
    ls = make_local_traffic_env(cfg)
    bls = make_batched_local_traffic_env(cfg)
    vls = batch_local_env(ls)
    key = jax.random.PRNGKey(3)
    B = 8
    state = bls.reset(key, B)
    a = jax.random.randint(key, (B,), 0, 2)
    u = jax.random.bernoulli(key, 0.4, (B, 8)).astype(jnp.float32)
    s2, obs, r, info = bls.step(state, a, u, key)
    ws2, wobs, wr, winfo = vls.step(state, a, u, key)
    assert jnp.array_equal(obs, wobs)
    assert jnp.allclose(r, wr, atol=1e-6)
    assert jnp.array_equal(info["dset"], winfo["dset"])
    assert jnp.array_equal(bls.dset_fn(state, a), vls.dset_fn(state, a))
    assert jnp.array_equal(bls.observe(s2), vls.observe(ws2))


def test_batched_warehouse_ls_matches_scalar():
    """With spawning disabled (the only internal randomness) batched and
    vmapped-scalar warehouse LS transitions agree exactly."""
    cfg = WarehouseConfig(p_item=0.0)
    ls = make_local_warehouse_env(cfg)
    bls = make_batched_local_warehouse_env(cfg)
    vls = batch_local_env(ls)
    key = jax.random.PRNGKey(4)
    B = 8
    state = bls.reset(key, B)
    a = jax.random.randint(key, (B,), 0, 5)
    u = jax.random.bernoulli(key, 0.3, (B, 12)).astype(jnp.float32)
    s2, obs, r, info = bls.step(state, a, u, key)
    ws2, wobs, wr, winfo = vls.step(state, a, u, key)
    assert jnp.array_equal(obs, wobs)
    assert jnp.array_equal(r, wr)
    assert jnp.array_equal(info["dset"], winfo["dset"])
    assert jnp.array_equal(bls.dset_fn(state, a), vls.dset_fn(state, a))


# ---------------------------------------------------------------------------
# GS <-> LS exact replay THROUGH the batched engine
# ---------------------------------------------------------------------------

def test_traffic_gs_replay_through_batched_ls():
    """Replaying a multi-agent GS rollout's true u_t through the NATIVE
    BATCHED LS (agents as the batch axis) reproduces every agent's
    obs/reward exactly — the IALS defining property, fused-engine path."""
    cfg = TrafficConfig(ext_influence=True)
    gs = make_multi_traffic_env(cfg, AGENTS4)
    bls = make_batched_local_traffic_env(cfg)
    key = jax.random.PRNGKey(5)
    k0, key = jax.random.split(key)
    s0 = gs.reset(k0)
    T, A = 20, 4
    acts = jax.random.randint(key, (T, A), 0, 2)

    def gs_step(s, xs):
        a, k = xs
        s, obs, r, info = gs.step(s, a, k)
        return s, {"obs": obs, "r": r, "u": info["u"]}

    _, traj = jax.lax.scan(gs_step, s0, (acts, jax.random.split(key, T)))

    s_loc = jax.vmap(lambda i, j: local_traffic_state(s0, i, j))(
        AGENTS4[:, 0], AGENTS4[:, 1])          # (A, ...) == batch axis

    def ls_step(s, xs):
        a, u = xs
        s, obs, r, _ = bls.step(s, a, u, jax.random.PRNGKey(0))
        return s, {"obs": obs, "r": r}

    _, replay = jax.lax.scan(ls_step, s_loc, (acts, traj["u"]))
    assert jnp.array_equal(replay["obs"], traj["obs"])
    assert jnp.allclose(replay["r"], traj["r"], atol=1e-6)


def test_warehouse_gs_replay_through_batched_ls():
    cfg = WarehouseConfig(p_item=0.0)
    gs = make_multi_warehouse_env(cfg, AGENTS4)
    bls = make_batched_local_warehouse_env(cfg)
    key = jax.random.PRNGKey(6)
    k0, key = jax.random.split(key)
    s0 = gs.reset(k0)
    T, A = 16, 4
    acts = jax.random.randint(key, (T, A), 0, 5)

    def gs_step(s, xs):
        a, k = xs
        s, obs, r, info = gs.step(s, a, k)
        return s, {"obs": obs, "r": r, "u": info["u"]}

    _, traj = jax.lax.scan(gs_step, s0, (acts, jax.random.split(key, T)))
    s_loc = jax.vmap(lambda i, j: local_warehouse_state(s0, i, j))(
        AGENTS4[:, 0], AGENTS4[:, 1])

    def ls_step(s, xs):
        a, u = xs
        s, obs, r, _ = bls.step(s, a, u, jax.random.PRNGKey(0))
        return s, {"obs": obs, "r": r}

    _, replay = jax.lax.scan(ls_step, s_loc, (acts, traj["u"]))
    assert jnp.array_equal(replay["obs"], traj["obs"])
    assert jnp.allclose(replay["r"], traj["r"], atol=1e-6)


# ---------------------------------------------------------------------------
# fused batched IALS engine
# ---------------------------------------------------------------------------

def _batched_ials(cfg_kw=None, **kw):
    cfg = TrafficConfig(**(cfg_kw or {}))
    bls = make_batched_local_traffic_env(cfg)
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=bls.spec.n_influence, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(0))
    return bls, acfg, params, ials.make_batched_ials(bls, params, acfg,
                                                     **kw)


def test_batched_ials_shapes_and_determinism():
    bls, acfg, params, env = _batched_ials()
    key = jax.random.PRNGKey(7)
    B = 5
    s = env.reset(key, B)
    a = jnp.zeros((B,), jnp.int32)
    s2, obs, r, info = jax.jit(env.step)(s, a, key)
    assert obs.shape == (B, bls.spec.obs_dim)
    assert r.shape == (B,)
    assert info["u"].shape == (B, 4)
    assert info["u_probs"].shape == (B, 4)
    s3, obs3, r3, _ = jax.jit(env.step)(s, a, key)
    assert jnp.array_equal(obs, obs3) and jnp.array_equal(r, r3)
    # aip state evolved
    assert float(jnp.abs(s2.aip_state - s.aip_state).max()) > 0


def test_batched_ials_fixed_marginal_rate():
    for p in (0.1, 0.5):
        _, _, _, env = _batched_ials(fixed_marginal=p)
        key = jax.random.PRNGKey(8)
        s = env.reset(key, 16)

        def step(carry, k):
            s = carry
            s, _, _, info = env.step(s, jnp.zeros((16,), jnp.int32), k)
            return s, info["u"]

        _, us = jax.lax.scan(step, s, jax.random.split(key, 96))
        assert abs(float(us.mean()) - p) < 0.05, p


def test_batched_ials_deterministic_marginal_vec():
    """p in {0, 1} makes the threshold-compare deterministic, pinning the
    fused path's Bernoulli semantics exactly."""
    vec = jnp.array([0.0, 1.0, 0.0, 1.0])
    _, _, _, env = _batched_ials(fixed_marginal_vec=vec)
    key = jax.random.PRNGKey(9)
    s = env.reset(key, 3)
    for _ in range(4):
        key, k = jax.random.split(key)
        s, _, _, info = jax.jit(env.step)(s, jnp.zeros((3,), jnp.int32), k)
        assert jnp.array_equal(info["u"],
                               jnp.broadcast_to(vec, info["u"].shape))


def test_batched_multi_ials_matches_scalar_multi_ials_marginals():
    """Batched vs scalar multi-IALS: same per-agent fixed marginals drive
    the same per-agent u rates (the engines share dynamics, not bits)."""
    A = 4
    marg = jnp.stack([jnp.full((4,), p) for p in (0.05, 0.3, 0.6, 0.9)])
    cfg = TrafficConfig()
    bls = make_batched_local_traffic_env(cfg)
    acfg = influence.AIPConfig(kind="fnn", d_in=bls.spec.dset_dim,
                               n_out=4, hidden=8, stack=2)
    params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(0), A))
    env = multi_ials.make_batched_multi_ials(bls, params, acfg, A,
                                             fixed_marginal_vec=marg)
    key = jax.random.PRNGKey(10)
    B = 8
    s = env.reset(key, B)

    def step(carry, k):
        s = carry
        s, _, _, info = env.step(s, jnp.zeros((B, A), jnp.int32), k)
        return s, info["u"]

    _, us = jax.lax.scan(step, s, jax.random.split(key, 64))   # (T,B,A,M)
    rates = us.mean(axis=(0, 1, 3))
    assert jnp.all(jnp.abs(rates - jnp.array([0.05, 0.3, 0.6, 0.9])) < 0.06)


def test_batched_multi_ials_agent_layout():
    """(B, A, ...) layout: agent i's trained-AIP probabilities come from
    agent i's params (check by giving agents wildly different heads)."""
    A, B = 3, 4
    cfg = TrafficConfig()
    bls = make_batched_local_traffic_env(cfg)
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=4, hidden=8)
    params = jax.vmap(lambda k: influence.init_aip(acfg, k))(
        jax.random.split(jax.random.PRNGKey(1), A))
    # agent 0's head bias -> -inf (p ~ 0); agent 2's -> +inf (p ~ 1)
    hb = params["head"]["b"]
    hb = hb.at[0].set(-50.0).at[2].set(50.0)
    params = {**params, "head": {**params["head"], "b": hb}}
    env = multi_ials.make_batched_multi_ials(bls, params, acfg, A)
    key = jax.random.PRNGKey(11)
    s = env.reset(key, B)
    s2, obs, r, info = jax.jit(env.step)(s, jnp.zeros((B, A), jnp.int32),
                                         key)
    assert obs.shape == (B, A, bls.spec.obs_dim)
    assert jnp.all(info["u"][:, 0] == 0.0)
    assert jnp.all(info["u"][:, 2] == 1.0)
    assert env.observe(s2).shape == (B, A, bls.spec.obs_dim)


def test_ppo_rollout_on_batched_engine():
    """PPO's rollout consumes the fused engine natively (no vmap adapter)
    and trains one iteration end-to-end."""
    from repro.rl import ppo
    bls = make_batched_local_warehouse_env(WarehouseConfig())
    acfg = influence.AIPConfig(kind="gru", d_in=bls.spec.dset_dim,
                               n_out=12, hidden=8)
    params = influence.init_aip(acfg, jax.random.PRNGKey(2))
    env = ials.make_batched_ials(bls, params, acfg)
    cfg = ppo.PPOConfig(obs_dim=bls.spec.obs_dim, n_actions=5, n_envs=4,
                        rollout_len=6, episode_len=4, hidden=16)
    key = jax.random.PRNGKey(12)
    pol = ppo.init_policy(cfg, key)
    rs = ppo.init_rollout_state(env, cfg, key)
    rs, batch, v_last = ppo.rollout(env, cfg, pol, rs, key)
    assert batch["x"].shape == (6, 4, bls.spec.obs_dim)
    assert float(batch["done"].sum()) > 0      # periodic reset fired
    opt, it_fn = ppo.make_train_iteration(env, cfg)
    ost = opt.init(pol)
    pol, ost, rs, m = it_fn(pol, ost, rs, key)
    assert jnp.isfinite(m["loss"])
