"""Environment invariants: unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.envs.traffic import (TrafficConfig, make_traffic_env,
                                make_local_traffic_env)
from repro.envs.warehouse import (WarehouseConfig, make_warehouse_env,
                                  make_local_warehouse_env, _ITEM_RC)

SET = dict(deadline=None, max_examples=15)


# ---------------------------------------------------------------------------
# Traffic
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), action=st.integers(0, 1))
@settings(**SET)
def test_traffic_occupancy_is_boolean_and_bounded(seed, action):
    env = make_traffic_env()
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    s2, obs, r, info = jax.jit(env.step)(s, jnp.int32(action), key)
    assert s2.lanes.dtype == jnp.bool_
    assert 0.0 <= float(r) <= 1.0
    assert obs.shape == (env.spec.obs_dim,)
    assert info["u"].shape == (4,)
    assert set(jax.device_get(info["u"]).tolist()) <= {0.0, 1.0}


@given(seed=st.integers(0, 10_000))
@settings(**SET)
def test_traffic_cars_move_at_most_one_cell(seed):
    """Conservation: car count changes only via boundary inflow/outflow, and
    interior cars move <= 1 cell (cellular-automaton invariant)."""
    env = make_traffic_env()
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    n0 = int(s.lanes.sum())
    s2, _, _, info = env.step(s, jnp.int32(0), key)
    n1 = int(s2.lanes.sum())
    # at most 4 lanes x G intersections inflow and as many crossings out
    G = 5
    assert abs(n1 - n0) <= 8 * G


def test_traffic_green_lets_head_car_cross_ls():
    ls = make_local_traffic_env()
    L = 10
    lanes = jnp.zeros((4, L), bool).at[0, L - 1].set(True)
    from repro.envs.traffic import LocalTrafficState
    s = LocalTrafficState(lanes=lanes, phase=jnp.int8(0))
    key = jax.random.PRNGKey(0)
    u = jnp.zeros((4,))
    # NS green (action 0): the southbound head car crosses out
    s2, _, r, _ = ls.step(s, jnp.int32(0), u, key)
    assert int(s2.lanes.sum()) == 0
    assert float(r) == 1.0
    # EW green (action 1): it stays
    s3, _, r2, _ = ls.step(s, jnp.int32(1), u, key)
    assert bool(s3.lanes[0, L - 1])
    assert float(r2) == 0.0


def test_traffic_ls_injection_follows_u():
    ls = make_local_traffic_env()
    from repro.envs.traffic import LocalTrafficState
    s = LocalTrafficState(lanes=jnp.zeros((4, 10), bool), phase=jnp.int8(0))
    u = jnp.array([1.0, 0.0, 1.0, 0.0])
    s2, _, _, _ = ls.step(s, jnp.int32(0), u, jax.random.PRNGKey(0))
    assert jax.device_get(s2.lanes[:, 0]).tolist() == [True, False, True,
                                                       False]


# ---------------------------------------------------------------------------
# Warehouse
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), action=st.integers(0, 4))
@settings(**SET)
def test_warehouse_robots_stay_in_region(seed, action):
    env = make_warehouse_env()
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    s2, obs, r, info = jax.jit(env.step)(s, jnp.int32(action), key)
    assert bool((s2.pos >= 0).all()) and bool((s2.pos <= 4).all())
    assert float(r) >= 0.0
    assert info["u"].shape == (12,)
    assert info["dset"].shape == (24,)


@given(seed=st.integers(0, 10_000))
@settings(**SET)
def test_warehouse_vanish_after_bounds_age(seed):
    env = make_warehouse_env(WarehouseConfig(vanish_after=8))
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    step = jax.jit(env.step)
    for t in range(12):
        key, k = jax.random.split(key)
        s, _, _, _ = step(s, jnp.int32(0), k)
    assert int(s.items_h.max()) <= 8
    assert int(s.items_v.max()) <= 8


def test_warehouse_item_cells_are_region_edges():
    rs = [rc[0] for rc in _ITEM_RC]
    cs = [rc[1] for rc in _ITEM_RC]
    assert len(_ITEM_RC) == 12
    for r, c in _ITEM_RC:
        assert r in (0, 4) or c in (0, 4)


def test_warehouse_ls_u_removes_items():
    ls = make_local_warehouse_env()
    from repro.envs.warehouse import LocalWarehouseState
    s = LocalWarehouseState(pos=jnp.array([2, 2]),
                            items=jnp.ones((12,), jnp.int32))
    u = jnp.ones((12,))
    s2, _, r, _ = ls.step(s, jnp.int32(0), u, jax.random.PRNGKey(3))
    # neighbours took everything; agent (at centre, not on a shelf) got none
    assert float(r) == 0.0
    # all items removed (spawn may re-add a couple with p=0.02)
    assert int((s2.items > 1).sum()) == 0


def test_warehouse_agent_pickup_reward():
    ls = make_local_warehouse_env()
    from repro.envs.warehouse import LocalWarehouseState
    # stand next to item cell (0,1); move up onto it
    s = LocalWarehouseState(pos=jnp.array([1, 1]),
                            items=jnp.ones((12,), jnp.int32))
    s2, _, r, _ = ls.step(s, jnp.int32(1), jnp.zeros((12,)),
                          jax.random.PRNGKey(0))
    assert float(r) == 1.0


def test_gs_and_ls_specs_agree():
    for gs, ls in ((make_traffic_env(), make_local_traffic_env()),
                   (make_warehouse_env(), make_local_warehouse_env())):
        assert gs.spec.obs_dim == ls.spec.obs_dim
        assert gs.spec.n_actions == ls.spec.n_actions
        assert gs.spec.n_influence == ls.spec.n_influence
        assert gs.spec.dset_dim == ls.spec.dset_dim
