"""The serving tier (PR 8): ragged-batch parity + scheduler guarantees.

Pins the serving contract of docs/ARCHITECTURE.md §8 exactly as stated:

* **Packed-vs-dense bitwise parity.** Inside one jitted fixed-slot
  program, a real lane's (action, logits, v) are bitwise-identical to a
  dense all-copies dispatch of the same request at the same slot shape —
  whatever the pad lanes hold (zeros, 1e6, NaN) and wherever the lane
  sits. Pinned for both domains x both AIP backbones (backbone-specific
  engine rollouts supply the frames) on the production dispatch route
  AND the forced interpret-mode Pallas kernel. The reference is a
  same-slot-shape dispatch on purpose: XLA's GEMM reduction order is
  program-shape-dependent, so the *compiled fixed-slot program* — not
  "the math" — is the unit of bitwise reproducibility.
* **Pad lanes are no-ops.** Outputs at pad lanes are exactly zero (and
  action 0) regardless of pad content; pad content never perturbs real
  lanes (property-tested across fill patterns via hypothesis, or its
  deterministic hypcompat grid when hypothesis is absent).
* **Scheduler guarantees.** No silent drops, EDF across classes with
  FIFO within a class, and miss counters that equal a ground-truth
  recount of the completion log — on adversarial traces with tied
  arrivals and a zero-slack deadline class.
* **Serve-time restore.** ``ckpt.restore_subtree`` brings a policy out
  of a full rl_train checkpoint without reading the training payload —
  proven by deleting every non-policy member from ``arrays.npz`` and
  restoring anyway.
"""
import json
import zipfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pure-pytest fallback (hypcompat)
    from hypcompat import given, settings, st

from repro.checkpoint import ckpt
from repro.core import engine, influence
from repro.envs.api import pad_lanes, pad_mask
from repro.envs.traffic import TrafficConfig, make_batched_local_traffic_env
from repro.envs.warehouse import (WarehouseConfig,
                                  make_batched_local_warehouse_env)
from repro.launch import policy_serve
from repro.rl import ppo
from repro.serving import (PolicyServer, Request, SlotScheduler,
                           TraceConfig, synthetic_trace)

S = 8                                    # the test slot shape
FRAME_STACK = {"traffic": 1, "warehouse": 8}    # as rl_train.build_domain
_JUNK = {"zero": 0.0, "big": 1e6, "nan": np.nan}
_cache = {}


def _bls(domain):
    if domain == "traffic":
        return make_batched_local_traffic_env(TrafficConfig())
    return make_batched_local_warehouse_env(WarehouseConfig())


def _frames(domain, kind):
    """(S, frame_dim) f32 observation frames from a short rollout of the
    unified IALS engine with the given AIP backbone — real serving
    inputs, and the backbone axis of the parity matrix."""
    key = ("frames", domain, kind)
    if key not in _cache:
        bls = _bls(domain)
        acfg = influence.AIPConfig(kind=kind, d_in=bls.spec.dset_dim,
                                   n_out=bls.spec.n_influence, hidden=8,
                                   stack=2)
        aip = influence.init_aip(acfg, jax.random.PRNGKey(0))
        env = engine.make_unified_ials(bls, aip, acfg, n_agents=1,
                                       use_horizon_kernel=False)
        state = env.reset(jax.random.PRNGKey(1), S)
        k = jax.random.PRNGKey(2)
        for _ in range(2):
            k, ka, ks = jax.random.split(k, 3)
            a = jax.random.randint(ka, (S,), 0, bls.spec.n_actions)
            state, _, _, _ = env.step(state, a, ks)
        obs = np.asarray(env.observe(state), np.float32)
        _cache[key] = np.tile(obs, (1, FRAME_STACK[domain]))
    return _cache[key]


def _server(domain, route):
    """One PolicyServer per (domain, route), shared across tests so each
    jitted slot program compiles once. All routes of a domain share the
    same params (same init key)."""
    key = ("server", domain, route)
    if key not in _cache:
        bls = _bls(domain)
        pcfg = ppo.PPOConfig(obs_dim=bls.spec.obs_dim,
                             n_actions=bls.spec.n_actions,
                             frame_stack=FRAME_STACK[domain], hidden=16)
        params = ppo.init_policy(pcfg, jax.random.PRNGKey(3))
        _cache[key] = PolicyServer(params, obs_dim=pcfg.obs_dim,
                                   n_actions=pcfg.n_actions,
                                   frame_stack=FRAME_STACK[domain],
                                   slot=S, route=route)
    return _cache[key]


def _packed(frames, n_valid, junk):
    out = frames.copy()
    out[n_valid:] = _JUNK[junk]
    return out


# ---------------------------------------------------------------- parity

@pytest.mark.parametrize("route", ["auto", "interpret"])
@pytest.mark.parametrize("kind", ["gru", "fnn"])
@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_packed_vs_dense_bitwise(domain, kind, route):
    """Every real lane of a NaN-padded packed slot == the same request
    dispatched dense (all-copies, same slot shape), bitwise, on both
    dispatch routes; pad-lane outputs are exactly zero."""
    frames = _frames(domain, kind)
    srv = _server(domain, route)
    for n_valid in (1, 3, S):
        a, lg, v = srv.forward_slot(_packed(frames, n_valid, "nan"),
                                    n_valid)
        for i in range(n_valid):
            da, dlg, dv = srv.forward_slot(np.tile(frames[i], (S, 1)), S)
            assert jnp.array_equal(lg[i], dlg[i]), (n_valid, i)
            assert jnp.array_equal(v[i], dv[i]), (n_valid, i)
            assert int(a[i]) == int(da[i]), (n_valid, i)
        assert not jnp.any(lg[n_valid:]) and not jnp.any(v[n_valid:])
        assert not jnp.any(a[n_valid:])


@settings(max_examples=20, deadline=None)
@given(n_valid=st.integers(1, S),
       junk=st.sampled_from(["zero", "big", "nan"]))
def test_pad_content_never_perturbs_real_lanes(n_valid, junk):
    """Property: real-lane outputs are a function of real-lane inputs
    only — any pad fill (including NaN, which would poison an unmasked
    reduction) leaves them bitwise-unchanged on both routes."""
    frames = _frames("traffic", "gru")
    for route in ("auto", "interpret"):
        srv = _server("traffic", route)
        base = srv.forward_slot(_packed(frames, n_valid, "zero"), n_valid)
        var = srv.forward_slot(_packed(frames, n_valid, junk), n_valid)
        for b, w in zip(base, var):
            assert jnp.array_equal(b[:n_valid], w[:n_valid]), (route, junk)
        assert not jnp.any(var[1][n_valid:])


def test_lane_permutation_equivariance():
    """Where a request sits in the slot does not change its outputs:
    permuting the packed lanes permutes the outputs, bitwise."""
    frames = _frames("traffic", "fnn")
    perm = np.random.default_rng(0).permutation(S)
    for route in ("auto", "interpret"):
        srv = _server("traffic", route)
        out = srv.forward_slot(frames, S)
        pout = srv.forward_slot(frames[perm], S)
        for o, p in zip(out, pout):
            assert jnp.array_equal(p, jnp.asarray(o)[perm]), route


@pytest.mark.parametrize("domain", ["traffic", "warehouse"])
def test_serve_forward_matches_training_policy(domain):
    """The fused serving forward == the training net
    (``ppo.policy_forward``) on logits/actions bitwise under jit; ``v``
    is the documented 1-ulp allclose leaf (the fused route computes both
    heads as one GEMM)."""
    frames = _frames(domain, "gru")
    aa, la, va = _server(domain, "auto").forward_slot(frames, S)
    ax, lx, vx = _server(domain, "xla").forward_slot(frames, S)
    assert jnp.array_equal(la, lx)
    assert jnp.array_equal(aa, ax)
    assert jnp.allclose(va, vx, atol=1e-6)


def test_pad_lanes_and_mask_contract():
    """The ragged-batch packing helpers: edge fill replicates lane 0,
    zero fill writes zeros, oversize batches and unknown fills raise,
    and ``pad_mask`` marks exactly the real prefix."""
    tree = {"x": jnp.arange(6.0).reshape(3, 2), "y": jnp.arange(3)}
    out = pad_lanes(tree, 5)
    assert out["x"].shape == (5, 2) and out["y"].shape == (5,)
    assert jnp.array_equal(out["x"][:3], tree["x"])
    assert jnp.array_equal(out["x"][3:],
                           jnp.broadcast_to(tree["x"][:1], (2, 2)))
    zout = pad_lanes(tree, 5, fill="zero")
    assert not jnp.any(zout["y"][3:])
    assert zout["y"].dtype == tree["y"].dtype
    with pytest.raises(ValueError):
        pad_lanes(tree, 2)
    with pytest.raises(ValueError):
        pad_lanes(tree, 5, fill="wrap")
    assert jnp.array_equal(pad_mask(3, 5),
                           jnp.array([1, 1, 1, 0, 0], bool))
    with pytest.raises(ValueError):
        PolicyServer({}, obs_dim=4, n_actions=2, route="mystery")


# ------------------------------------------------------------- scheduler

def _adversarial_trace(seed, n=60):
    """Tied arrivals (coarse rounding), a zero-slack deadline class
    (klass 0 misses by construction), interleaved classes."""
    rng = np.random.default_rng(seed)
    classes = (0.0, 0.004, 0.02)
    arrivals = np.sort(np.round(rng.uniform(0.0, 0.05, n), 3))
    frame = np.zeros(4, np.float32)
    return [Request(rid=rid, region=int(rng.integers(0, 5)),
                    klass=(k := int(rng.integers(0, len(classes)))),
                    arrival=float(t), deadline=float(t) + classes[k],
                    frame=frame)
            for rid, t in enumerate(arrivals)]


def _drive(trace, slot, service_s=0.003):
    """The server's replay loop with a virtual clock, scheduler only —
    returns (scheduler, batches in pop order)."""
    sched = SlotScheduler(slot)
    pops, now, i = [], 0.0, 0
    while i < len(trace) or sched.pending:
        while i < len(trace) and trace[i].arrival <= now:
            sched.admit(trace[i])
            i += 1
        if not sched.pending:
            now = trace[i].arrival
            continue
        batch = sched.next_batch()
        now += service_s
        sched.complete(batch, now)
        pops.append(batch)
    return sched, pops


@given(seed=st.integers(0, 3), slot=st.sampled_from([1, 3, 8]))
def test_scheduler_no_drops_and_exact_miss_accounting(seed, slot):
    """Every admitted request is served exactly once (even the ones that
    already missed — recorded, never shed), and the miss counters equal
    an independent recount of the completion log."""
    trace = _adversarial_trace(seed)
    sched, pops = _drive(trace, slot)
    served_rids = sorted(r.rid for b in pops for r in b)
    assert served_rids == list(range(len(trace)))     # exactly once each
    assert sched.served == sched.admitted == len(trace)
    assert sched.pending == 0
    misses, by_class = 0, {}
    for rid, klass, arrival, deadline, t_done in sched.completions:
        assert deadline == trace[rid].deadline
        if t_done > deadline:
            misses += 1
            by_class[klass] = by_class.get(klass, 0) + 1
    assert sched.deadline_misses == misses
    assert sched.misses_by_class == by_class
    assert misses > 0                    # klass 0 has zero slack


@given(seed=st.integers(0, 3), slot=st.sampled_from([1, 3, 8]))
def test_scheduler_edf_and_fifo_within_class(seed, slot):
    """Each popped batch is deadline-sorted (EDF), and per deadline
    class the global pop order is admission order (FIFO) — absolute
    deadlines make that a theorem, the heap tiebreak makes it bitwise."""
    trace = _adversarial_trace(seed)
    _, pops = _drive(trace, slot)
    for batch in pops:
        dls = [r.deadline for r in batch]
        assert dls == sorted(dls)
    flat = [r for b in pops for r in b]
    for klass in {r.klass for r in trace}:
        rids = [r.rid for r in flat if r.klass == klass]
        assert rids == sorted(rids), klass


def test_scheduler_rejects_degenerate_slot():
    with pytest.raises(ValueError):
        SlotScheduler(0)


# ------------------------------------------------- trace + virtual replay

def test_synthetic_trace_deterministic_sorted_and_bursty():
    cfg = TraceConfig(n_regions=12, mean_rps=600.0, horizon_s=0.3,
                      frame_dim=6, seed=4)
    a, b = synthetic_trace(cfg), synthetic_trace(cfg)
    assert len(a) == len(b) > 0
    sizes_by_region = {}
    for ra, rb in zip(a, b):
        assert (ra.rid, ra.region, ra.klass, ra.arrival,
                ra.deadline) == (rb.rid, rb.region, rb.klass, rb.arrival,
                                 rb.deadline)
        assert np.array_equal(ra.frame, rb.frame)        # pure fn of cfg
        assert ra.deadline == ra.arrival + cfg.classes_s[ra.klass]
        assert ra.frame.shape == (cfg.frame_dim,)
        sizes_by_region.setdefault((ra.region, ra.arrival), 0)
        sizes_by_region[(ra.region, ra.arrival)] += 1
    assert [r.rid for r in a] == list(range(len(a)))     # dense rids
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)
    sizes = {}
    for (region, _), k in sizes_by_region.items():
        assert k in cfg.region_sizes                     # whole bursts
        sizes.setdefault(region, set()).add(k)
    assert all(len(s) == 1 for s in sizes.values())      # fixed per region
    assert len({r.region for r in a}) == cfg.n_regions   # staggered phases


def test_virtual_replay_report_is_exact_and_deterministic():
    """``mode="virtual"`` report numbers equal a ground-truth recount of
    the scheduler's completion log, and two replays are identical."""
    srv = _server("traffic", "auto")
    trace = synthetic_trace(TraceConfig(
        n_regions=8, mean_rps=400.0, horizon_s=0.2,
        frame_dim=srv.frame_dim, seed=5))
    sched = SlotScheduler(srv.slot)
    rep = srv.serve(trace, sched, mode="virtual", service_time_s=0.002)
    assert rep.requests == rep.served == len(trace) == sched.served
    assert rep.dispatches >= 1
    assert rep.mean_occupancy * rep.dispatches == pytest.approx(
        rep.served)                      # every request in some batch
    lat = np.array([t - a for (_, _, a, _, t) in sched.completions])
    assert rep.p50_s == float(np.percentile(lat, 50))
    assert rep.p99_s == float(np.percentile(lat, 99))
    misses = sum(t > d for (_, _, _, d, t) in sched.completions)
    assert rep.deadline_misses == misses == sched.deadline_misses
    last_done = max(t for (_, _, _, _, t) in sched.completions)
    assert np.isclose(rep.qps, rep.served / (last_done
                                             - trace[0].arrival))
    rep2 = srv.serve(trace, mode="virtual", service_time_s=0.002)
    assert rep2.latencies_s == rep.latencies_s
    assert rep2.summary() == rep.summary()
    with pytest.raises(ValueError):
        srv.serve(trace, mode="closed-loop")


# ------------------------------------------------------ restore + driver

def test_serve_restore_reads_only_policy_payload(tmp_path):
    """Serve-time policy restore never touches the training payload:
    delete every non-``['policy']`` member from ``arrays.npz`` — full
    ``restore`` breaks, ``restore_subtree`` still yields exact params,
    and a server built from them matches the original bitwise."""
    pcfg = ppo.PPOConfig(obs_dim=41, n_actions=2, frame_stack=1,
                         hidden=16)
    policy = ppo.init_policy(pcfg, jax.random.PRNGKey(7))
    tree = {"policy": policy,
            "opt": {"m": jnp.zeros((256, 256)), "v": jnp.ones((256, 256))},
            "rs": jnp.arange(32, dtype=jnp.uint32),
            "it": jnp.int32(11)}
    ckpt.save(tmp_path, 11, tree, metadata={"it": 11})

    d = tmp_path / "step_000000011"
    meta = msgpack.unpackb((d / "meta.msgpack").read_bytes())
    keep = {f"leaf_{i:05d}.npy" for i, p in enumerate(meta["paths"])
            if p.startswith("['policy']")}
    assert 0 < len(keep) < len(meta["paths"])
    src = d / "arrays.npz"
    with zipfile.ZipFile(src) as zin:
        members = {n: zin.read(n) for n in zin.namelist() if n in keep}
    with zipfile.ZipFile(src, "w") as zout:
        for n, raw in members.items():
            zout.writestr(n, raw)

    with pytest.raises(KeyError):        # training payload really gone
        ckpt.restore(tmp_path, jax.eval_shape(lambda: tree))
    got, step, user = ckpt.restore_subtree(
        tmp_path, jax.eval_shape(lambda: policy), "['policy']")
    assert step == 11 and user == {"it": 11}
    for a, b in zip(jax.tree_util.tree_leaves(policy),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype and bool((a == b).all())

    frames = _frames("traffic", "gru")
    kw = dict(obs_dim=41, n_actions=2, frame_stack=1, slot=S)
    out_a = PolicyServer(policy, **kw).forward_slot(frames, 5)
    out_b = PolicyServer(got, **kw).forward_slot(frames, 5)
    for x, y in zip(out_a, out_b):
        assert jnp.array_equal(x, y)


def test_policy_serve_driver_end_to_end(tmp_path):
    """The launch driver serves a small wall-clock trace to completion
    and writes the JSON report."""
    out = tmp_path / "serve.json"
    res = policy_serve.main([
        "--domain", "traffic", "--slot", "8", "--regions", "4",
        "--rps", "400", "--duration-s", "0.05", "--out", str(out)])
    assert res["served"] == res["requests"] > 0
    assert res["p99_ms"] >= res["p50_ms"] > 0
    on_disk = json.loads(out.read_text())
    assert on_disk == res                # json round-trips floats exactly
