"""Per-kernel shape/dtype sweeps, allclose vs the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.gru import gru_sequence as gru_kernel
from repro.kernels.rmsnorm import rmsnorm as rms_kernel


@pytest.mark.parametrize("T,S,D,causal,dtype", [
    (128, 128, 64, True, jnp.float32),
    (128, 128, 64, False, jnp.float32),
    (256, 256, 128, True, jnp.float32),
    (128, 256, 64, False, jnp.float32),   # cross-attn shape (T != S)
    (128, 128, 64, True, jnp.bfloat16),
])
def test_flash_attention_kernel(T, S, D, causal, dtype):
    key = jax.random.PRNGKey(0)
    BH = 4
    q = jax.random.normal(key, (BH, T, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, D), dtype)
    out = fa_kernel(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(out.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 32), (32, 128)])
def test_flash_attention_block_shapes(bq, bk):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 256, 64))
    k = jax.random.normal(key, (2, 256, 64))
    v = jax.random.normal(key, (2, 256, 64))
    out = fa_kernel(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(out - want).max()) < 2e-5


def test_flash_attention_gqa_wrapper():
    key = jax.random.PRNGKey(4)
    B, T, H, KH, D = 2, 128, 8, 2, 64
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(5), (B, T, KH, D))
    v = jax.random.normal(jax.random.PRNGKey(6), (B, T, KH, D))
    out = ops.flash_attention_mha(q, k, v, causal=True)
    kf = jnp.repeat(k, H // KH, axis=2).transpose(0, 2, 1, 3).reshape(
        B * H, T, D)
    vf = jnp.repeat(v, H // KH, axis=2).transpose(0, 2, 1, 3).reshape(
        B * H, T, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    want = ref.flash_attention_ref(qf, kf, vf, causal=True).reshape(
        B, H, T, D).transpose(0, 2, 1, 3)
    assert float(jnp.abs(out - want).max()) < 2e-5


@pytest.mark.parametrize("B,T,D,H,dtype", [
    (4, 20, 24, 32, jnp.float32),
    (1, 1, 8, 16, jnp.float32),
    (8, 64, 40, 64, jnp.float32),
    (2, 16, 12, 32, jnp.bfloat16),
])
def test_gru_kernel(B, T, D, H, dtype):
    key = jax.random.PRNGKey(7)
    wx = jax.random.normal(key, (D, 3 * H), dtype) * 0.2
    wh = jax.random.normal(jax.random.PRNGKey(8), (H, 3 * H), dtype) * 0.2
    b = jnp.zeros((3 * H,), dtype)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, T, D), dtype)
    h0 = jnp.zeros((B, H), dtype)
    hs, hT = gru_kernel(x, wx, wh, b, h0, interpret=True)
    hs_r, hT_r = ref.gru_sequence_ref(x, wx, wh, b, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    assert float(jnp.abs(hs.astype(jnp.float32)
                         - hs_r.astype(jnp.float32)).max()) < tol
    assert float(jnp.abs(hT.astype(jnp.float32)
                         - hT_r.astype(jnp.float32)).max()) < tol


def test_gru_kernel_matches_nn_rnn():
    """The kernel is a drop-in for repro.nn.rnn.gru_sequence."""
    from repro.nn.rnn import gru_init, gru_sequence
    key = jax.random.PRNGKey(10)
    p = gru_init(key, 16, 32)
    x = jax.random.normal(key, (3, 12, 16))
    hs_k, _ = ops.gru_sequence(p, x)
    hs_x, _ = gru_sequence(p, x)
    assert float(jnp.abs(hs_k - hs_x).max()) < 1e-5


@pytest.mark.parametrize("N,d,dtype", [
    (256, 128, jnp.float32),
    (1000, 512, jnp.float32),     # N not divisible by default block
    (64, 256, jnp.bfloat16),
])
def test_rmsnorm_kernel(N, d, dtype):
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (N, d), dtype)
    g = jax.random.normal(jax.random.PRNGKey(12), (d,), jnp.float32)
    out = rms_kernel(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < 1e-2
