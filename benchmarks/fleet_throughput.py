"""Fleet tier: disaggregated actor/learner throughput + fault resilience.

Two questions, per domain, about ``distributed/actor_learner.py`` driving
the fused IALS engine:

1. **Scaling** — aggregate sample production (samples/s = batches produced
   x n_envs x rollout_len / wall-clock) of the *async* fleet vs worker
   count. On this single-process CPU container the workers time-share the
   same cores, so the curve measures harness overhead (queue + param
   store + staleness gate), not silicon scaling — flat-or-better is the
   pass shape, and the per-worker rates are the committed regression
   floors (``fleet_throughput_{domain}.json``, gated by ``--check``).

2. **Fault resilience** — time-to-reward-target of the *deterministic*
   fleet with and without an injected worker kill
   (``fault_injection.KillWorker``: the worker loses its rollout state
   mid-run and restarts). The target is seeded from the committed
   ``learning_curves_{domain}.json`` IALS curve: its plateau (mean of
   the last-half evals — the final point alone is a 4-episode draw),
   with a band of 25% of the first-to-plateau travel (floored at 0.02 —
   the warehouse curve's travel is small). "Reached" is
   direction-agnostic — inside the band, or past the target on the
   approach side — because these curves converge downward on traffic and
   upward on warehouse. Results go to ``fleet_faults_{domain}.json``
   (informational; never a regression baseline — wall-clock-to-target is
   too seeded to gate on).

    PYTHONPATH=src python -m benchmarks.fleet_throughput [--quick]
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.distributed import actor_learner, fault_injection
from repro.rl import ppo

from .common import RESULTS_DIR, build_sims, row, save_json


def _ppo_cfg(spec, domain: str, n_envs: int, T: int, ep_len: int):
    return ppo.PPOConfig(obs_dim=spec.obs_dim, n_actions=spec.n_actions,
                         frame_stack=8 if domain == "warehouse" else 1,
                         n_envs=n_envs, rollout_len=T, episode_len=ep_len)


def _reward_target(domain: str):
    """-> (target, band, first) from the committed IALS learning curve,
    or None when no curve is committed (fresh checkout).

    The target is the curve's *plateau* — the mean of its last-half
    evals — not the single final eval: each committed eval point is only
    4 episodes, and on the warehouse reward scale (~0.01-0.06) one
    lucky final draw would set a target no same-compute rerun reaches
    (measured: an integrated-trainer rerun at the curve's own scale
    plateaus at 0.01-0.03 while the curve's last point is 0.0605)."""
    path = RESULTS_DIR / f"learning_curves_{domain}.json"
    if not path.exists():
        return None
    curve = json.loads(path.read_text())["ials"]
    evals = [r["gs_eval_r"] for r in curve]
    first = evals[0]
    tail = evals[len(evals) // 2:]
    target = sum(tail) / len(tail)
    band = max(0.25 * abs(target - first), 0.02)
    return target, band, first


def _reached(r: float, target: float, band: float, first: float) -> bool:
    """Inside the band, or overshot the target coming from ``first``'s
    side — curves that keep improving past the target still count."""
    if abs(r - target) <= band:
        return True
    return r <= target if first > target else r >= target


def _time_to_target(trainer, gs, pcfg, target, band, first, *,
                    max_updates: int, eval_every: int, key):
    """Run the fleet until the GS-eval reward reaches the target (or the
    update budget runs out) -> result dict."""
    state = trainer.init_state()
    wallclock = 0.0
    evals = []
    while int(state.version) < max_updates:
        state, info = trainer.run(state, eval_every)
        wallclock += info["wallclock_s"]
        v = int(state.version)
        r = ppo.evaluate(gs, pcfg, state.params,
                         jax.random.fold_in(key, v), n_episodes=4)
        evals.append({"update": v, "gs_eval_r": round(float(r), 4)})
        if _reached(float(r), target, band, first):
            return {"reached": True, "updates_to_target": v,
                    "train_wallclock_s": round(wallclock, 2),
                    "evals": evals}
    return {"reached": False, "updates_to_target": None,
            "train_wallclock_s": round(wallclock, 2), "evals": evals}


def run(quick: bool = False):
    out = []
    n_envs, T = (4, 32) if quick else (8, 64)
    worker_counts = (1, 2) if quick else (1, 2, 4)
    n_updates = 3 if quick else 8
    domains = ["traffic"] if quick else ["traffic", "warehouse"]
    for domain in domains:
        key = jax.random.PRNGKey(0)
        # full-size AIP build matches the committed learning-curves run:
        # the reward target below was measured with THAT simulator quality
        sims, *_ = build_sims(domain, key,
                              collect_episodes=8 if quick else 48,
                              aip_epochs=2 if quick else 8)
        env = sims["ials"]
        cfg = _ppo_cfg(env.spec, domain, n_envs, T, ep_len=T)

        # -- 1. async fleet scaling -----------------------------------
        rates = {}
        for w in worker_counts:
            fcfg = actor_learner.FleetConfig(n_workers=w, queue_size=8,
                                             max_staleness=4,
                                             deterministic=False, seed=0)
            trainer = actor_learner.ActorLearnerTrainer(env, cfg, fcfg)
            state = trainer.init_state()
            state, _ = trainer.run(state, 1)       # warmup / compile
            state, info = trainer.run(state, n_updates)
            samples = info["produced"] * n_envs * T
            rate = samples / max(info["wallclock_s"], 1e-9)
            rates[f"fleet-w{w}"] = rate
            out.append(row(
                f"fleet_throughput/{domain}/w{w}",
                info["wallclock_s"] * 1e6 / max(samples, 1),
                {"samples_per_s": round(rate),
                 "updates_per_s": round(
                     info["updates"] / max(info["wallclock_s"], 1e-9), 2),
                 "produced": info["produced"],
                 "dropped": info["dropped"]}))
        if not quick:
            # quick-mode rates are not baselines: writing them would
            # silently corrupt the committed bench-check floors
            save_json(f"fleet_throughput_{domain}", rates)

        # -- 2. time-to-target with and without a worker kill ---------
        seeded = _reward_target(domain)
        if seeded is None:
            out.append(row(f"fleet_faults/{domain}/skipped", 0.0,
                           {"reason": "no committed learning curve"}))
            continue
        target, band, first = seeded
        # match the committed curve's training scale so the target is
        # actually on this run's trajectory
        fn_envs, fT = (8, 64) if quick else (16, 128)
        fcfg_det = actor_learner.FleetConfig(n_workers=2, queue_size=8,
                                             max_staleness=4,
                                             deterministic=True, seed=2)
        fcfg_cfg = _ppo_cfg(env.spec, domain, fn_envs, fT, ep_len=128)
        max_updates = 6 if quick else 24
        results = {"target": target, "band": band, "first": first}
        for label, plan in (
                ("no_fault", None),
                ("with_fault", fault_injection.FaultPlan.of(
                    fault_injection.KillWorker(worker_id=1, at_tick=1)))):
            injector = (fault_injection.FaultInjector(plan)
                        if plan is not None else None)
            trainer = actor_learner.ActorLearnerTrainer(
                env, fcfg_cfg, fcfg_det, injector=injector)
            res = _time_to_target(trainer, sims["gs"], fcfg_cfg, target,
                                  band, first, max_updates=max_updates,
                                  eval_every=2, key=jax.random.PRNGKey(7))
            if injector is not None:
                res["kills"] = injector.kills_applied
            results[label] = res
            out.append(row(
                f"fleet_faults/{domain}/{label}", 0.0,
                {"reached": res["reached"],
                 "updates_to_target": res["updates_to_target"],
                 "train_wallclock_s": res["train_wallclock_s"],
                 "kills": res.get("kills", 0),
                 "target": round(target, 4), "band": round(band, 4)}))
        if not quick:
            save_json(f"fleet_faults_{domain}", results)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
