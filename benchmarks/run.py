"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --check   # regression gate

``--check`` re-measures the throughput benches and compares each
steps/s entry against the committed ``results/bench/*.json`` baselines,
failing on a >30% regression; the baseline files are restored afterwards
so the gate is side-effect-free (``make bench-check``).

Prints ``name,us_per_call,derived`` CSV lines (derived is a JSON dict).
Mapping to the paper:
    simulator_throughput  Fig. 3/5 middle (GS vs IALS total runtime)
    multi_agent_throughput  Distributed-IALS: N batched IALS vs Python loop
    aip_accuracy          Fig. 3/5 bottom + App. E Eq. 9/10
    learning_curves       Fig. 3/5 top + App. E Fig. 11/12 (F-IALS)
    serve_throughput      continuous-batching policy serving: QPS + p50/p99
    fleet_throughput      disaggregated actor/learner scaling + faults
    memory_dependence     Fig. 6 (Theorem 1)
    dset_ablation         App. B / §4.2 (Theorem 2)
    kernel_bench          Pallas kernels vs oracles
    roofline_report       EXPERIMENTS.md §Roofline source (dry-run cells)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    "kernel_bench",
    "roofline_report",
    "simulator_throughput",
    "multi_agent_throughput",
    "train_throughput",
    "serve_throughput",
    "fleet_throughput",
    "aip_accuracy",
    "dset_ablation",
    "memory_dependence",
    "learning_curves",
]

# modules whose saved JSONs are flat {simulator: steps/s} rate tables —
# the --check regression gate compares these against the committed files
CHECK_MODULES = {"simulator_throughput": "sim_throughput_",
                 "multi_agent_throughput": "multi_agent_throughput_",
                 "train_throughput": "train_throughput_",
                 "serve_throughput": "serve_throughput_",
                 # fleet_faults_*.json is informational, not a baseline —
                 # the prefix below deliberately excludes it
                 "fleet_throughput": "fleet_throughput_"}
CHECK_TOLERANCE = 0.30


def _rate_files(mods):
    from .common import RESULTS_DIR
    prefixes = tuple(CHECK_MODULES[m] for m in mods)
    return sorted(p for p in RESULTS_DIR.glob("*.json")
                  if p.name.startswith(prefixes))


def check_dryrun_cells() -> int:
    """The roofline pipeline must have real cells: zero ok dry-run cells
    means the committed roofline artifacts are (or would regenerate as)
    empty — fail the gate and name the command that fixes it."""
    from . import roofline_report
    cells = roofline_report.load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    if not ok:
        print(f"# check: {len(cells)} dry-run cells, 0 ok — the roofline "
              f"artifacts are empty. Run: {roofline_report.DRYRUN_CMD}")
        return 1
    print(f"# check: roofline dry-run cells ok={len(ok)}")
    return 0


def check_regressions(baselines) -> int:
    """Compare freshly saved rate tables against the committed baselines.
    -> number of >CHECK_TOLERANCE regressions (0 == gate passes)."""
    bad = 0
    for path, old in baselines.items():
        new = json.loads(path.read_text())
        for sim, old_rate in old.items():
            new_rate = new.get(sim)
            if new_rate is None:
                print(f"# check: {path.name}:{sim} missing from fresh run")
                bad += 1
                continue
            ratio = new_rate / max(old_rate, 1e-9)
            status = "REGRESSION" if ratio < 1.0 - CHECK_TOLERANCE else "ok"
            print(f"# check: {path.name}:{sim} {old_rate:.0f} -> "
                  f"{new_rate:.0f} steps/s ({ratio:.2f}x) {status}")
            if status == "REGRESSION":
                bad += 1
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check", action="store_true",
                    help="re-measure throughput benches, fail on a >30%% "
                         "steps/s regression vs results/bench baselines")
    ap.add_argument("--out", default=None,
                    help="write every module's emitted rows to this JSON "
                         "file (CI uploads it as the bench-smoke "
                         "artifact); never touches results/bench")
    args = ap.parse_args(argv)

    if args.check:
        if args.quick:
            ap.error("--check needs full-size runs (the baselines were "
                     "measured at full size); drop --quick")
        mods = [m for m in CHECK_MODULES
                if args.only is None or m == args.only]
        if not mods:
            ap.error(f"--check --only must name one of "
                     f"{sorted(CHECK_MODULES)}")
    else:
        mods = [m for m in MODULES if args.only is None or m == args.only]
    baselines = ({p: json.loads(p.read_text()) for p in _rate_files(mods)}
                 if args.check else {})

    print("name,us_per_call,derived")
    failures = 0
    collected = {}
    try:
        for name in mods:
            t0 = time.time()
            print(f"# --- {name} ---", flush=True)
            try:
                mod = __import__(f"benchmarks.{name}", fromlist=["run"])
                collected[name] = mod.run(quick=args.quick)
                print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
            except Exception:
                failures += 1
                print(f"# {name} FAILED:", flush=True)
                traceback.print_exc()
        if args.check:
            failures += check_regressions(baselines)
            failures += check_dryrun_cells()
    finally:
        for path, old in baselines.items():   # gate is side-effect-free,
            path.write_text(json.dumps(old, indent=1))  # crash included
        if args.out:
            from pathlib import Path
            Path(args.out).write_text(json.dumps(
                {"quick": args.quick, "failures": failures,
                 "rows": collected}, indent=1))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
