"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines (derived is a JSON dict).
Mapping to the paper:
    simulator_throughput  Fig. 3/5 middle (GS vs IALS total runtime)
    multi_agent_throughput  Distributed-IALS: N batched IALS vs Python loop
    aip_accuracy          Fig. 3/5 bottom + App. E Eq. 9/10
    learning_curves       Fig. 3/5 top + App. E Fig. 11/12 (F-IALS)
    memory_dependence     Fig. 6 (Theorem 1)
    dset_ablation         App. B / §4.2 (Theorem 2)
    kernel_bench          Pallas kernels vs oracles
    roofline_report       EXPERIMENTS.md §Roofline source (dry-run cells)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "kernel_bench",
    "roofline_report",
    "simulator_throughput",
    "multi_agent_throughput",
    "aip_accuracy",
    "dset_ablation",
    "memory_dependence",
    "learning_curves",
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    mods = [m for m in MODULES if args.only is None or m == args.only]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
