"""Dry-run roofline table: reads results/dryrun/*.json -> CSV rows + the
markdown table EXPERIMENTS.md embeds (results/bench/roofline_table.md).

The report is load-bearing: an empty/missing ``results/dryrun`` raises
(so ``benchmarks.run`` — and CI — fail instead of committing header-only
tables), and a malformed cell becomes a labeled error row instead of a
KeyError. See the "roofline contract" section of docs/ARCHITECTURE.md
for what a cell contains and how the times are derived.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import row, save_json

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"

# the command that (re)generates the IALS cells this report is built from
DRYRUN_CMD = "PYTHONPATH=src python -m repro.launch.dryrun --ials all"


def load_cells():
    cells = []
    for f in sorted(DRYRUN.glob("*.json")):
        try:
            cells.append(json.loads(f.read_text()))
        except json.JSONDecodeError:
            cells.append({"arch": f.stem, "status": "error",
                          "error": "unparseable JSON"})
    return cells


def _cell_row(c) -> str:
    """One table row; malformed cells (missing arch/shape/roofline keys)
    degrade to a labeled error row instead of crashing the report."""
    arch = c.get("arch", "?")
    shape = c.get("shape", "?")
    if c.get("status") != "ok":
        return (f"| {arch} | {shape} | — | — | — | "
                f"{c.get('status', '?')} | — | — | — |")
    try:
        r = c["roofline"]
        m = c["memory"]["peak_bytes_per_device"] / 2**30
        return (
            f"| {arch} | {shape} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {m:.2f} | "
            f"{r.get('useful_flops_ratio', 0):.3f} | "
            f"{r.get('mfu_upper_bound', 0):.4f} |")
    except (KeyError, TypeError):
        return f"| {arch} | {shape} | — | — | — | malformed-cell | — | — | — |"


def make_table(cells, mesh: str = "pod1") -> str:
    lines = ["| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | "
             "bottleneck | mem/dev (GiB) | useful-FLOPs | MFU-bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        lines.append(_cell_row(c))
    return "\n".join(lines)


def run(quick: bool = False):
    out = []
    cells = load_cells()
    if not cells:
        raise RuntimeError(
            f"no dry-run cells in {DRYRUN} — the roofline artifacts would "
            f"be empty. Generate the cells first:\n    {DRYRUN_CMD}")
    ok = [c for c in cells if c.get("status") == "ok"]
    skip = [c for c in cells if str(c.get("status", "")).startswith("skip")]
    err = [c for c in cells if c.get("status") not in ("ok",)
           and not str(c.get("status", "")).startswith("skip")]
    ials_ok = [c for c in ok if str(c.get("arch", "")).startswith("ials_")]
    if not ok:
        raise RuntimeError(
            f"{len(cells)} dry-run cells in {DRYRUN} but none with "
            f"status=ok — regenerate them:\n    {DRYRUN_CMD}")
    out.append(row("roofline/cells", 0.0,
                   {"ok": len(ok), "skipped": len(skip), "error": len(err),
                    "ials_ok": len(ials_ok)}))
    for c in ok:
        try:
            r = c["roofline"]
            out.append(row(
                f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}", 0.0,
                {"bottleneck": r["bottleneck"],
                 "t_comp": round(r["t_compute_s"], 4),
                 "t_mem": round(r["t_memory_s"], 4),
                 "t_coll": round(r["t_collective_s"], 4),
                 "mfu_bound": round(r.get("mfu_upper_bound", 0), 5)}))
        except (KeyError, TypeError):
            out.append(row(f"roofline/{c.get('arch', '?')}/"
                           f"{c.get('shape', '?')}/malformed", 0.0,
                           {"error": "malformed cell"}))
    programs = sorted({c.get("program") for c in ials_ok
                       if c.get("program")})
    save_json("roofline_summary", {
        "ok": len(ok), "skipped": len(skip), "error": len(err),
        "ials_ok": len(ials_ok), "ials_programs": programs})
    outdir = Path(__file__).resolve().parent.parent / "results" / "bench"
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "roofline_table.md").write_text(
        make_table(cells, "pod1") + "\n")
    (outdir / "roofline_table_pod2.md").write_text(
        make_table(cells, "pod2") + "\n")
    return out
