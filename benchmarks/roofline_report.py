"""Dry-run roofline table: reads results/dryrun/*.json -> CSV rows + the
markdown table EXPERIMENTS.md embeds (results/bench/roofline_table.md)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import row, save_json

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(DRYRUN.glob("*.json")):
        try:
            cells.append(json.loads(f.read_text()))
        except json.JSONDecodeError:
            pass
    return cells


def make_table(cells, mesh: str = "pod1") -> str:
    lines = ["| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | "
             "bottleneck | mem/dev (GiB) | useful-FLOPs | MFU-bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"{c.get('status','?')} | — | — | — |")
            continue
        r = c["roofline"]
        m = c["memory"]["peak_bytes_per_device"] / 2**30
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {m:.1f} | "
            f"{r.get('useful_flops_ratio', 0):.3f} | "
            f"{r.get('mfu_upper_bound', 0):.4f} |")
    return "\n".join(lines)


def run(quick: bool = False):
    out = []
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skip = [c for c in cells if str(c.get("status", "")).startswith("skip")]
    err = [c for c in cells if c.get("status") == "error"]
    out.append(row("roofline/cells", 0.0,
                   {"ok": len(ok), "skipped": len(skip), "error": len(err)}))
    for c in ok:
        if c["mesh"] != "pod1":
            continue
        r = c["roofline"]
        out.append(row(
            f"roofline/{c['arch']}/{c['shape']}", 0.0,
            {"bottleneck": r["bottleneck"],
             "t_comp": round(r["t_compute_s"], 4),
             "t_mem": round(r["t_memory_s"], 4),
             "t_coll": round(r["t_collective_s"], 4),
             "mfu_bound": round(r.get("mfu_upper_bound", 0), 5)}))
    table = make_table(cells, "pod1")
    save_json("roofline_summary", {
        "ok": len(ok), "skipped": len(skip), "error": len(err)})
    outdir = Path(__file__).resolve().parent.parent / "results" / "bench"
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "roofline_table.md").write_text(table + "\n")
    (outdir / "roofline_table_pod2.md").write_text(
        make_table(cells, "pod2") + "\n")
    return out
